"""End-to-end driver: federated training of a ~100M-param qwen3-family model
with SAFA in silo mode for a few hundred rounds on CPU.

This is the 'train a ~100M model for a few hundred steps' deliverable; the
identical code path lowers on the 16x16 / 2x16x16 production meshes (see
repro/launch/dryrun.py).

    PYTHONPATH=src python examples/llm_federated.py [--rounds 200]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import run
from repro.models.model import build_model

ap = argparse.ArgumentParser()
ap.add_argument('--rounds', type=int, default=200)
ap.add_argument('--clients', type=int, default=4)
args = ap.parse_args()

# ~100M-param member of the qwen3 family (qk-norm, GQA), CPU-trainable.
cfg = dataclasses.replace(
    get_config('qwen3-1.7b'),
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
    vocab_size=2048, dtype=jnp.float32, remat=False,
    q_block=64, kv_block=64)
# register it under a temporary id by monkey-running the driver directly
import repro.launch.train as T


def _patched_get_config(arch_id):
    return cfg


T.get_config = _patched_get_config
n = build_model(cfg).n_params()
print(f'model: qwen3-family reduced, {n/1e6:.1f}M params, '
      f'{args.clients} federated clients, SAFA tau=5 C=0.5')
hist = run('qwen3-1.7b', rounds=args.rounds, n_clients=args.clients,
           fraction=0.5, lag_tolerance=5, crash_prob=0.2, batch=4, seq=64,
           local_steps=2, lr=0.05, full_size=True,
           ckpt='results/llm_federated.npz')
print(f'loss: {hist[0]:.3f} -> {min(hist):.3f} over {args.rounds} rounds')
