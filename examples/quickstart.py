"""Quickstart: federated learning with SAFA in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro import api
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec

# 1. An edge environment: 5 unreliable clients (30% crash rate per round).
#    EnvSpec is declarative — .build() draws the client population.
env = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
              t_lim=830.0, seed=3).build()

# 2. A federated task: Boston-housing-like regression, data partitioned
#    with the paper's N(mu, 0.3mu) imbalance model.
x, y = make_regression()
data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
task = regression_task(data, lr=1e-3, epochs=3)

# 3. Declare the experiment: SAFA with post-training CFCFM selection
#    (C=0.5) and lag tolerance 5; execution knobs live in ExecSpec.
exp = api.Experiment(task, env,
                     api.SafaSpec(fraction=0.5, lag_tolerance=5),
                     api.ExecSpec(eval_every=15),
                     rounds=60)

# 4. Compile and run (one lax.scan dispatch per eval segment).
hist = exp.compile().run()

print(f'protocol: {hist.protocol}')
print(f'best eval: {hist.best_eval}')
print(f'mean round length: {hist.mean("round_len"):.1f}s  '
      f'(deadline {env.t_lim:.0f}s)')
print(f'EUR {hist.mean("eur"):.3f} | SR {hist.mean("sr"):.3f} | '
      f'VV {hist.mean("vv"):.3f} | futility {hist.futility:.3f}')
