"""Reproduce the paper's headline comparison: SAFA vs FedAvg vs FedCS vs
FedAsync vs fully-local, on round efficiency and model quality, across
crash rates.  Each protocol's crash-rate grid runs as one batched fleet
(``Experiment(...).compile().run_sweep``) — every protocol in the
``api.PROTOCOLS`` registry shares the scan/fleet engines.

    PYTHONPATH=src python examples/protocol_comparison.py

(ROUNDS env var overrides the round count — CI uses a tiny value.)
"""
import os

from repro import api
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec, env_grid

C, ROUNDS = 0.3, int(os.environ.get('ROUNDS', '80'))
CRASH_RATES = (0.1, 0.3, 0.5, 0.7)
BASE = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
               t_lim=830.0, seed=3)

env0 = BASE.build()
x, y = make_regression()
data = partition(x, y, env0.partition_sizes, 5, seed=1)
task = regression_task(data, lr=1e-3, epochs=3)

rows = {}
for pdef in api.PROTOCOLS.values():
    members = [api.SweepMember(env=spec, fraction=C, lag_tolerance=5)
               for spec in env_grid(BASE, crash_prob=CRASH_RATES)]
    exp = api.Experiment(task, env0, pdef.spec_cls(),
                         api.ExecSpec(eval_every=max(2, ROUNDS // 4)),
                         rounds=ROUNDS)
    hists = exp.compile().run_sweep(members)
    rows.update({(cr, pdef.name): h for cr, h in zip(CRASH_RATES, hists)})

print(f'{"cr":>4} {"protocol":>8} {"best_acc":>9} {"round_len":>10} '
      f'{"EUR":>6} {"SR":>6} {"futility":>8}')
for cr in CRASH_RATES:
    for name in ('local', 'fedavg', 'fedcs', 'fedasync', 'safa'):
        h = rows[(cr, name)]
        print(f'{cr:>4} {name:>8} {h.best_eval["acc"]:>9.4f} '
              f'{h.mean("round_len"):>10.1f} {h.mean("eur"):>6.3f} '
              f'{h.mean("sr"):>6.3f} {h.futility:>8.3f}')
