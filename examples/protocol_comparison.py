"""Reproduce the paper's headline comparison: SAFA vs FedAvg vs FedCS vs
fully-local, on round efficiency and model quality, across crash rates.

    PYTHONPATH=src python examples/protocol_comparison.py
"""
import numpy as np

from repro.core import federation
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv

C, ROUNDS = 0.3, 80
print(f'{"cr":>4} {"protocol":>8} {"best_acc":>9} {"round_len":>10} '
      f'{"EUR":>6} {"SR":>6} {"futility":>8}')
for cr in (0.1, 0.3, 0.5, 0.7):
    for name in ('local', 'fedavg', 'fedcs', 'safa'):
        env = FLEnv(m=5, crash_prob=cr, dataset_size=506, batch_size=5,
                    epochs=3, t_lim=830.0, seed=3)
        x, y = make_regression()
        data = partition(x, y, env.partition_sizes, 5, seed=1)
        task = regression_task(data, lr=1e-3, epochs=3)
        kw = dict(fraction=C, rounds=ROUNDS, eval_every=20)
        if name == 'safa':
            kw['lag_tolerance'] = 5
        h = federation.PROTOCOLS[name](task, env, **kw)
        print(f'{cr:>4} {name:>8} {h.best_eval["acc"]:>9.4f} '
              f'{h.mean("round_len"):>10.1f} {h.mean("eur"):>6.3f} '
              f'{h.mean("sr"):>6.3f} {h.futility:>8.3f}')
