"""Batched serving example: prefill + KV-cache decode on three architecture
families (dense+SWA, SSM, MoE), demonstrating the family-specific caches.

    PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import run

for arch in ('h2o-danube-3-4b', 'mamba2-130m', 'llama4-scout-17b-a16e'):
    print(f'=== {arch} (reduced config) ===')
    run(arch, batch=2, prompt_len=16, gen=8)
    print()
