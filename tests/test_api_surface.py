"""Public-API snapshot: ``repro.api.__all__``, the spec field names and
the registry contents are a contract — a failing test here means the
public surface changed, which must be deliberate (update the snapshot in
the same commit, and the migration notes in docs/ARCHITECTURE.md).

No hypothesis dependency — this module must run in a bare environment.
"""
import dataclasses

import pytest

from repro import api

EXPECTED_ALL = {
    'CompiledRunner', 'CsaflSpec', 'ExecSpec', 'Experiment', 'FedAsyncSpec',
    'FedAvgSpec', 'FedCSSpec', 'History', 'LocalSpec', 'PROTOCOLS',
    'ProtocolDef', 'ProtocolSpec', 'RoundRecord', 'STALENESS_FNS',
    'SafaSpec', 'SeaflSpec', 'SweepMember', 'SweepSpec', 'Task',
    'WEIGHTED_SCHEMES', 'check_compat', 'init_fleet_global',
    'precompute_weighted_schedule', 'register', 'spec',
    'staleness_discount',
}

SPEC_FIELDS = {
    'SafaSpec': ('fraction', 'lag_tolerance', 'quantize_uploads'),
    'FedAvgSpec': ('fraction', 'sampler'),
    'FedCSSpec': ('fraction',),
    'LocalSpec': ('fraction',),
    'FedAsyncSpec': ('alpha', 'staleness_exp', 'staleness_fn', 'hinge_a',
                     'hinge_b'),
    'SeaflSpec': ('alpha', 'staleness_fn', 'staleness_exp', 'hinge_a',
                  'hinge_b', 'use_loss', 'loss_coef'),
    'CsaflSpec': ('clusters', 'alpha', 'staleness_fn', 'staleness_exp',
                  'hinge_a', 'hinge_b'),
    'ExecSpec': ('engine', 'wire', 'use_kernel', 'schedule', 'shard',
                 'eval_every', 'numeric'),
    'SweepSpec': ('members', 'tasks'),
    'SweepMember': ('env', 'fraction', 'lag_tolerance', 'seed', 'alpha',
                    'staleness_exp', 'overrides'),
}


def test_all_snapshot():
    assert set(api.__all__) == EXPECTED_ALL
    for name in api.__all__:
        assert hasattr(api, name), name


def test_spec_field_snapshot():
    for cls_name, fields in SPEC_FIELDS.items():
        cls = getattr(api, cls_name)
        assert tuple(f.name for f in dataclasses.fields(cls)) == fields, \
            cls_name


def test_protocol_specs_are_frozen():
    for cls_name in ('SafaSpec', 'FedAvgSpec', 'FedCSSpec', 'LocalSpec',
                     'FedAsyncSpec', 'SeaflSpec', 'CsaflSpec', 'ExecSpec',
                     'SweepSpec'):
        inst = getattr(api, cls_name)() if cls_name != 'SweepSpec' \
            else api.SweepSpec(members=())
        with pytest.raises(dataclasses.FrozenInstanceError):
            inst.some_field = 1


def test_registry_snapshot():
    assert {d.name for d in api.PROTOCOLS.values()} == \
        {'safa', 'fedavg', 'fedcs', 'local', 'fedasync', 'seafl', 'csafl'}
    assert set(api.PROTOCOLS) == {api.SafaSpec, api.FedAvgSpec,
                                  api.FedCSSpec, api.LocalSpec,
                                  api.FedAsyncSpec, api.SeaflSpec,
                                  api.CsaflSpec}
    for pdef in api.PROTOCOLS.values():
        for fn in ('precompute', 'fleet_precompute', 'scan_segment',
                   'loop_round', 'fleet_segment'):
            assert callable(getattr(pdef, fn)), (pdef.name, fn)


def test_exec_spec_defaults():
    ex = api.ExecSpec()
    assert (ex.engine, ex.wire, ex.use_kernel, ex.shard, ex.eval_every,
            ex.numeric) == (None, 'f32', False, True, 10, True)
