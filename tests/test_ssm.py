"""Mamba2 / SSD correctness: chunked dual form vs sequential recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import ssm


class TestSSD:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 33), st.integers(1, 3),
           st.sampled_from([4, 8]), st.sampled_from([4, 16]),
           st.integers(0, 100))
    def test_chunked_matches_sequential(self, b, s, h, p, n, seed):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B = jax.random.normal(ks[3], (b, s, n)) * 0.5
        C = jax.random.normal(ks[0], (b, s, n)) * 0.5
        y_chunk, st_chunk = ssm.ssd_chunked(x, dt, A, B, C, chunk=8)
        y_ref, st_ref = ssm.ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st_ref),
                                   atol=1e-4, rtol=1e-3)

    def test_initial_state_passing(self):
        """Splitting a sequence across two chunked calls == one call."""
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 5)
        b, s, h, p, n = 2, 24, 2, 8, 4
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B = jax.random.normal(ks[3], (b, s, n)) * 0.5
        C = jax.random.normal(ks[4], (b, s, n)) * 0.5
        y_full, st_full = ssm.ssd_chunked(x, dt, A, B, C, chunk=8)
        half = s // 2
        y1, st1 = ssm.ssd_chunked(x[:, :half], dt[:, :half], A, B[:, :half],
                                  C[:, :half], chunk=8)
        y2, st2 = ssm.ssd_chunked(x[:, half:], dt[:, half:], A, B[:, half:],
                                  C[:, half:], chunk=8, initial_state=st1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                                   atol=1e-4, rtol=1e-3)


class TestMambaBlock:
    def test_decode_chain_matches_parallel(self):
        """Step-by-step block decode == full-sequence block forward."""
        key = jax.random.PRNGKey(2)
        d_model, d_state, headdim = 32, 8, 16
        from repro.models import common as cm
        p = cm.unbox(ssm.init_mamba_block(key, d_model, d_state, headdim, jnp.float32))[0]
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 9, d_model))
        y_par = ssm.apply_mamba_block(p, x, d_state=d_state, headdim=headdim,
                                      chunk=4)
        cache = ssm.init_mamba_cache(2, d_model, d_state, headdim, jnp.float32)
        ys = []
        for t in range(x.shape[1]):
            cache, y = ssm.step_mamba_block(p, cache, x[:, t:t + 1],
                                            d_state=d_state, headdim=headdim)
            ys.append(y)
        y_seq = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                                   atol=2e-4, rtol=2e-3)
