"""Compressed-wire fast path: packed int8 quantize kernels, the fused
dequant-aggregate kernel, the ``wire='int8'`` protocol knob, and the
satellite helpers (backend detection, comm_bytes layouts, memoised
per-leaf reference wrapper).

No hypothesis dependency — this module must run in a bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federation, protocol
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.comm_quant import (QBLOCK, dequantize, dequantize_packed,
                                      quantize, quantize_packed,
                                      quantize_packed_fleet)
from repro.kernels.safa_aggregate import (safa_aggregate_packed_q8,
                                          safa_aggregate_packed_q8_fleet)


def _env(**kw):
    base = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
                epochs=3, t_lim=830.0, seed=3)
    base.update(kw)
    return FLEnv(**base)


@pytest.fixture(scope='module')
def reg_task():
    env = _env()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, 5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestQuantizePacked:
    @pytest.mark.parametrize('m,n,tile', [(1, 2048, 2048), (5, 4096, 2048),
                                          (8, 1024, 512), (3, 512, 256)])
    def test_matches_per_row_kernel(self, m, n, tile):
        """The packed kernel == m per-row ``quantize`` calls, bit for bit
        (the contract that makes the wire path bit-identical to the
        per-leaf reference)."""
        x = jax.random.normal(jax.random.PRNGKey(m + n), (m, n)) * 2.0
        q, s = quantize_packed(x, tile=tile)
        for k in range(m):
            qk, sk = quantize(x[k], tile=tile)
            np.testing.assert_array_equal(np.asarray(q[k]), np.asarray(qk))
            np.testing.assert_array_equal(np.asarray(s[k]), np.asarray(sk))

    def test_matches_oracle(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 4096)) * 3.0
        q, s = quantize_packed(x)
        rq, rs = ref.quantize_packed_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)

    def test_dequantize_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 2048)) * 2.0
        q, s = quantize_packed(x)
        xd = dequantize_packed(q, s)
        for k in range(6):
            dk = dequantize(q[k], s[k], n=2048)
            np.testing.assert_array_equal(np.asarray(xd[k]), np.asarray(dk))
        # int8 symmetric error bound: half a quant step per block
        err = np.abs(np.asarray(xd) - np.asarray(x))
        bound = np.repeat(np.asarray(s) / 2 + 1e-7, QBLOCK, axis=1)
        assert np.all(err <= bound + 1e-6)

    def test_fleet_matches_singles(self):
        xs = jax.random.normal(jax.random.PRNGKey(2), (3, 5, 2048))
        qf, sf = quantize_packed_fleet(xs)
        for i in range(3):
            q1, s1 = quantize_packed(xs[i])
            np.testing.assert_array_equal(np.asarray(qf[i]), np.asarray(q1))
            np.testing.assert_array_equal(np.asarray(sf[i]), np.asarray(s1))

    def test_rejects_unpadded_width(self):
        with pytest.raises(ValueError, match='multiple of tile'):
            quantize_packed(jnp.zeros((2, 1000)))
        with pytest.raises(ValueError, match='QBLOCK'):
            quantize_packed(jnp.zeros((2, 192)), tile=192)


class TestPackedQ8Kernel:
    def _operands(self, m=5, n=4096, key=0):
        ks = jax.random.split(jax.random.PRNGKey(key), 9)
        x = jax.random.normal(ks[8], (m, n)) * 2.0
        q, s = quantize_packed(x)
        return dict(
            q=q, scales=s,
            base=jax.random.normal(ks[0], (m, n)),
            cache=jax.random.normal(ks[1], (m, n)),
            global_prev=jax.random.normal(ks[2], (n,)),
            picked=jax.random.bernoulli(ks[3], 0.4, (m,)),
            undrafted=jax.random.bernoulli(ks[4], 0.4, (m,)),
            deprecated=jax.random.bernoulli(ks[5], 0.3, (m,)),
            completed=jax.random.bernoulli(ks[6], 0.7, (m,)),
            weights=jax.nn.softmax(jax.random.normal(ks[7], (m,))))

    def test_matches_composition_oracle(self):
        """Fused kernel == dequantise rows -> crash-substitute -> Eq. 6-8,
        bit for bit, including the new_local output."""
        ops = self._operands()
        ng, nc, nl = safa_aggregate_packed_q8(*ops.values())
        rg, rc, rl = ref.safa_aggregate_q8_ref(*ops.values())
        np.testing.assert_array_equal(np.asarray(ng), np.asarray(rg))
        np.testing.assert_array_equal(np.asarray(nc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(nl), np.asarray(rl))

    def test_fleet_matches_singles(self):
        singles = [self._operands(key=k) for k in range(3)]
        stacked = [jnp.stack([np.asarray(s[k]) for s in singles])
                   for k in singles[0]]
        outs_f = safa_aggregate_packed_q8_fleet(*stacked)
        for i, ops in enumerate(singles):
            outs_1 = safa_aggregate_packed_q8(*ops.values())
            for a, b in zip(outs_f, outs_1):
                np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b))

    def test_single_dispatch(self):
        ops = self._operands()
        jaxpr = jax.make_jaxpr(
            lambda *a: safa_aggregate_packed_q8(*a))(*ops.values())
        assert kops.count_pallas_calls(jaxpr.jaxpr) == 1

    def test_rejects_unpadded_width(self):
        ops = self._operands(n=2048)
        with pytest.raises(ValueError, match='multiple of tile'):
            safa_aggregate_packed_q8(*ops.values(), tile=4096)


class TestWireSpecAlignment:
    SHAPES = ((4, 3), (64,), (8, 33), (2, 5, 7))

    def _global(self, key=4):
        ks = jax.random.split(jax.random.PRNGKey(key), len(self.SHAPES))
        return {f'p{i}': jax.random.normal(k, s)
                for i, (k, s) in enumerate(zip(ks, self.SHAPES))}

    def test_offsets_qblock_aligned(self):
        spec = kops.wire_spec(self._global())
        assert all(o % QBLOCK == 0 for o in spec.offsets)
        assert spec.n_total % QBLOCK == 0
        assert spec.n_padded % 2048 == 0
        for i, size in enumerate(spec.sizes):
            assert spec.slot(i) >= size

    def test_aligned_pack_roundtrip(self):
        g = self._global()
        spec = kops.wire_spec(g)
        m = 4
        stacked = jax.tree.map(lambda a: jnp.stack([a] * m), g)
        back = kops.unpack_stacked(kops.pack_stacked(stacked, spec), spec)
        _assert_trees_equal(back, stacked)
        gback = kops.unpack_global(kops.pack_global(g, spec), spec)
        _assert_trees_equal(gback, g)

    def test_wire_roundtrip_matches_per_leaf_reference(self):
        """``wire_roundtrip_packed`` (2 dispatches) == each client
        quantising each leaf independently (2 per leaf per client)."""
        g = self._global()
        m = 4
        stacked = jax.tree.map(
            lambda a: jax.random.normal(
                jax.random.PRNGKey(int(a.size)), (m,) + a.shape), g)
        rt = kops.wire_roundtrip_packed(stacked, like=g)

        def per_leaf(x):
            flat = x.reshape(m, -1)
            rows = [dequantize(*quantize(flat[k]), n=flat.shape[1])
                    for k in range(m)]
            return jnp.stack(rows).reshape(x.shape)

        _assert_trees_equal(rt, jax.tree.map(per_leaf, stacked))

    def test_non_f32_rejected(self):
        g16 = jax.tree.map(lambda a: a.astype(jnp.bfloat16), self._global())
        stacked = jax.tree.map(lambda a: jnp.stack([a] * 2), g16)
        with pytest.raises(TypeError, match='float32'):
            kops.wire_roundtrip_packed(stacked, like=g16)


class TestWireRound:
    KW = dict(fraction=0.5, lag_tolerance=5, rounds=8, eval_every=4)

    def test_scan_bit_identical_to_loop(self, reg_task):
        hists = {e: federation.run_safa(reg_task, _env(), engine=e,
                                        wire='int8', **self.KW)
                 for e in ('loop', 'scan')}
        _assert_trees_equal(hists['loop'].final_global,
                            hists['scan'].final_global)
        assert hists['loop'].evals() == hists['scan'].evals()

    def test_bit_identical_to_per_leaf_reference(self, reg_task):
        """Acceptance criterion: the packed wire path (2 dispatches per
        round) is bit-identical to the per-leaf quantize->dequantize
        reference (``quantize_uploads=True``), against both the jnp and
        the packed-kernel aggregation forms of the reference."""
        h_wire = federation.run_safa(reg_task, _env(), engine='scan',
                                     wire='int8', **self.KW)
        h_ref = federation.run_safa(reg_task, _env(), engine='scan',
                                    quantize_uploads=True, **self.KW)
        h_ref_packed = federation.run_safa(
            reg_task, _env(), engine='scan', quantize_uploads=True,
            use_kernel='packed', **self.KW)
        _assert_trees_equal(h_wire.final_global, h_ref.final_global)
        _assert_trees_equal(h_wire.final_global, h_ref_packed.final_global)
        assert h_wire.evals() == h_ref.evals()

    def test_fleet_bit_identical_to_sequential(self, reg_task):
        def members():
            return [federation.SweepMember(env=_env(), fraction=0.5,
                                           lag_tolerance=5, seed=s)
                    for s in (0, 1)]
        hf = federation.run_sweep(reg_task, members(), rounds=6,
                                  eval_every=3, wire='int8', engine='fleet')
        hs = federation.run_sweep(reg_task, members(), rounds=6,
                                  eval_every=3, wire='int8',
                                  engine='sequential')
        for a, b in zip(hf, hs):
            _assert_trees_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    def test_compressed_scan_round_is_two_dispatches(self, reg_task):
        """Acceptance criterion: a wire='int8' SAFA round on the packed
        path issues exactly 2 pallas_calls (quantize + fused
        dequant-aggregate), regardless of model depth."""
        env = _env()
        sched = federation.precompute_safa_schedule(
            env, fraction=0.5, lag_tolerance=5, rounds=3)
        ns = federation._NumericState(reg_task, env.m, 0)
        w = jnp.asarray(env.weights)
        jaxpr = jax.make_jaxpr(
            lambda g, l, c, s, ww: protocol._safa_scan(
                g, l, c, s, ww, reg_task.local_train, False, 'int8')
        )(ns.global_w, ns.local_w, ns.cache, sched.to_device(), w)
        assert kops.count_pallas_calls(jaxpr.jaxpr) == 2

    def test_fedavg_wire_scan_bit_identical_to_loop(self, reg_task):
        hists = {e: federation.run_fedavg(reg_task, _env(), fraction=0.5,
                                          rounds=6, eval_every=3, engine=e,
                                          wire='int8')
                 for e in ('loop', 'scan')}
        _assert_trees_equal(hists['loop'].final_global,
                            hists['scan'].final_global)

    def test_fedavg_wire_close_to_f32(self, reg_task):
        """The int8 wire perturbs FedAvg only at quantisation-noise
        scale."""
        h_q = federation.run_fedavg(reg_task, _env(), fraction=0.5,
                                    rounds=10, eval_every=10, wire='int8')
        h_f = federation.run_fedavg(reg_task, _env(), fraction=0.5,
                                    rounds=10, eval_every=10)
        assert h_q.best_eval['loss'] < h_f.best_eval['loss'] * 1.5 + 1.0

    def test_wire_validation(self, reg_task):
        with pytest.raises(ValueError, match='wire'):
            federation.run_safa(reg_task, _env(), wire='int4', **self.KW)
        with pytest.raises(ValueError, match='wire'):
            federation.run_fedavg(reg_task, _env(), fraction=0.5, rounds=2,
                                  wire='fp8')
        with pytest.raises(ValueError, match='reference'):
            federation.run_safa(reg_task, _env(), wire='int8',
                                quantize_uploads=True, **self.KW)

    def test_sweep_rejects_wire_for_local_and_fedasync(self, reg_task):
        members = [federation.SweepMember(env=_env(), fraction=0.5)]
        for proto in ('local', 'fedasync'):
            with pytest.raises(ValueError, match='wire'):
                federation.run_sweep(reg_task, members, rounds=2,
                                     proto=proto, wire='int8')


class TestBackendHelper:
    def test_kernel_modules_share_backend_constant(self):
        from repro.kernels import (backend, comm_quant, safa_aggregate,
                                   swa_attention)
        assert comm_quant.INTERPRET is backend.INTERPRET
        assert safa_aggregate.INTERPRET is backend.INTERPRET
        assert swa_attention.INTERPRET is backend.INTERPRET

    def test_env_override(self, monkeypatch):
        from repro.kernels import backend
        monkeypatch.setenv('REPRO_FORCE_INTERPRET', '1')
        assert backend.use_interpret() is True
        monkeypatch.setenv('REPRO_FORCE_INTERPRET', '0')
        assert backend.use_interpret() is False
        monkeypatch.setenv('REPRO_FORCE_INTERPRET', 'false')
        assert backend.use_interpret() is False
        # set-but-empty must fall back to detection, not force compile
        monkeypatch.setenv('REPRO_FORCE_INTERPRET', '')
        assert backend.use_interpret() == \
            (jax.default_backend() != 'tpu')
        monkeypatch.delenv('REPRO_FORCE_INTERPRET')
        assert backend.use_interpret() == \
            (jax.default_backend() != 'tpu')


class TestQuantizedTrainFnMemo:
    def test_memoised_per_wrapped_function(self):
        class T:
            def train_a(self, x):
                return x

            def train_b(self, x):
                return x

        t = T()
        wa1 = federation._quantized_train_fn(t.train_a)
        wa2 = federation._quantized_train_fn(t.train_a)
        wb = federation._quantized_train_fn(t.train_b)
        assert wa1 is wa2          # stable static arg across runs
        assert wa1 is not wb       # no stale closure for a different method

    def test_unbound_not_cached(self):
        def free_fn(x):
            return x
        w1 = federation._quantized_train_fn(free_fn)
        w2 = federation._quantized_train_fn(free_fn)
        assert w1 is not w2


class TestCommBytesLayout:
    def test_packed_accounting(self):
        tree = {'w': jnp.zeros((100, 13)), 'b': jnp.zeros((13,))}
        spec_f = kops.pack_spec(tree)
        spec_q = kops.wire_spec(tree)
        assert kops.comm_bytes(tree, quantized=False, layout='packed') == \
            4 * spec_f.n_padded
        assert kops.comm_bytes(tree, quantized=True, layout='packed') == \
            spec_q.n_padded + 4 * (spec_q.n_padded // QBLOCK)
        # tree layout unchanged from the historical accounting
        assert kops.comm_bytes(tree, quantized=False) == 4 * 1313
        assert kops.comm_bytes(tree, quantized=True) == \
            1313 + 4 * (-(-1300 // QBLOCK) + 1)

    def test_unknown_layout_rejected(self):
        with pytest.raises(ValueError, match='layout'):
            kops.comm_bytes({'w': jnp.zeros(4)}, quantized=False,
                            layout='Packed')
