"""Per-assigned-architecture smoke tests: a REDUCED variant of each family
(2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
decode step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct
lowering, no allocation) — see repro.launch.dryrun and EXPERIMENTS.md.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_supported
from repro.models.model import build_model


def make_batch(cfg, key, B=2, S=16):
    kt, kl = jax.random.split(key)
    batch = {'tokens': jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             'labels': jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.family == 'vlm':
        batch['patch_embeds'] = 0.1 * jax.random.normal(
            kt, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == 'audio':
        batch['frame_embeds'] = 0.1 * jax.random.normal(
            kt, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize('arch_id', ARCH_IDS)
class TestArchSmoke:
    def test_reduced_train_step(self, arch_id):
        cfg = get_config(arch_id).reduced()
        assert cfg.n_layers == 2 and cfg.d_model <= 512
        if cfg.n_experts:
            assert cfg.n_experts <= 4
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        batch = make_batch(cfg, key)
        loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
        assert np.isfinite(float(loss)), arch_id
        # one SGD step, loss decreases on the same batch
        params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
        loss2 = jax.jit(model.loss)(params2, batch)
        assert np.isfinite(float(loss2))
        assert float(loss2) < float(loss) + 1e-3

    def test_reduced_decode_step(self, arch_id):
        cfg = get_config(arch_id).reduced()
        model = build_model(cfg)
        key = jax.random.PRNGKey(1)
        params = model.init(key)
        B = 2
        cache = model.init_cache(B, 24, length=0)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        new_cache, logits = jax.jit(model.decode_step)(params, cache, tok)
        assert logits.shape == (B, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch_id
        assert int(new_cache['length']) == 1

    def test_full_config_shapes_only(self, arch_id):
        """The full config's parameter tree materialises as shapes without
        allocation, and the config matches its citation block."""
        cfg = get_config(arch_id)
        model = build_model(cfg)
        shapes = model.param_shapes()  # eval_shape: no allocation
        n = model.n_params()
        assert n > 1e8, (arch_id, n)
        leaves = jax.tree.leaves(shapes)
        assert all(hasattr(l, 'shape') for l in leaves)


def test_assigned_shape_matrix():
    """10 archs x 4 shapes = 40 pairs; long_500k skips documented."""
    pairs = [(a, s) for a in ARCH_IDS for s in INPUT_SHAPES]
    assert len(pairs) == 40
    supported = [p for p in pairs if shape_supported(*p)]
    skipped = [p for p in pairs if not shape_supported(*p)]
    assert len(supported) == 33
    assert all(s == 'long_500k' for _, s in skipped)
    # sub-quadratic-capable archs run long_500k
    for a in ('mamba2-130m', 'zamba2-1.2b', 'h2o-danube-3-4b'):
        assert shape_supported(a, 'long_500k')


def test_exact_assigned_hyperparams():
    """Configs must match the assignment table exactly."""
    t = {
        'h2o-danube-3-4b': (24, 3840, 32, 8, 10240, 32000),
        'minitron-4b': (32, 3072, 24, 8, 9216, 256000),
        'nemotron-4-340b': (96, 18432, 96, 8, 73728, 256000),
        'internvl2-26b': (48, 6144, 48, 8, 16384, 92553),
        'llama4-maverick-400b-a17b': (48, 5120, 40, 8, 8192, 202048),
        'llama4-scout-17b-a16e': (48, 5120, 40, 8, 8192, 202048),
        'qwen3-1.7b': (28, 2048, 16, 8, 6144, 151936),
        'whisper-medium': (24, 1024, 16, 16, 4096, 51865),
    }
    for a, (L, d, H, KH, ff, V) in t.items():
        c = get_config(a)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, H, KH, ff, V), a
    z = get_config('zamba2-1.2b')
    assert (z.n_layers, z.d_model, z.n_heads, z.n_kv_heads, z.d_ff,
            z.vocab_size, z.ssm_state) == (38, 2048, 32, 32, 8192, 32000, 64)
    m = get_config('mamba2-130m')
    assert (m.n_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (24, 768, 50280, 128)
    assert get_config('llama4-maverick-400b-a17b').n_experts == 128
    assert get_config('llama4-scout-17b-a16e').n_experts == 16
