"""SAFA protocol algebra: Eq. 3 / 6 / 7 / 8 semantics and CFCFM properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import metrics, protocol, selection


def _tree(key, m, shapes=((4, 3), (5,))):
    ks = jax.random.split(key, len(shapes))
    return {f'p{i}': jax.random.normal(k, (m,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def _global(key, shapes=((4, 3), (5,))):
    ks = jax.random.split(key, len(shapes))
    return {f'p{i}': jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


class TestDistribution:
    def test_sync_takes_global(self):
        m = 6
        g = _global(jax.random.PRNGKey(0))
        local = _tree(jax.random.PRNGKey(1), m)
        sync = jnp.array([True, False, True, False, False, True])
        out = protocol.distribute(g, local, sync)
        for k in g:
            for i in range(m):
                expect = g[k] if bool(sync[i]) else local[k][i]
                np.testing.assert_array_equal(out[k][i], expect)

    def test_classify_versions(self):
        v = jnp.array([5, 3, 1, 0, 5])
        committed = jnp.array([True, False, False, False, False])
        up, dep, tol = protocol.classify_versions(v, 5, 3, committed)
        np.testing.assert_array_equal(np.asarray(up), [1, 0, 0, 0, 0])
        # staleness: 0,2,4,5,0 ; deprecated iff >= 3 and not committed
        np.testing.assert_array_equal(np.asarray(dep), [0, 0, 1, 1, 0])
        np.testing.assert_array_equal(np.asarray(tol), [0, 1, 0, 0, 1])


class TestDiscriminativeAggregation:
    def test_eq678_by_hand(self):
        """Replay Eq. 6-8 entry by entry against the vectorized impl."""
        m = 5
        key = jax.random.PRNGKey(2)
        cache = _tree(key, m)
        trained = _tree(jax.random.PRNGKey(3), m)
        g = _global(jax.random.PRNGKey(4))
        picked = jnp.array([1, 0, 0, 1, 0], bool)
        undrafted = jnp.array([0, 1, 0, 0, 0], bool)
        deprecated = jnp.array([0, 0, 1, 1, 0], bool)
        w = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(m)),
                        jnp.float32)

        res = protocol.discriminative_aggregation(
            cache, trained, g, picked=picked, undrafted=undrafted,
            deprecated=deprecated, weights=w)

        for k in cache:
            # Eq. 6
            c1 = []
            for i in range(m):
                if bool(picked[i]):
                    c1.append(trained[k][i])
                elif bool(deprecated[i]):
                    c1.append(g[k])
                else:
                    c1.append(cache[k][i])
            c1 = jnp.stack(c1)
            # Eq. 7
            expect_global = jnp.tensordot(w, c1, axes=1)
            np.testing.assert_allclose(np.asarray(res.new_global[k]),
                                       np.asarray(expect_global), rtol=1e-5)
            # Eq. 8
            for i in range(m):
                expect = trained[k][i] if bool(undrafted[i]) else c1[i]
                np.testing.assert_allclose(np.asarray(res.new_cache[k][i]),
                                           np.asarray(expect), rtol=1e-6)

    def test_weights_sum_preserved(self):
        """Aggregating identical cache entries returns that entry."""
        m = 4
        g = _global(jax.random.PRNGKey(5))
        cache = protocol.broadcast_global(g, m)
        w = jnp.full((m,), 1.0 / m)
        out = protocol.aggregate(cache, w)
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(g[k]),
                                       rtol=1e-6)

    def test_kernel_path_matches_jnp_path(self):
        m = 6
        cache = _tree(jax.random.PRNGKey(6), m, shapes=((64,), (8, 33)))
        trained = _tree(jax.random.PRNGKey(7), m, shapes=((64,), (8, 33)))
        g = _global(jax.random.PRNGKey(8), shapes=((64,), (8, 33)))
        picked = jnp.array([1, 0, 1, 0, 0, 0], bool)
        undrafted = jnp.array([0, 1, 0, 0, 1, 0], bool)
        deprecated = jnp.array([0, 0, 0, 1, 0, 0], bool)
        w = jnp.full((m,), 1.0 / m)
        a = protocol.discriminative_aggregation(
            cache, trained, g, picked=picked, undrafted=undrafted,
            deprecated=deprecated, weights=w, use_kernel=False)
        b = protocol.discriminative_aggregation(
            cache, trained, g, picked=picked, undrafted=undrafted,
            deprecated=deprecated, weights=w, use_kernel=True)
        for k in cache:
            np.testing.assert_allclose(np.asarray(a.new_global[k]),
                                       np.asarray(b.new_global[k]), atol=1e-5)
            np.testing.assert_allclose(np.asarray(a.new_cache[k]),
                                       np.asarray(b.new_cache[k]), atol=1e-6)


class TestCFCFM:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(2, 40), st.floats(0.05, 1.0), st.integers(0, 10_000))
    def test_invariants(self, m, frac, seed):
        rng = np.random.default_rng(seed)
        arrival = rng.exponential(100, m)
        completed = rng.random(m) < 0.8
        arrival = np.where(completed, arrival, np.inf)
        picked_prev = rng.random(m) < 0.4
        deadline = 500.0
        sel = selection.cfcfm(arrival, completed, picked_prev, frac, deadline)
        quota = max(1, int(round(frac * m)))
        committed = completed & (arrival <= deadline)
        # picked are committed, disjoint from undrafted, and bounded by quota
        assert not np.any(sel.picked & ~committed)
        assert not np.any(sel.picked & sel.undrafted)
        assert sel.picked.sum() <= quota
        assert np.array_equal(sel.picked | sel.undrafted, committed)
        # if enough priority clients committed, quota is met entirely by them
        prio = committed & ~picked_prev
        if prio.sum() >= quota:
            assert sel.picked.sum() == quota
            assert not np.any(sel.picked & picked_prev)

    def test_compensatory_priority(self):
        """A slower not-picked-last-round client beats a faster picked one."""
        arrival = np.array([10.0, 20.0])
        completed = np.array([True, True])
        picked_prev = np.array([True, False])  # client 0 was picked last round
        sel = selection.cfcfm(arrival, completed, picked_prev, 0.5, 100.0)
        assert sel.picked.tolist() == [False, True]

    def test_fcfs_order_within_priority(self):
        arrival = np.array([30.0, 10.0, 20.0, 5.0])
        completed = np.ones(4, bool)
        picked_prev = np.zeros(4, bool)
        sel = selection.cfcfm(arrival, completed, picked_prev, 0.5, 100.0)
        # first two arrivals: client 3 (t=5) and client 1 (t=10)
        assert sel.picked.tolist() == [False, True, False, True]


class TestEURTheory:
    @settings(max_examples=40, deadline=None)
    @given(st.floats(0.05, 1.0), st.floats(0.0, 0.9))
    def test_eq5_regimes(self, C, R):
        eur = metrics.eur_theory_safa(C, R)
        assert eur == pytest.approx(min(C, 1 - R))
        assert metrics.eur_theory_fedavg(C, R) <= eur + 1e-9

    def test_eq5_matches_simulation(self):
        """Monte-Carlo CFCFM EUR converges to Eq. 5."""
        m, C, crash = 200, 0.3, 0.5
        rng = np.random.default_rng(0)
        prev = np.zeros(m, bool)
        eurs = []
        for _ in range(60):
            completed = rng.random(m) > crash
            arrival = np.where(completed, rng.exponential(10, m), np.inf)
            sel = selection.cfcfm(arrival, completed, prev, C, 1e9)
            eurs.append(metrics.eur_measured(sel.picked, ~completed))
            prev = sel.picked
        assert np.mean(eurs) == pytest.approx(
            metrics.eur_theory_safa(C, crash), abs=0.03)
