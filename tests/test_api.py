"""Unified experiment API: golden equivalence with the legacy runners,
checkpoint/resume bit-identity, per-member-Task sweeps, and the protocol
registry.

No hypothesis dependency — this module must run in a bare environment.
"""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.core import api, federation
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv

BASE = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
            epochs=3, t_lim=830.0, seed=3)


def _env(**kw):
    base = dict(BASE)
    base.update(kw)
    return FLEnv(**base)


@pytest.fixture(scope='module')
def reg_task():
    env = _env()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, 5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _legacy(name, task, env, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter('ignore', DeprecationWarning)
        return federation.RUNNERS[name](task, env, **kw)


def _legacy_sweep(task, members, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter('ignore', DeprecationWarning)
        return federation.run_sweep(task, members, **kw)


_PROTO_KW = {
    'safa': dict(fraction=0.5, lag_tolerance=5),
    'fedavg': dict(fraction=0.5),
    'fedcs': dict(fraction=0.5),
    'local': dict(fraction=0.5),
    'fedasync': {},
}
_WIRES = {'safa': ('f32', 'int8'), 'fedavg': ('f32', 'int8'),
          'fedcs': ('f32', 'int8'), 'local': ('f32',),
          'fedasync': ('f32',)}


class TestGoldenEquivalence:
    """Acceptance criterion: every legacy ``run_*`` call is bit-identical
    to its ``Experiment`` spelling, across all five protocols x
    {scan, loop} x {f32, int8-where-supported}."""

    @pytest.mark.parametrize('proto,engine,wire', [
        (p, e, w)
        for p in ('safa', 'fedavg', 'fedcs', 'local', 'fedasync')
        for e in ('scan', 'loop')
        for w in _WIRES[p]])
    def test_legacy_matches_experiment(self, reg_task, proto, engine, wire):
        kw = dict(_PROTO_KW[proto])
        legacy_kw = dict(kw, rounds=6, eval_every=3, engine=engine)
        if wire != 'f32':
            legacy_kw['wire'] = wire
        h_old = _legacy(proto, reg_task, _env(), **legacy_kw)
        exp = api.Experiment(
            reg_task, _env(), api.spec(proto, **kw),
            api.ExecSpec(engine=engine, wire=wire, eval_every=3),
            rounds=6)
        h_new = exp.compile().run()
        assert h_new.protocol == h_old.protocol
        _assert_tree_equal(h_new.final_global, h_old.final_global)
        assert h_new.evals() == h_old.evals()
        assert h_new.futility == h_old.futility
        assert h_new.records == h_old.records

    def test_timing_only_matches(self):
        for proto in federation.RUNNERS:
            kw = dict(_PROTO_KW[proto])
            h_old = _legacy(proto, None, _env(), rounds=10, numeric=False,
                            **kw)
            h_new = api.Experiment(
                None, _env(), api.spec(proto, **kw),
                api.ExecSpec(numeric=False), rounds=10).compile().run()
            assert h_new.records == h_old.records, proto
            assert h_new.futility == h_old.futility, proto

    def test_legacy_sweep_matches_run_sweep(self, reg_task):
        def members():
            return [api.SweepMember(env=_env(draw_seed=s), fraction=0.5,
                                    lag_tolerance=tau, seed=s)
                    for s, tau in ((0, 5), (1, 2))]
        h_old = _legacy_sweep(reg_task, members(), rounds=6, eval_every=3)
        exp = api.Experiment(reg_task, _env(),
                             api.SafaSpec(fraction=0.5, lag_tolerance=5),
                             api.ExecSpec(eval_every=3), rounds=6)
        h_new = exp.compile().run_sweep(members())
        for a, b in zip(h_new, h_old):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()
            assert a.futility == b.futility

    def test_experiment_schedule_cached_across_runs(self, reg_task):
        """The env rng is consumed once per Experiment: repeated run()
        calls replay the same schedule and produce the same bits."""
        exp = api.Experiment(reg_task, _env(),
                             api.SafaSpec(fraction=0.5, lag_tolerance=5),
                             api.ExecSpec(eval_every=2), rounds=4)
        runner = exp.compile()
        h1, h2 = runner.run(), runner.run()
        _assert_tree_equal(h1.final_global, h2.final_global)
        assert h1.evals() == h2.evals()

    def test_repeated_runs_do_not_alias_records(self, reg_task):
        """Histories from the same (schedule-cached) Experiment must not
        share RoundRecord objects: a later partial run would otherwise
        report the earlier run's evals for rounds it never executed."""
        exp = api.Experiment(reg_task, _env(),
                             api.SafaSpec(fraction=0.5, lag_tolerance=5),
                             api.ExecSpec(eval_every=3), rounds=9)
        runner = exp.compile()
        full = runner.run()
        partial = runner.run(max_segments=1)
        assert len(full.evals()) == 3
        assert len(partial.evals()) == 1        # no stale evals leak in
        assert full.records[0] is not partial.records[0]


class TestValidation:
    def test_unknown_wire_engine_kernel(self, reg_task):
        with pytest.raises(ValueError, match='wire'):
            api.check_compat(api.SafaSpec(), api.ExecSpec(wire='int4'))
        with pytest.raises(ValueError, match='engine'):
            api.check_compat(api.SafaSpec(), api.ExecSpec(engine='warp'))
        with pytest.raises(ValueError, match='use_kernel'):
            api.check_compat(api.SafaSpec(), api.ExecSpec(use_kernel='Packed'))

    def test_quantize_uploads_wire_exclusive(self):
        with pytest.raises(ValueError, match='reference'):
            api.check_compat(api.SafaSpec(quantize_uploads=True),
                             api.ExecSpec(wire='int8'))

    def test_unregistered_spec_rejected(self):
        @dataclasses.dataclass(frozen=True)
        class GossipSpec(api.ProtocolSpec):
            fanout: int = 2
        with pytest.raises(TypeError, match='unregistered'):
            api.check_compat(GossipSpec())

    def test_unknown_proto_name(self):
        with pytest.raises(ValueError, match='proto'):
            api.spec('gossip')

    def test_wire_rejected_uniformly_for_local_and_fedasync(self, reg_task):
        """Satellite: one check_compat, one message — on the new surface
        AND through the legacy run_local/run_fedasync shims."""
        messages = set()
        for name in ('local', 'fedasync'):
            with pytest.raises(ValueError, match='upload-aggregate wire') \
                    as ei:
                api.check_compat(api.spec(name), api.ExecSpec(wire='int8'))
            messages.add(str(ei.value).replace(name, '<proto>'))
        with pytest.raises(ValueError, match='upload-aggregate wire') as e1:
            _legacy('local', reg_task, _env(), fraction=0.5, rounds=2,
                    wire='int8')
        messages.add(str(e1.value).replace('local', '<proto>'))
        with pytest.raises(ValueError, match='upload-aggregate wire') as e2:
            _legacy('fedasync', reg_task, _env(), rounds=2, wire='int8')
        messages.add(str(e2.value).replace('fedasync', '<proto>'))
        assert len(messages) == 1  # identical wording everywhere

    def test_use_kernel_rejected_for_non_safa(self, reg_task):
        for name in ('fedavg', 'local', 'fedasync'):
            with pytest.raises(ValueError, match='use_kernel'):
                api.check_compat(api.spec(name),
                                 api.ExecSpec(use_kernel='packed'))
        with pytest.raises(ValueError, match='use_kernel'):
            _legacy('local', reg_task, _env(), fraction=0.5, rounds=2,
                    use_kernel=True)

    def test_sweep_spec_length_mismatch(self, reg_task):
        with pytest.raises(ValueError, match='task'):
            api.SweepSpec(members=(api.SweepMember(env=_env()),),
                          tasks=(reg_task, reg_task))


class TestHistoryRoundTrip:
    def test_to_dict_from_dict_through_json(self, reg_task):
        h = api.Experiment(reg_task, _env(),
                           api.SafaSpec(fraction=0.5, lag_tolerance=5),
                           api.ExecSpec(eval_every=2),
                           rounds=4).compile().run()
        d = json.loads(json.dumps(h.to_dict()))
        h2 = api.History.from_dict(d)
        assert h2.protocol == h.protocol
        assert h2.futility == h.futility
        assert h2.best_eval == h.best_eval
        assert h2.records == h.records          # exact floats: json reprs
        assert h2.evals() == h.evals()
        assert h2.final_global is None          # excluded by contract

    def test_timing_only_roundtrip(self):
        h = api.Experiment(None, _env(), api.FedAvgSpec(fraction=0.3),
                           api.ExecSpec(numeric=False),
                           rounds=8).compile().run()
        h2 = api.History.from_dict(json.loads(json.dumps(h.to_dict())))
        assert h2.records == h.records


class TestCheckpointResume:
    def _exp(self, task, **kw):
        cfg = dict(rounds=9, eval_every=3)
        cfg.update(kw)
        return api.Experiment(task, _env(),
                              api.SafaSpec(fraction=0.5, lag_tolerance=5),
                              api.ExecSpec(eval_every=cfg['eval_every']),
                              rounds=cfg['rounds'])

    def test_resume_single_run_bit_identical(self, reg_task, tmp_path):
        """Acceptance criterion: a run killed mid-way resumes from its
        checkpoint to a bit-identical History."""
        golden = self._exp(reg_task).compile().run()
        path = str(tmp_path / 'run.npz')
        partial = self._exp(reg_task).compile().run(checkpoint=path,
                                                    max_segments=1)
        assert len(partial.evals()) == 1        # killed after segment 1
        resumed = self._exp(reg_task).compile().run(checkpoint=path)
        _assert_tree_equal(resumed.final_global, golden.final_global)
        assert resumed.evals() == golden.evals()
        assert resumed.best_eval == golden.best_eval
        assert resumed.futility == golden.futility

    def test_resume_loop_engine(self, reg_task, tmp_path):
        """Checkpoint boundaries are eval segments, so the reference loop
        engine resumes too."""
        mk = lambda: api.Experiment(
            reg_task, _env(), api.SafaSpec(fraction=0.5, lag_tolerance=5),
            api.ExecSpec(engine='loop', eval_every=3), rounds=6)
        golden = mk().compile().run()
        path = str(tmp_path / 'loop.npz')
        mk().compile().run(checkpoint=path, max_segments=1)
        resumed = mk().compile().run(checkpoint=path)
        _assert_tree_equal(resumed.final_global, golden.final_global)
        assert resumed.evals() == golden.evals()

    def test_resume_mid_sweep_bit_identical(self, reg_task, tmp_path):
        """Acceptance criterion: a checkpointed sweep killed mid-run
        resumes to bit-identical per-member Histories."""
        def members():
            return [api.SweepMember(env=_env(draw_seed=s), fraction=f,
                                    lag_tolerance=tau, seed=s)
                    for s, (f, tau) in enumerate(((0.5, 5), (0.3, 2),
                                                  (1.0, 10), (0.1, 1)))]
        golden = self._exp(reg_task).compile().run_sweep(members())
        path = str(tmp_path / 'sweep.npz')
        partial = self._exp(reg_task).compile().run_sweep(
            members(), checkpoint=path, max_segments=1)
        assert all(len(h.evals()) == 1 for h in partial)
        resumed = self._exp(reg_task).compile().run_sweep(members(),
                                                          checkpoint=path)
        for a, b in zip(resumed, golden):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()
            assert a.best_eval == b.best_eval

    def test_fingerprint_mismatch_rejected(self, reg_task, tmp_path):
        path = str(tmp_path / 'fp.npz')
        self._exp(reg_task).compile().run(checkpoint=path, max_segments=1)
        other = api.Experiment(reg_task, _env(),
                               api.SafaSpec(fraction=0.5, lag_tolerance=2),
                               api.ExecSpec(eval_every=3), rounds=9)
        with pytest.raises(ValueError, match='fingerprint'):
            other.compile().run(checkpoint=path)

    def test_fingerprint_covers_task_data(self, reg_task, tmp_path):
        """Resuming a carry against different client data would silently
        mix two runs — the task participates in the fingerprint."""
        path = str(tmp_path / 'task_fp.npz')
        self._exp(reg_task).compile().run(checkpoint=path, max_segments=1)
        env = _env()
        x, y = make_regression()
        other_task = regression_task(
            partition(x, y, env.partition_sizes, 5, seed=2),  # other split
            lr=1e-3, epochs=3)
        with pytest.raises(ValueError, match='fingerprint'):
            self._exp(other_task).compile().run(checkpoint=path)

    def test_sequential_sweep_checkpoint_rejected(self, reg_task, tmp_path):
        exp = api.Experiment(reg_task, _env(),
                             api.SafaSpec(fraction=0.5, lag_tolerance=5),
                             api.ExecSpec(engine='sequential'), rounds=4)
        with pytest.raises(ValueError, match='fleet'):
            exp.compile().run_sweep([api.SweepMember(env=_env())],
                                    checkpoint=str(tmp_path / 'x.npz'))


class TestPerMemberTasks:
    """ROADMAP item: sweeps over members with *different client data*
    (padded stacking), closing the multi-seed env-sweep gap."""

    def _setup(self):
        # different env seeds => different partition sizes => different
        # batch counts: the padding path is actually exercised
        envs = [_env(seed=s) for s in (3, 4)]
        x, y = make_regression()
        tasks = [regression_task(partition(x, y, e.partition_sizes, 5,
                                           seed=1), lr=1e-3, epochs=3)
                 for e in envs]
        members = [api.SweepMember(env=e, fraction=0.5, lag_tolerance=5,
                                   seed=i) for i, e in enumerate(envs)]
        assert tasks[0]._x.shape != tasks[1]._x.shape  # ragged for real
        return members, tasks

    def _exp(self):
        return api.Experiment(None, _env(),
                              api.SafaSpec(fraction=0.5, lag_tolerance=5),
                              api.ExecSpec(eval_every=3), rounds=6)

    def test_fleet_bit_identical_to_sequential(self):
        """Acceptance criterion: per-member Tasks via padded stacking,
        fleet vs sequential bit-identity (the sequential members train on
        their own *unpadded* data — padding must be an exact no-op)."""
        members, tasks = self._setup()
        hf = self._exp().compile().run_sweep(
            api.SweepSpec(members=members, tasks=tasks))
        members2, tasks2 = self._setup()
        exp = api.Experiment(None, _env(),
                             api.SafaSpec(fraction=0.5, lag_tolerance=5),
                             api.ExecSpec(engine='sequential', eval_every=3),
                             rounds=6)
        hs = exp.compile().run_sweep(api.SweepSpec(members=members2,
                                                   tasks=tasks2))
        for a, b in zip(hf, hs):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    def test_fleet_member_matches_single_run(self):
        members, tasks = self._setup()
        hf = self._exp().compile().run_sweep(
            api.SweepSpec(members=members, tasks=tasks))
        members2, tasks2 = self._setup()
        for s in range(2):
            single = api.Experiment(
                tasks2[s], members2[s].env,
                api.SafaSpec(fraction=0.5, lag_tolerance=5),
                api.ExecSpec(eval_every=3), rounds=6,
                seed=members2[s].seed).compile().run()
            _assert_tree_equal(hf[s].final_global, single.final_global)
            assert hf[s].evals() == single.evals()

    def test_legacy_run_sweep_accepts_task_list(self):
        members, tasks = self._setup()
        hl = _legacy_sweep(tasks, members, rounds=6, eval_every=3)
        members2, tasks2 = self._setup()
        hn = self._exp().compile().run_sweep(
            api.SweepSpec(members=members2, tasks=tasks2))
        for a, b in zip(hl, hn):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    def test_local_per_member_tasks(self):
        """The train-context threading also covers the local fleet (no
        global carry; vmapped aggregation at eval points)."""
        members, tasks = self._setup()
        exp = api.Experiment(None, _env(), api.LocalSpec(fraction=0.5),
                             api.ExecSpec(eval_every=3), rounds=6)
        hf = exp.compile().run_sweep(api.SweepSpec(members=members,
                                                   tasks=tasks))
        members2, tasks2 = self._setup()
        exp2 = api.Experiment(None, _env(), api.LocalSpec(fraction=0.5),
                              api.ExecSpec(engine='sequential',
                                           eval_every=3), rounds=6)
        hs = exp2.compile().run_sweep(api.SweepSpec(members=members2,
                                                    tasks=tasks2))
        for a, b in zip(hf, hs):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    def test_stacked_tasks_validation(self):
        from repro.data.tasks import stack_tasks
        members, tasks = self._setup()
        env = _env(seed=5)
        x, y = make_regression()
        data = partition(x, y, env.partition_sizes, 5, seed=1)
        with pytest.raises(ValueError, match='epoch'):
            stack_tasks([tasks[0], regression_task(data, lr=1e-3, epochs=2)])
        with pytest.raises(ValueError, match='lr'):
            # one compiled train step serves all members: differing lr
            # would silently train member 1 with member 0's step
            stack_tasks([tasks[0], regression_task(data, lr=1e-1, epochs=3)])
        with pytest.raises(ValueError, match='empty'):
            stack_tasks([])


class TestRegistry:
    def test_builtin_registry_contents(self):
        import repro.api  # noqa: F401 — registers the aggregation family
        assert {d.name for d in api.PROTOCOLS.values()} == \
            {'safa', 'fedavg', 'fedcs', 'local', 'fedasync', 'seafl',
             'csafl'}
        assert api.PROTOCOLS[api.SafaSpec].uses_cache
        assert not api.PROTOCOLS[api.LocalSpec].supports_wire

    def test_register_new_variant_without_touching_federation(self,
                                                              reg_task):
        """A new spec type registers with the precompute/scan/fleet triple
        of an existing protocol and immediately runs through Experiment —
        the extension point a SEAFL-style staleness-discounted variant
        would use."""
        @dataclasses.dataclass(frozen=True)
        class TwinSafaSpec(api.ProtocolSpec):
            fraction: float = 0.5
            lag_tolerance: int = 5

        base = api.PROTOCOLS[api.SafaSpec]
        pdef = api.ProtocolDef(
            name='safa-twin', spec_cls=TwinSafaSpec,
            precompute=lambda env, sp, *, rounds, seed: base.precompute(
                env, api.SafaSpec(fraction=sp.fraction,
                                  lag_tolerance=sp.lag_tolerance),
                rounds=rounds, seed=seed),
            fleet_precompute=base.fleet_precompute,
            scan_segment=base.scan_segment, loop_round=base.loop_round,
            fleet_segment=base.fleet_segment,
            uses_cache=True, supports_wire=True, supports_kernel=True)
        api.register(pdef)
        try:
            with pytest.raises(ValueError, match='registered'):
                api.register(pdef)           # duplicate names rejected
            h = api.Experiment(reg_task, _env(), TwinSafaSpec(),
                               api.ExecSpec(eval_every=2),
                               rounds=4).compile().run()
            ref = api.Experiment(reg_task, _env(), api.SafaSpec(),
                                 api.ExecSpec(eval_every=2),
                                 rounds=4).compile().run()
            _assert_tree_equal(h.final_global, ref.final_global)
            assert h.evals() == ref.evals()
            assert api.spec('safa-twin', fraction=0.3).fraction == 0.3
        finally:
            del api.PROTOCOLS[TwinSafaSpec]
            del api._BY_NAME['safa-twin']
