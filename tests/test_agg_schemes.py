"""The staleness-adaptive aggregation family: discount goldens, legacy
bit-identity, the fedasync fold, the packed merge kernel, member
overrides, every ``check_compat`` rejection's golden message, and the
``init_fleet_global`` contract (the carried batched-init roadmap item).

Engine-identity invariants (scan==loop, fleet==sequential==single, int8
wire parity, resume) live in ``test_conformance.py`` — this module covers
what the registry-wide matrix can't: exact values and exact messages.
"""
import dataclasses

import jax
import numpy as np
import pytest

import conformance as C
from repro import api
from repro.core import agg_schemes, federation
from repro.fedsim import FLEnv


def fresh_env(seed=3, **kw):
    base = dict(C.BASE_ENV)
    base.update(kw)
    return FLEnv(seed=seed, **base)


def assert_tree_close(a, b, rtol, context=''):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f'{context}: tree structures differ'
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol,
                                   atol=1e-7, err_msg=f'{context}: leaf {i}')


# ---------------------------------------------------------------------------
# Surface: the family is reachable from the facade and the registry
# ---------------------------------------------------------------------------

class TestSurface:
    def test_facade_exports(self):
        for name in ('CsaflSpec', 'SeaflSpec', 'WEIGHTED_SCHEMES',
                     'STALENESS_FNS', 'precompute_weighted_schedule',
                     'staleness_discount', 'init_fleet_global'):
            assert hasattr(api, name), name

    def test_registered_through_api_register(self):
        by_name = {p.name: p for p in api.PROTOCOLS.values()}
        for name, cls in (('seafl', api.SeaflSpec), ('csafl', api.CsaflSpec)):
            pdef = by_name[name]
            assert pdef.spec_cls is cls
            assert pdef.supports_wire
            assert pdef.supports_kernel == 'packed'
            assert pdef.sparse_precompute is None

    def test_spec_by_name(self):
        sp = api.spec('csafl', clusters=4, alpha=0.5)
        assert isinstance(sp, api.CsaflSpec)
        assert (sp.clusters, sp.alpha) == (4, 0.5)


# ---------------------------------------------------------------------------
# Discount goldens
# ---------------------------------------------------------------------------

class TestDiscountGoldens:
    def test_constant(self):
        np.testing.assert_array_equal(
            agg_schemes.staleness_discount([0, 1, 50], 'constant'),
            [1.0, 1.0, 1.0])

    def test_poly(self):
        got = agg_schemes.staleness_discount([0, 3, 8], 'poly',
                                             staleness_exp=0.5)
        np.testing.assert_allclose(got, [1.0, 0.5, 1.0 / 3.0], rtol=1e-15)
        np.testing.assert_allclose(
            agg_schemes.staleness_discount([4], 'poly', staleness_exp=1.0),
            [0.2], rtol=1e-15)

    def test_poly_matches_legacy_expression(self):
        # bit-for-bit the engine's legacy alpha scaling: (1+s)**-exp
        s = np.arange(0, 20, dtype=float)
        np.testing.assert_array_equal(
            agg_schemes.staleness_discount(s, 'poly', staleness_exp=0.5),
            (1.0 + s) ** -0.5)

    def test_hinge(self):
        got = agg_schemes.staleness_discount([0, 4, 5, 6], 'hinge',
                                             hinge_a=10.0, hinge_b=4)
        np.testing.assert_allclose(got, [1.0, 1.0, 0.1, 0.05], rtol=1e-15)

    def test_hinge_clamps_to_one(self):
        # raw hinge 1/(a*(s-b)) > 1 when a < 1/(s-b): must clamp, never
        # amplify
        got = agg_schemes.staleness_discount([1], 'hinge', hinge_a=0.1,
                                             hinge_b=0)
        np.testing.assert_array_equal(got, [1.0])

    def test_unknown_fn(self):
        with pytest.raises(ValueError, match='staleness_fn'):
            agg_schemes.staleness_discount([1], 'exp')


# ---------------------------------------------------------------------------
# Legacy bit-identity + the fedasync fold
# ---------------------------------------------------------------------------

class TestAsyncSchedule:
    def test_poly_bit_identical_to_legacy_precompute(self):
        new = agg_schemes.precompute_async_schedule(
            fresh_env(), rounds=8, alpha=0.6, staleness_fn='poly',
            staleness_exp=0.5)
        old = federation.precompute_fedasync_schedule(
            fresh_env(), rounds=8, alpha=0.6, staleness_exp=0.5)
        np.testing.assert_array_equal(new.alphas, old.alphas)
        np.testing.assert_array_equal(new.order, old.order)
        np.testing.assert_array_equal(new.committed, old.committed)
        assert [dataclasses.asdict(r) for r in new.records] == \
            [dataclasses.asdict(r) for r in old.records]
        assert new.futility == old.futility

    def test_fold_matches_sequential_engine(self):
        """A FedAsync member folded into the weighted engine
        (overrides={'scheme': 'fedasync'}) reproduces the sequential
        arrival-ordered merge chain to float tolerance."""
        ref = C.run_single(api.FedAsyncSpec())
        mem = api.SweepMember(env=C.fresh_env(), seed=0, alpha=0.6,
                              staleness_exp=0.5,
                              overrides={'scheme': 'fedasync'})
        folded = C.run_sweep(api.SeaflSpec(), [mem])[0]
        assert_tree_close(folded.final_global, ref.final_global, rtol=2e-5,
                          context='fold vs sequential')
        # identical event stream; evals differ in final ulps (the fold is
        # allclose to the sequential chain, not bit-identical)
        def without_eval(h):
            return [{k: v for k, v in dataclasses.asdict(r).items()
                     if k != 'eval'} for r in h.records]
        assert without_eval(folded) == without_eval(ref)
        np.testing.assert_allclose([e['loss'] for _, e in folded.evals()],
                                   [e['loss'] for _, e in ref.evals()],
                                   rtol=2e-5)

    def test_mixed_scheme_fleet_matches_sequential(self):
        """One fleet dispatch mixing all three weighted schemes equals the
        per-member sequential runs bit-for-bit."""
        def members():
            return [
                api.SweepMember(env=C.fresh_env(3), seed=0),
                api.SweepMember(env=C.fresh_env(4), seed=1,
                                overrides={'scheme': 'csafl', 'clusters': 2}),
                api.SweepMember(env=C.fresh_env(5), seed=2,
                                overrides={'scheme': 'fedasync'}),
            ]
        h_fleet = C.run_sweep(api.SeaflSpec(), members(), engine='fleet')
        h_seq = C.run_sweep(api.SeaflSpec(), members(), engine='sequential')
        for s in range(3):
            C.assert_history_equal(h_fleet[s], h_seq[s], f'member {s}')


# ---------------------------------------------------------------------------
# Packed merge kernel
# ---------------------------------------------------------------------------

class TestPackedKernel:
    @pytest.mark.parametrize('spec', [api.SeaflSpec(),
                                      api.CsaflSpec(clusters=3)],
                             ids=['seafl', 'csafl'])
    def test_packed_close_to_default(self, spec):
        ref = C.run_single(spec)
        h = C.run_single(spec, exec_kw={'use_kernel': 'packed'})
        assert_tree_close(h.final_global, ref.final_global, rtol=1e-5,
                          context='packed vs default')

    def test_packed_scan_equals_loop(self):
        kw = {'use_kernel': 'packed'}
        h_scan = C.run_single(api.SeaflSpec(), exec_kw=kw)
        h_loop = C.run_single(api.SeaflSpec(), engine='loop', exec_kw=kw)
        C.assert_history_equal(h_scan, h_loop, 'packed: scan vs loop')


# ---------------------------------------------------------------------------
# Member overrides
# ---------------------------------------------------------------------------

class TestOverrides:
    def test_member_columns_win(self):
        mem = api.SweepMember(env=None, alpha=0.3, staleness_exp=1.5)
        kw = agg_schemes.weighted_kwargs(api.SeaflSpec(), mem)
        assert (kw['alpha'], kw['staleness_exp']) == (0.3, 1.5)
        assert kw['scheme'] == 'seafl'

    def test_override_switches_scheme(self):
        mem = api.SweepMember(env=None, overrides={'scheme': 'fedasync'})
        assert agg_schemes.weighted_kwargs(api.SeaflSpec(),
                                           mem)['scheme'] == 'fedasync'

    def test_unknown_override_key_rejected(self):
        mem = api.SweepMember(env=None, overrides={'bogus': 1})
        with pytest.raises(ValueError, match='bogus'):
            agg_schemes.weighted_kwargs(api.SeaflSpec(), mem)

    def test_async_precompute_rejects_weighted_only_keys(self):
        # 'scheme'/'clusters' belong to the weighted family, not fedasync's
        # sequential-merge precompute
        mem = api.SweepMember(env=None, overrides={'clusters': 3})
        with pytest.raises(ValueError, match='clusters'):
            agg_schemes.async_kwargs(api.FedAsyncSpec(), mem)


# ---------------------------------------------------------------------------
# check_compat: every rejection, one golden fragment each
# ---------------------------------------------------------------------------

GOLDENS = [
    ('wire-value', api.SafaSpec(), dict(wire='int4'), 'wire'),
    ('engine-name', api.SafaSpec(), dict(engine='warp'), 'unknown engine'),
    ('use-kernel-value', api.SafaSpec(), dict(use_kernel='Packed'),
     'unknown use_kernel'),
    ('wire-protocol', api.LocalSpec(), dict(wire='int8'),
     'upload-aggregate wire'),
    ('kernel-protocol', api.LocalSpec(), dict(use_kernel='packed'),
     'fused aggregation kernel'),
    ('kernel-protocol-fedcs', api.FedCSSpec(), dict(use_kernel='packed'),
     'fused aggregation kernel'),
    ('kernel-packed-only', api.SeaflSpec(), dict(use_kernel=True),
     'pack buffers only'),
    ('staleness-fn', api.FedAsyncSpec(staleness_fn='exp'), {},
     'unknown staleness_fn'),
    ('alpha-zero', api.FedAsyncSpec(alpha=0.0), {}, 'alpha must be in'),
    ('alpha-above-one', api.SeaflSpec(alpha=1.5), {}, 'alpha must be in'),
    ('hinge-a', api.CsaflSpec(hinge_a=0.0), {}, 'hinge_a must be'),
    ('clusters', api.CsaflSpec(clusters=0), {}, 'clusters must be'),
    ('quantize-vs-wire', api.SafaSpec(quantize_uploads=True),
     dict(wire='int8'), 'one or the other'),
    ('sampler', api.FedAvgSpec(sampler='bogus'), {}, 'unknown sampler'),
    ('schedule-value', api.SafaSpec(), dict(schedule='csr'),
     'unknown schedule'),
    ('sparse-protocol', api.SeaflSpec(), dict(schedule='sparse'),
     'no sparse schedule form'),
    ('sparse-quantize', api.SafaSpec(quantize_uploads=True),
     dict(schedule='sparse'), 'dense per-leaf reference knob'),
    ('sparse-delta-kernel', api.SafaSpec(),
     dict(schedule='sparse_delta', use_kernel=True), 'no rows form'),
]


class TestCheckCompatGoldens:
    @pytest.mark.parametrize('spec,exec_kw,fragment',
                             [g[1:] for g in GOLDENS],
                             ids=[g[0] for g in GOLDENS])
    def test_rejection_message(self, spec, exec_kw, fragment):
        with pytest.raises(ValueError, match=fragment):
            api.check_compat(spec, api.ExecSpec(**exec_kw))

    def test_unregistered_spec_is_type_error(self):
        @dataclasses.dataclass(frozen=True)
        class GossipSpec(api.ProtocolSpec):
            fanout: int = 2
        with pytest.raises(TypeError, match='register'):
            api.check_compat(GossipSpec())

    def test_valid_pairs_pass(self):
        # the matrix's accepted corners return the ProtocolDef
        assert api.check_compat(api.SeaflSpec(),
                                api.ExecSpec(use_kernel='packed',
                                             wire='int8')).name == 'seafl'
        assert api.check_compat(api.CsaflSpec(clusters=5)).name == 'csafl'
        assert api.check_compat(
            api.FedAsyncSpec(staleness_fn='hinge', hinge_b=0)
        ).name == 'fedasync'


# ---------------------------------------------------------------------------
# init_fleet_global: the codified fleet-init contract
# ---------------------------------------------------------------------------

class TestInitFleetGlobal:
    def test_rows_bit_identical_to_scalar_init(self):
        """Each member's stacked row equals its own scalar
        ``task.init_global(PRNGKey(seed))`` — the contract that keeps
        fleet == sequential == single-run init exact (vmapping the
        PRNG-keyed init is NOT bit-stable; the fleet path must never do
        that)."""
        task = C.shared_task()
        seeds = [0, 1, 0]
        g = api.init_fleet_global(task, seeds)
        for s, seed in enumerate(seeds):
            ref = task.init_global(jax.random.PRNGKey(seed))
            for got, want in zip(jax.tree.leaves(g), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(np.asarray(got)[s],
                                              np.asarray(want),
                                              err_msg=f'member {s}')

    def test_duplicate_seeds_share_rows(self):
        g = api.init_fleet_global(C.shared_task(), [7, 7])
        for leaf in jax.tree.leaves(g):
            np.testing.assert_array_equal(np.asarray(leaf)[0],
                                          np.asarray(leaf)[1])
