"""Registry-wide protocol conformance: every spec in ``api.PROTOCOLS``
(plus named field variants) through the engine-identity matrix.  See
``tests/conformance.py`` for the harness; a failing test names the
offending spec in its id.

No hypothesis dependency — this module must run in a bare environment.
"""
import dataclasses

import pytest

import conformance as C
from repro import api

CASES = C.cases()
IDS = sorted(CASES)

_SCAN_REF = {}


def scan_ref(cid):
    """The scan-engine reference run (env seed 3, numeric seed 0), cached
    per case — the single source every invariant compares against."""
    if cid not in _SCAN_REF:
        _SCAN_REF[cid] = C.run_single(CASES[cid]())
    return _SCAN_REF[cid]


@pytest.mark.parametrize('cid', IDS)
def test_scan_equals_loop(cid):
    h_loop = C.run_single(CASES[cid](), engine='loop')
    C.assert_history_equal(scan_ref(cid), h_loop, f'{cid}: scan vs loop')


@pytest.mark.parametrize('cid', IDS)
def test_fleet_equals_sequential_equals_single(cid):
    spec = CASES[cid]()

    def members():
        return [C.member_for(spec, C.fresh_env(3), seed=0),
                C.member_for(spec, C.fresh_env(4), seed=1)]

    h_fleet = C.run_sweep(spec, members(), engine='fleet')
    h_seq = C.run_sweep(spec, members(), engine='sequential')
    for s in range(2):
        C.assert_history_equal(h_fleet[s], h_seq[s],
                               f'{cid}: fleet vs sequential member {s}')
    # member 0 replays the scan reference's exact configuration
    C.assert_history_equal(h_fleet[0], scan_ref(cid),
                           f'{cid}: fleet member 0 vs single run')


@pytest.mark.parametrize('cid', IDS)
def test_checkpoint_resume_bit_identity(cid, tmp_path):
    spec = CASES[cid]()
    path = str(tmp_path / 'ck')
    partial = C.run_single(spec, checkpoint=path, max_segments=1)
    assert partial.final_global is not None
    resumed = C.run_single(spec, checkpoint=path)
    C.assert_history_equal(resumed, scan_ref(cid),
                           f'{cid}: resumed vs uninterrupted')


@pytest.mark.parametrize('cid', IDS)
def test_history_dict_roundtrip(cid):
    h = scan_ref(cid)
    h2 = api.History.from_dict(h.to_dict())
    assert h2.protocol == h.protocol
    assert h2.futility == h.futility
    assert h2.best_eval == h.best_eval
    assert [dataclasses.asdict(r) for r in h2.records] == \
        [dataclasses.asdict(r) for r in h.records]
    assert h2.evals() == h.evals()


@pytest.mark.parametrize('cid', IDS)
def test_sparse_matches_dense(cid):
    spec = CASES[cid]()
    if C.pdef_of(spec).sparse_precompute is None:
        pytest.skip(f'{C.pdef_of(spec).name}: no sparse schedule form')
    h_sparse = C.run_single(spec, exec_kw={'schedule': 'sparse'})
    C.assert_history_equal(h_sparse, scan_ref(cid),
                           f'{cid}: sparse vs dense')


def _tier_or_skip(spec):
    pdef = C.pdef_of(spec)
    if pdef.tier_precompute is None:
        pytest.skip(f'{pdef.name}: no lag-tier schedule form')


@pytest.mark.parametrize('cid', IDS)
def test_tier_scan_equals_loop(cid):
    spec = CASES[cid]()
    _tier_or_skip(spec)
    ex = {'schedule': 'sparse_tier'}
    h_scan = C.run_single(spec, exec_kw=ex)
    h_loop = C.run_single(spec, engine='loop', exec_kw=ex)
    C.assert_history_equal(h_scan, h_loop, f'{cid}: tier scan vs loop')


@pytest.mark.parametrize('cid', IDS)
def test_tier_fleet_equals_sequential(cid):
    """Bitwise fleet == sequential only: tier fleet members replay the
    fleet-padded program, so a standalone single run of the same member
    is allclose, not bit-identical (different reduction widths)."""
    spec = CASES[cid]()
    _tier_or_skip(spec)

    def members():
        return [C.member_for(spec, C.fresh_env(3), seed=0),
                C.member_for(spec, C.fresh_env(4), seed=1)]

    ex = {'schedule': 'sparse_tier'}
    h_fleet = C.run_sweep(spec, members(), engine='fleet', exec_kw=ex)
    h_seq = C.run_sweep(spec, members(), engine='sequential', exec_kw=ex)
    for s in range(2):
        C.assert_history_equal(h_fleet[s], h_seq[s],
                               f'{cid}: tier fleet vs sequential member {s}')


@pytest.mark.parametrize('cid', IDS)
def test_tier_checkpoint_resume_bit_identity(cid, tmp_path):
    spec = CASES[cid]()
    _tier_or_skip(spec)
    ex = {'schedule': 'sparse_tier'}
    path = str(tmp_path / 'ck')
    partial = C.run_single(spec, checkpoint=path, max_segments=1,
                           exec_kw=ex)
    assert partial.final_global is not None
    resumed = C.run_single(spec, checkpoint=path, exec_kw=ex)
    full = C.run_single(spec, exec_kw=ex)
    C.assert_history_equal(resumed, full,
                           f'{cid}: tier resumed vs uninterrupted')


@pytest.mark.parametrize('cid', IDS)
def test_tier_wire_int8_engine_parity(cid):
    spec = CASES[cid]()
    _tier_or_skip(spec)
    ex = {'schedule': 'sparse_tier', 'wire': 'int8'}
    h_scan = C.run_single(spec, exec_kw=ex)
    h_loop = C.run_single(spec, engine='loop', exec_kw=ex)
    C.assert_history_equal(h_scan, h_loop, f'{cid}: tier int8 scan vs loop')


@pytest.mark.parametrize('cid', IDS)
def test_wire_int8_engine_parity(cid):
    spec = CASES[cid]()
    pdef = C.pdef_of(spec)
    if not pdef.supports_wire:
        with pytest.raises(ValueError, match='wire'):
            C.run_single(spec, exec_kw={'wire': 'int8'})
        return
    h_scan = C.run_single(spec, exec_kw={'wire': 'int8'})
    h_loop = C.run_single(spec, engine='loop', exec_kw={'wire': 'int8'})
    C.assert_history_equal(h_scan, h_loop, f'{cid}: int8 scan vs loop')
    if any(f.name == 'quantize_uploads' for f in dataclasses.fields(spec)):
        # the packed wire must equal the per-leaf reference bit-for-bit
        ref_spec = dataclasses.replace(spec, quantize_uploads=True)
        h_ref = C.run_single(ref_spec)
        C.assert_history_equal(h_scan, h_ref,
                               f'{cid}: int8 wire vs quantize_uploads')
