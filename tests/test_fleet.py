"""Fleet engine regression tests: batched sweeps must be a pure perf
change — bit-identical per member to the single-run scan engine, with the
fleet-major schedule precompute bit-identical to per-member precomputes.

No hypothesis dependency — this module must run in a bare environment.
"""
import itertools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federation, selection
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv, env_grid
from repro.kernels import ops as kops

BASE = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
            epochs=3, t_lim=830.0, seed=3)


def _members(s=8):
    """S heterogeneous fleet members sharing one client population."""
    envs = env_grid(BASE, crash_prob=(0.3, 0.7),
                    draw_seed=tuple(range((s + 1) // 2)))[:s]
    hyper = itertools.cycle(zip((0.5, 0.3, 1.0, 0.1), (5, 2, 10, 1)))
    return [federation.SweepMember(env=e, fraction=f, lag_tolerance=tau)
            for e, (f, tau) in zip(envs, hyper)]


@pytest.fixture(scope='module')
def reg_task():
    env = FLEnv(**BASE)
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, 5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestFleetEngine:
    def test_safa_fleet_bit_identical_to_sequential_scans(self, reg_task):
        """S=8 configs in one vmapped-scan dispatch == 8 sequential
        engine='scan' runs, bit for bit (acceptance criterion)."""
        hf = federation.run_sweep(reg_task, _members(8), rounds=12,
                                  eval_every=6, engine='fleet')
        hs = federation.run_sweep(reg_task, _members(8), rounds=12,
                                  eval_every=6, engine='sequential')
        assert len(hf) == len(hs) == 8
        for a, b in zip(hf, hs):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()
            assert a.futility == b.futility

    def test_fleet_matches_run_safa(self, reg_task):
        """The fleet member result equals the standalone single-run API."""
        hf = federation.run_sweep(reg_task, _members(8), rounds=12,
                                  eval_every=6)
        for s in (0, 5):
            mem = _members(8)[s]
            h = federation.run_safa(reg_task, mem.env, fraction=mem.fraction,
                                    lag_tolerance=mem.lag_tolerance,
                                    rounds=12, eval_every=6, engine='scan')
            _assert_tree_equal(hf[s].final_global, h.final_global)
            assert hf[s].evals() == h.evals()

    def test_fedavg_fleet_bit_identical(self, reg_task):
        kw = dict(rounds=10, eval_every=5, proto='fedavg')
        hf = federation.run_sweep(reg_task, _members(4), engine='fleet', **kw)
        hs = federation.run_sweep(reg_task, _members(4),
                                  engine='sequential', **kw)
        for a, b in zip(hf, hs):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    @pytest.mark.parametrize('proto', ['fedcs', 'local', 'fedasync'])
    def test_every_proto_fleet_bit_identical(self, reg_task, proto):
        """Acceptance criterion: run_sweep(engine='fleet') takes members of
        every protocol, bit-identical per member to sequential scans."""
        kw = dict(rounds=8, eval_every=4, proto=proto)
        hf = federation.run_sweep(reg_task, _members(4), engine='fleet', **kw)
        hs = federation.run_sweep(reg_task, _members(4),
                                  engine='sequential', **kw)
        for a, b in zip(hf, hs):
            _assert_tree_equal(a.final_global, b.final_global)
            assert a.evals() == b.evals()

    def test_local_fleet_matches_run_local(self, reg_task):
        """The fleet member result equals the standalone single-run API
        (including the vmapped eval-point aggregation)."""
        hf = federation.run_sweep(reg_task, _members(4), rounds=8,
                                  eval_every=4, proto='local')
        mem = _members(4)[2]
        h = federation.run_local(reg_task, mem.env, fraction=mem.fraction,
                                 rounds=8, eval_every=4, engine='scan')
        _assert_tree_equal(hf[2].final_global, h.final_global)
        assert hf[2].evals() == h.evals()

    def test_fedasync_fleet_matches_run_fedasync(self, reg_task):
        hf = federation.run_sweep(reg_task, _members(4), rounds=8,
                                  eval_every=4, proto='fedasync')
        mem = _members(4)[1]
        h = federation.run_fedasync(reg_task, mem.env, rounds=8,
                                    eval_every=4, engine='scan')
        _assert_tree_equal(hf[1].final_global, h.final_global)
        assert hf[1].evals() == h.evals()

    @pytest.mark.parametrize('proto', ['fedavg', 'fedcs', 'local',
                                       'fedasync'])
    def test_timing_only_sweep_matches_single_runs_every_proto(self, proto):
        hists = federation.run_sweep(None, _members(4), rounds=12,
                                     proto=proto, numeric=False)
        fn = federation.RUNNERS[proto]
        for mem, h in zip(_members(4), hists):
            single = fn(None, mem.env, fraction=mem.fraction, rounds=12,
                        numeric=False, seed=mem.seed)
            assert [r.round_len for r in h.records] == \
                [r.round_len for r in single.records]
            assert h.futility == single.futility

    def test_fleet_packed_kernel_matches_reference(self, reg_task):
        """use_kernel='packed' under the fleet vmap (batched-grid pallas
        dispatch) stays numerically on the reference trajectory."""
        hk = federation.run_sweep(reg_task, _members(4), rounds=6,
                                  eval_every=6, use_kernel='packed')
        hr = federation.run_sweep(reg_task, _members(4), rounds=6,
                                  eval_every=6)
        for a, b in zip(hk, hr):
            for la, lb in zip(jax.tree.leaves(a.final_global),
                              jax.tree.leaves(b.final_global)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           atol=1e-5)

    def test_cnn_fleet_tracks_sequential(self):
        """dot_general-based tasks (CNN) are not covered by the bitwise
        contract (batch-size-dependent lowering), but the fleet engine
        must stay numerically on the sequential trajectory."""
        from repro.data import make_images
        from repro.data.tasks import cnn_task
        base = dict(m=4, crash_prob=0.3, dataset_size=64, batch_size=8,
                    epochs=1, t_lim=830.0, seed=3)
        envs = env_grid(base, draw_seed=(0, 1))
        x, y = make_images(n=64, seed=0)
        data = partition(x, y, envs[0].partition_sizes, 8, seed=0)
        task = cnn_task(data, lr=1e-3, epochs=1)
        members = lambda: [federation.SweepMember(env=e, fraction=0.5)
                           for e in env_grid(base, draw_seed=(0, 1))]
        hf = federation.run_sweep(task, members(), rounds=3, eval_every=3)
        hs = federation.run_sweep(task, members(), rounds=3, eval_every=3,
                                  engine='sequential')
        for a, b in zip(hf, hs):
            for la, lb in zip(jax.tree.leaves(a.final_global),
                              jax.tree.leaves(b.final_global)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=1e-5, atol=1e-6)

    def test_timing_only_sweep_matches_single_runs(self):
        hists = federation.run_sweep(None, _members(4), rounds=15,
                                     numeric=False)
        for mem, h in zip(_members(4), hists):
            single = federation.run_safa(None, mem.env, fraction=mem.fraction,
                                         lag_tolerance=mem.lag_tolerance,
                                         rounds=15, numeric=False)
            assert [r.round_len for r in h.records] == \
                [r.round_len for r in single.records]
            assert h.futility == single.futility

    def test_sweep_validation(self, reg_task):
        with pytest.raises(ValueError, match='proto'):
            federation.run_sweep(reg_task, _members(2), rounds=2,
                                 proto='gossip')
        with pytest.raises(ValueError, match='engine'):
            federation.run_sweep(reg_task, _members(2), rounds=2,
                                 engine='warp')
        with pytest.raises(ValueError, match='empty'):
            federation.run_sweep(reg_task, [], rounds=2)
        bad = _members(2)
        bad[1] = federation.SweepMember(
            env=FLEnv(**{**BASE, 'm': 7, 'dataset_size': 700}))
        with pytest.raises(ValueError, match='client count'):
            federation.run_sweep(reg_task, bad, rounds=2)

    def test_sharded_fleet_bit_identical(self):
        """With the fleet axis sharded over 2 forced host devices the
        per-member bits must not change (subprocess: device count is fixed
        at jax import)."""
        code = (
            "import itertools, jax, numpy as np\n"
            "from repro.core import federation\n"
            "from repro.data import make_regression, partition\n"
            "from repro.data.tasks import regression_task\n"
            "from repro.fedsim import FLEnv, env_grid\n"
            f"BASE = dict({', '.join(f'{k}={v!r}' for k, v in BASE.items())})\n"
            "assert len(jax.devices()) == 2, jax.devices()\n"
            "env = FLEnv(**BASE)\n"
            "x, y = make_regression()\n"
            "data = partition(x, y, env.partition_sizes, 5, seed=1)\n"
            "task = regression_task(data, lr=1e-3, epochs=3)\n"
            "def members():\n"
            "    envs = env_grid(BASE, crash_prob=(0.3, 0.7),\n"
            "                    draw_seed=(0, 1))\n"
            "    return [federation.SweepMember(env=e, fraction=f,\n"
            "                                   lag_tolerance=t)\n"
            "            for e, f, t in zip(envs, (0.5, 0.3, 1.0, 0.1),\n"
            "                               (5, 2, 10, 1))]\n"
            "hf = federation.run_sweep(task, members(), rounds=6,\n"
            "                          eval_every=6, engine='fleet')\n"
            "hs = federation.run_sweep(task, members(), rounds=6,\n"
            "                          eval_every=6, engine='sequential')\n"
            "for a, b in zip(hf, hs):\n"
            "    for la, lb in zip(jax.tree.leaves(a.final_global),\n"
            "                      jax.tree.leaves(b.final_global)):\n"
            "        np.testing.assert_array_equal(np.asarray(la),\n"
            "                                      np.asarray(lb))\n"
            "print('SHARDED_OK')\n")
        env = dict(os.environ)
        env['XLA_FLAGS'] = (env.get('XLA_FLAGS', '')
                            + ' --xla_force_host_platform_device_count=2')
        env['JAX_PLATFORMS'] = 'cpu'
        out = subprocess.run([sys.executable, '-c', code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert 'SHARDED_OK' in out.stdout


class TestFleetSchedule:
    def test_fleet_precompute_bit_identical_to_singles(self):
        """The vectorised [S, m] host pass == S independent
        precompute_safa_schedule calls: masks, records and futility."""
        fleet = federation.precompute_fleet_schedule(_members(8), rounds=20)
        singles = [federation.precompute_safa_schedule(
            mem.env, fraction=mem.fraction, lag_tolerance=mem.lag_tolerance,
            rounds=20) for mem in _members(8)]
        stacked = federation.FleetSchedule.stack(singles)
        for k in federation.FleetSchedule.MASKS:
            np.testing.assert_array_equal(getattr(fleet, k),
                                          getattr(stacked, k))
        np.testing.assert_array_equal(fleet.futility, stacked.futility)
        assert fleet.records == stacked.records

    def test_fleet_precompute_large_m(self):
        """Same identity on a paper-scale population (m=100), where masked
        and compressed reductions could diverge if formulated sloppily."""
        base = dict(m=100, crash_prob=0.5, dataset_size=70000, batch_size=40,
                    epochs=5, t_lim=5600.0, seed=2)
        members = [federation.SweepMember(env=e, fraction=f, lag_tolerance=t)
                   for e, f, t in zip(env_grid(base, draw_seed=(0, 1, 2)),
                                      (0.3, 0.7, 0.5), (5, 1, 10))]
        rebuild = [federation.SweepMember(env=e, fraction=f, lag_tolerance=t)
                   for e, f, t in zip(env_grid(base, draw_seed=(0, 1, 2)),
                                      (0.3, 0.7, 0.5), (5, 1, 10))]
        fleet = federation.precompute_fleet_schedule(members, rounds=12)
        singles = [federation.precompute_safa_schedule(
            mem.env, fraction=mem.fraction, lag_tolerance=mem.lag_tolerance,
            rounds=12) for mem in rebuild]
        for s, single in enumerate(singles):
            got = fleet.member(s)
            for k in federation.FleetSchedule.MASKS:
                np.testing.assert_array_equal(getattr(got, k),
                                              getattr(single, k))
            assert got.records == single.records
            assert got.futility == single.futility

    @pytest.mark.parametrize('fedcs', [False, True])
    def test_sync_fleet_precompute_bit_identical_to_singles(self, fedcs):
        """The [S, rounds, m] sync host pass (no per-member Python loop)
        == S independent precompute_sync_schedule calls: masks, records
        and futility — for both the FedCS rank-comparison selection and
        the rng-stream FedAvg selection."""
        members = _members(8)
        for s, mem in enumerate(members):   # vary the selection seeds too
            mem.seed = s % 3
        fleet = federation.precompute_sync_fleet_schedule(members, rounds=20,
                                                          fedcs=fedcs)
        singles = []
        rebuild = _members(8)
        for s, mem in enumerate(rebuild):
            mem.seed = s % 3
            singles.append(federation.precompute_sync_schedule(
                mem.env, fraction=mem.fraction, rounds=20, seed=mem.seed,
                fedcs=fedcs))
        stacked = federation.SyncFleetSchedule.stack(singles)
        for k in federation.SyncFleetSchedule.MASKS:
            np.testing.assert_array_equal(getattr(fleet, k),
                                          getattr(stacked, k))
        np.testing.assert_array_equal(fleet.futility, stacked.futility)
        assert fleet.records == stacked.records

    def test_sync_fleet_precompute_large_m(self):
        """Same identity at paper scale (m=100) where the deadline culls
        slow clients, covering the too-slow-reckoned-crashed branch."""
        base = dict(m=100, crash_prob=0.5, dataset_size=70000, batch_size=40,
                    epochs=5, t_lim=5600.0, seed=2)
        def members():
            return [federation.SweepMember(env=e, fraction=f, seed=sd)
                    for e, f, sd in zip(env_grid(base, draw_seed=(0, 1, 2)),
                                        (0.3, 0.7, 1.0), (0, 1, 2))]
        for fedcs in (False, True):
            fleet = federation.precompute_sync_fleet_schedule(
                members(), rounds=12, fedcs=fedcs)
            singles = [federation.precompute_sync_schedule(
                mem.env, fraction=mem.fraction, rounds=12, seed=mem.seed,
                fedcs=fedcs) for mem in members()]
            for s, single in enumerate(singles):
                got = fleet.member(s)
                for k in federation.SyncFleetSchedule.MASKS:
                    np.testing.assert_array_equal(getattr(got, k),
                                                  getattr(single, k))
                assert got.records == single.records
                assert got.futility == single.futility

    def test_shapes_and_round_idx(self):
        fleet = federation.precompute_fleet_schedule(_members(4), rounds=7)
        assert fleet.size == 4 and fleet.rounds == 7
        dev = fleet.to_device()
        for mask in (dev.sync, dev.completed, dev.picked, dev.undrafted,
                     dev.deprecated):
            assert mask.shape == (4, 7, 5)
        assert dev.round_idx.shape == (4, 7)
        np.testing.assert_array_equal(np.asarray(dev.round_idx[2]),
                                      np.arange(1, 8))

    def test_rng_streams_independent_per_member(self):
        """Each member consumes only its own env rng: permuting the other
        members does not change a member's schedule."""
        a = federation.precompute_fleet_schedule(_members(4), rounds=10)
        perm = list(reversed(_members(4)))
        b = federation.precompute_fleet_schedule(perm, rounds=10)
        for s in range(4):
            np.testing.assert_array_equal(a.picked[s], b.picked[3 - s])

    def test_stack_rejects_mismatched_shapes(self):
        s1 = federation.precompute_safa_schedule(FLEnv(**BASE), fraction=0.5,
                                                 lag_tolerance=5, rounds=5)
        s2 = federation.precompute_safa_schedule(FLEnv(**BASE), fraction=0.5,
                                                 lag_tolerance=5, rounds=6)
        with pytest.raises(ValueError, match='rounds'):
            federation.FleetSchedule.stack([s1, s2])


class TestCfcfmInvariants:
    def _draw(self, rng, m):
        arrival = rng.exponential(100.0, m) + 10.0
        completed = rng.random(m) < 0.7
        arrival = np.where(completed, arrival, np.inf)
        picked_prev = rng.random(m) < 0.4
        fraction = rng.choice([0.1, 0.3, 0.5, 0.9, 1.0])
        deadline = rng.choice([120.0, 200.0, 1e9])
        return arrival, completed, picked_prev, fraction, deadline

    def test_invariants_randomized(self):
        rng = np.random.default_rng(0)
        for m in (1, 3, 5, 17, 64):
            for _ in range(40):
                arrival, completed, prev, frac, deadline = self._draw(rng, m)
                sel = selection.cfcfm(arrival, completed, prev, frac,
                                      deadline)
                quota = max(1, int(round(frac * m)))
                committed = completed & (arrival <= deadline)
                # picked is a subset of committed arrivals
                assert not np.any(sel.picked & ~sel.committed)
                np.testing.assert_array_equal(sel.committed, committed)
                # quota respected, and met whenever enough clients arrived
                assert sel.picked.sum() == min(quota, committed.sum())
                # undrafted = committed leftovers
                np.testing.assert_array_equal(sel.undrafted,
                                              committed & ~sel.picked)
                # compensatory priority: a previously-picked client may only
                # be picked once every not-previously-picked arrival is
                assert not (np.any(sel.picked & prev)
                            and np.any(committed & ~prev & ~sel.picked))
                assert sel.quota_met_time <= deadline

    def test_batch_matches_scalar(self):
        """cfcfm_batch rows == independent cfcfm calls (the fleet schedule
        precompute is built on this)."""
        rng = np.random.default_rng(1)
        for m in (2, 5, 33):
            rows = [self._draw(rng, m) for _ in range(16)]
            batch = selection.cfcfm_batch(
                np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]),
                np.stack([r[2] for r in rows]),
                np.array([r[3] for r in rows]),
                np.array([r[4] for r in rows]))
            for s, (arrival, completed, prev, frac, deadline) in \
                    enumerate(rows):
                ref = selection.cfcfm(arrival, completed, prev, frac,
                                      deadline)
                np.testing.assert_array_equal(batch.picked[s], ref.picked)
                np.testing.assert_array_equal(batch.undrafted[s],
                                              ref.undrafted)
                np.testing.assert_array_equal(batch.committed[s],
                                              ref.committed)
                assert batch.quota_met_time[s] == ref.quota_met_time


class TestFleetKernel:
    SHAPES = ((4, 3), (64,), (8, 33))

    def _operands(self, s=3, m=6):
        def tr(key, lead):
            ks = jax.random.split(key, len(self.SHAPES))
            return {f'p{i}': jax.random.normal(k, lead + shp)
                    for i, (k, shp) in enumerate(zip(ks, self.SHAPES))}
        rng = np.random.default_rng(0)
        picked = jnp.asarray(rng.random((s, m)) < 0.4)
        masks = dict(
            picked=picked,
            undrafted=jnp.asarray(rng.random((s, m)) < 0.3) & ~picked,
            deprecated=jnp.asarray(rng.random((s, m)) < 0.3),
            weights=jnp.asarray(rng.dirichlet(np.ones(m), size=s),
                                jnp.float32))
        return (tr(jax.random.PRNGKey(0), (s, m)),
                tr(jax.random.PRNGKey(1), (s, m)),
                tr(jax.random.PRNGKey(2), (s,)), masks)

    def test_fleet_grid_matches_per_member_packed(self):
        cache, trained, g, masks = self._operands()
        out = kops.safa_aggregate_tree_packed_fleet(cache, trained, g,
                                                    **masks)
        for s in range(3):
            ref = kops.safa_aggregate_tree_packed(
                jax.tree.map(lambda a, i=s: a[i], cache),
                jax.tree.map(lambda a, i=s: a[i], trained),
                jax.tree.map(lambda a, i=s: a[i], g),
                **{k: v[s] for k, v in masks.items()})
            for k in cache:
                np.testing.assert_allclose(
                    np.asarray(out.new_global[k][s]),
                    np.asarray(ref.new_global[k]), atol=1e-6)
                np.testing.assert_allclose(
                    np.asarray(out.new_cache[k][s]),
                    np.asarray(ref.new_cache[k]), atol=1e-6)

    def test_fleet_grid_single_dispatch(self):
        cache, trained, g, masks = self._operands()
        jaxpr = jax.make_jaxpr(
            lambda c, t, gg: kops.safa_aggregate_tree_packed_fleet(
                c, t, gg, **masks))(cache, trained, g)
        assert kops.count_pallas_calls(jaxpr.jaxpr) == 1

    def test_fleet_pack_roundtrip(self):
        cache, _, g, _ = self._operands()
        spec = kops.pack_spec(jax.tree.map(lambda a: a[0], g))
        back = kops.unpack_fleet(kops.pack_fleet(cache, spec), spec)
        for k in cache:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(cache[k]))

    def test_fleet_packed_rejects_non_f32(self):
        cache, trained, g, masks = self._operands()
        to16 = lambda t: jax.tree.map(lambda a: a.astype(jnp.bfloat16), t)
        with pytest.raises(TypeError, match='float32'):
            kops.safa_aggregate_tree_packed_fleet(to16(cache), to16(trained),
                                                  to16(g), **masks)


class TestEnvGrid:
    def test_grid_order_and_size(self):
        envs = env_grid(BASE, crash_prob=(0.1, 0.9), draw_seed=(0, 1, 2))
        assert len(envs) == 6
        # row-major: last axis fastest
        assert [e.crash_prob for e in envs] == [0.1] * 3 + [0.9] * 3
        assert [e.draw_seed for e in envs] == [0, 1, 2] * 2

    def test_draw_seed_shares_population(self):
        a, b = env_grid(BASE, draw_seed=(0, 1))
        np.testing.assert_array_equal(a.partition_sizes, b.partition_sizes)
        np.testing.assert_array_equal(a.perf, b.perf)
        ca, _ = a.draw_round()
        cb, _ = b.draw_round()
        assert not np.array_equal(ca, cb)  # independent crash streams

    def test_default_draw_stream_unchanged(self):
        """draw_seed=None keeps the seed's single-stream behaviour."""
        e1 = FLEnv(**BASE)
        e2 = FLEnv(**BASE, draw_seed=None)
        for _ in range(3):
            c1, f1 = e1.draw_round()
            c2, f2 = e2.draw_round()
            np.testing.assert_array_equal(c1, c2)
            np.testing.assert_array_equal(f1, f2)
