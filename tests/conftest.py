# NOTE: do NOT set --xla_force_host_platform_device_count here.  Smoke tests
# and benches must see the real 1-device CPU platform; only the dry-run
# entrypoint (repro.launch.dryrun) creates 512 placeholder devices.
import jax

jax.config.update('jax_enable_x64', False)
