# NOTE: do NOT set --xla_force_host_platform_device_count here.  Smoke tests
# and benches must see the real 1-device CPU platform; only the dry-run
# entrypoint (repro.launch.dryrun) creates 512 placeholder devices.
import importlib.util

import jax

jax.config.update('jax_enable_x64', False)

# Property-based test modules need hypothesis (declared in pyproject's
# [test] extra; CI installs it).  In a bare environment skip collecting
# them instead of erroring out the whole run.
if importlib.util.find_spec('hypothesis') is None:
    collect_ignore = ['test_env_trace_properties.py', 'test_kernels.py',
                      'test_protocol.py', 'test_schedule_properties.py',
                      'test_ssm.py']
