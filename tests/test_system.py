"""End-to-end system tests: federated LLM training, serving, numeric
SAFA-vs-FedAvg equivalence under degenerate settings, silo-mode lowering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.core import protocol
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv
from repro.launch import mesh as mesh_lib
from repro.launch.steps import ServeSetup, SiloSetup
from repro.launch.train import run as train_run
from repro.models.model import build_model


class TestFederatedLLMTraining:
    def test_loss_decreases(self):
        hist = train_run('qwen3-1.7b', rounds=12, n_clients=4, fraction=0.5,
                         lag_tolerance=3, crash_prob=0.2, batch=2, seq=32,
                         local_steps=2, lr=0.1, seed=0)
        assert hist[-1] < hist[0] - 0.1

    def test_ssm_arch_trains(self):
        hist = train_run('mamba2-130m', rounds=6, n_clients=2, fraction=0.5,
                         lag_tolerance=3, crash_prob=0.0, batch=2, seq=32,
                         local_steps=2, lr=0.1, seed=0)
        assert np.isfinite(hist[-1])
        assert hist[-1] < hist[0]


class TestSiloStepSemantics:
    def test_safa_degenerates_to_fedavg(self):
        """C=1, no crashes, equal weights: the SAFA silo round equals the
        FedAvg silo round exactly (cache == trained for all clients)."""
        cfg = get_config('qwen3-1.7b').reduced()
        model = build_model(cfg)
        C = 3
        setup = SiloSetup(model, n_clients=C, local_steps=1,
                          learning_rate=0.05)
        key = jax.random.PRNGKey(0)
        g = model.init(key)
        state = {'global': g,
                 'local': protocol.broadcast_global(g, C),
                 'cache': protocol.broadcast_global(g, C)}
        tok = jax.random.randint(key, (C, 2, 16), 0, cfg.vocab_size)
        ones = jnp.ones(C, bool)
        batch = {'tokens': tok, 'labels': tok,
                 'meta': {'sync': ones, 'picked': ones,
                          'undrafted': jnp.zeros(C, bool),
                          'deprecated': jnp.zeros(C, bool),
                          'completed': ones,
                          'weights': jnp.full((C,), 1 / C)}}
        s1, _ = jax.jit(setup.train_step)(
            jax.tree.map(jnp.copy, state), batch)
        s2, _ = jax.jit(setup.fedavg_train_step)(
            jax.tree.map(jnp.copy, state), batch)
        for a, b in zip(jax.tree.leaves(s1['global']),
                        jax.tree.leaves(s2['global'])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)

    def test_silo_round_matches_simulation_protocol(self):
        """The jit silo step reproduces core.protocol.safa_round leaf-wise."""
        cfg = get_config('mamba2-130m').reduced()
        model = build_model(cfg)
        C = 4
        setup = SiloSetup(model, n_clients=C, local_steps=1,
                          learning_rate=0.05)
        key = jax.random.PRNGKey(1)
        g = model.init(key)
        state = {'global': g,
                 'local': protocol.broadcast_global(g, C),
                 'cache': protocol.broadcast_global(g, C)}
        tok = jax.random.randint(key, (C, 2, 16), 0, cfg.vocab_size)
        meta = {'sync': jnp.array([1, 1, 0, 1], bool),
                'picked': jnp.array([1, 0, 0, 1], bool),
                'undrafted': jnp.array([0, 1, 0, 0], bool),
                'deprecated': jnp.array([0, 0, 1, 0], bool),
                'completed': jnp.array([1, 1, 0, 1], bool),
                'weights': jnp.asarray([0.3, 0.3, 0.2, 0.2], jnp.float32)}
        batch = {'tokens': tok, 'labels': tok, 'meta': meta}
        s1, _ = jax.jit(setup.train_step)(jax.tree.map(jnp.copy, state), batch)

        def train_fn(base):
            def one(params, cb):
                loss, grad = jax.value_and_grad(model.loss)(params, cb)
                return jax.tree.map(
                    lambda w, gw: (w - 0.05 * gw.astype(jnp.float32)
                                   ).astype(w.dtype), params, grad)
            return jax.vmap(one)(base, {'tokens': tok, 'labels': tok})

        g2, l2, c2 = protocol.safa_round(
            state['global'], state['local'], state['cache'],
            sync_mask=meta['sync'], completed=meta['completed'],
            picked=meta['picked'], undrafted=meta['undrafted'],
            deprecated=meta['deprecated'], weights=meta['weights'],
            local_train_fn=train_fn)
        for a, b in zip(jax.tree.leaves(s1['global']), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        for a, b in zip(jax.tree.leaves(s1['cache']), jax.tree.leaves(c2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestLocalMeshLowering:
    """Sharded lowering works on the CPU mesh (production-mesh lowering is
    exercised by repro.launch.dryrun; see EXPERIMENTS.md §Dry-run)."""

    def test_silo_train_step_compiles_sharded(self):
        cfg = get_config('qwen3-1.7b').reduced()
        model = build_model(cfg)
        mesh = mesh_lib.make_local_mesh()
        setup = SiloSetup(model, n_clients=2)
        shape = INPUT_SHAPES['train_4k']
        shape = dataclasses.replace(shape, seq_len=32, global_batch=4)
        state_sh, batch_sh = setup.shardings(mesh, shape)
        with mesh:
            lowered = jax.jit(setup.train_step,
                              in_shardings=(state_sh, batch_sh)).lower(
                setup.state_sds(), setup.client_batch(shape))
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None

    def test_serve_decode_compiles_sharded(self):
        cfg = get_config('h2o-danube-3-4b').reduced()
        model = build_model(cfg)
        mesh = mesh_lib.make_local_mesh()
        setup = ServeSetup(model)
        shape = dataclasses.replace(INPUT_SHAPES['decode_32k'], seq_len=64,
                                    global_batch=2)
        cache_sds, tok_sds = setup.decode_batch(shape)
        cache_sh, tok_sh = setup.decode_shardings(mesh, shape)
        p_sh = setup.param_shardings(mesh)
        with mesh:
            compiled = jax.jit(setup.serve_step,
                               in_shardings=(p_sh, cache_sh, tok_sh)).lower(
                model.param_shapes(), cache_sds, tok_sds).compile()
        assert compiled.memory_analysis() is not None


class TestQuantizedCommunication:
    def test_quantized_round_close_to_exact(self):
        """int8 upload compression changes client updates only slightly and
        cuts wire bytes ~3.9x."""
        from repro.kernels import ops as kops
        env = FLEnv(m=5, crash_prob=0.0, dataset_size=506, batch_size=5,
                    epochs=3, t_lim=830.0, seed=3)
        x, y = make_regression()
        data = partition(x, y, env.partition_sizes, 5, seed=1)
        task = regression_task(data, lr=1e-3, epochs=3)
        g = task.init_global(jax.random.PRNGKey(0))
        stacked = protocol.broadcast_global(g, 5)
        trained = task.local_train(stacked, 1)
        qt = kops.quantize_tree(trained)
        deq = kops.dequantize_tree(qt, trained)
        for a, b in zip(jax.tree.leaves(trained), jax.tree.leaves(deq)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=0.05)
        # compression ratio on a realistically-sized tree (~1M params)
        big = {'w': jnp.zeros((1024, 1024), jnp.float32)}
        raw = kops.comm_bytes(big, quantized=False)
        q = kops.comm_bytes(big, quantized=True)
        assert raw / q > 3.5
