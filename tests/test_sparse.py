"""Sparse active-set schedules: the sparse==dense contracts.

Four layers under test:

* event streams — ``form='sparse'`` precomputes equal the dense
  precompute's ``.to_sparse()`` exactly; ``to_dense`` round-trips every
  mask (round 1's population-wide bootstrap sync is elided by design);
* engines — ``schedule='sparse'`` is *bit-identical* to dense across
  {safa, fedavg, fedcs} x {scan, loop} x {f32, int8} x {single, fleet};
  ``schedule='sparse_delta'`` (running-aggregate / stateless forms,
  including the packed kernels) is allclose;
* kernels — gather/scatter rows and the fused rows-aggregate kernels
  against numpy oracles, including sentinel-slot semantics;
* memory — quota-bounded schedules and stateless carries at m=10_000.

The environments here must be NON-degenerate (clients actually commit):
a too-small ``t_lim`` silences every mask and turns the identity
assertions vacuous.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, federation, protocol, selection
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv
from repro.kernels import ops

M = 24
BASE = dict(m=M, crash_prob=0.3, dataset_size=480, batch_size=10,
            epochs=1, t_lim=200.0, seed=3)


def _env(**kw):
    base = dict(BASE)
    base.update(kw)
    return FLEnv(**base)


@pytest.fixture(scope='module')
def reg_task():
    x, y = make_regression()
    data = partition(x, y, _env().partition_sizes, 5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _run(task, proto, proto_kw, exec_kw, rounds=8):
    return api.Experiment(task, _env(), api.spec(proto, **proto_kw),
                          api.ExecSpec(**exec_kw), rounds=rounds,
                          seed=0).compile().run()


def _trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _trees_close(a, b, **kw):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **kw)


# ---------------------------------------------------------------------------
# Event-stream equality
# ---------------------------------------------------------------------------

class TestEventStreams:
    def test_safa_sparse_form_equals_dense_to_sparse(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10)
        s = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10, form='sparse')
        t = d.to_sparse()
        np.testing.assert_array_equal(s.idx, t.idx)
        np.testing.assert_array_equal(s.roles, t.roles)
        assert s.records[-1].round_len == d.records[-1].round_len
        assert s.futility == d.futility

    def test_sync_sparse_form_equals_dense_to_sparse(self):
        for fedcs in (False, True):
            d = federation.precompute_sync_schedule(
                _env(), fraction=0.3, rounds=10, seed=0, fedcs=fedcs)
            s = federation.precompute_sync_schedule(
                _env(), fraction=0.3, rounds=10, seed=0, fedcs=fedcs,
                form='sparse')
            t = d.to_sparse()
            np.testing.assert_array_equal(s.idx, t.idx)
            np.testing.assert_array_equal(s.roles, t.roles)

    def test_safa_to_dense_roundtrip(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10)
        r = d.to_sparse().to_dense()
        # round 1's bootstrap sync (everyone holds w(0)) is elided: the
        # reconstruction recovers the active clients only
        np.testing.assert_array_equal(r.sync[1:], d.sync[1:])
        assert not r.sync[0][~(d.committed[0] | d.picked[0]
                               | d.undrafted[0] | d.deprecated[0])].any()
        for f in ('committed', 'picked', 'undrafted', 'deprecated'):
            np.testing.assert_array_equal(getattr(r, f), getattr(d, f))

    def test_bootstrap_round_has_no_sync_only_rows(self):
        s = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=5, rounds=6, form='sparse')
        r0 = s.roles[0][s.idx[0] < M]
        assert not np.any(r0 == protocol.ROLE_SYNC)

    def test_explicit_capacity_too_small_raises(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.5, lag_tolerance=2, rounds=6)
        with pytest.raises(ValueError, match='capacity'):
            d.to_sparse(capacity=1)

    def test_safa_tier_form_equals_dense_to_tier(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10)
        s = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10,
            form='sparse_tier')
        t = d.to_tier()
        for f in ('idx', 'roles', 'base_src', 'cache_src', 'cache_dst',
                  'global_dst'):
            np.testing.assert_array_equal(getattr(s, f), getattr(t, f))
        assert s.capacity == t.capacity
        # the event stream is the sparse one; only the slot maps are new
        sp = d.to_sparse()
        np.testing.assert_array_equal(s.idx, sp.idx)
        np.testing.assert_array_equal(s.roles, sp.roles)

    def test_tier_to_dense_roundtrip(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.3, lag_tolerance=2, rounds=10)
        r = d.to_tier().to_dense()
        np.testing.assert_array_equal(r.sync[1:], d.sync[1:])
        for f in ('committed', 'picked', 'undrafted', 'deprecated'):
            np.testing.assert_array_equal(getattr(r, f), getattr(d, f))

    def test_tier_slot_maps_stay_in_buffer(self):
        s = federation.precompute_safa_schedule(
            _env(), fraction=0.5, lag_tolerance=5, rounds=12,
            form='sparse_tier')
        scr = s.scratch
        for f in ('base_src', 'cache_src', 'cache_dst'):
            a = getattr(s, f)
            assert a.min() >= 0 and a.max() <= scr
        assert s.global_dst.min() >= 0 and s.global_dst.max() <= scr
        # within a round the written slots are distinct and disjoint from
        # the read slots (what lets the fused kernel alias the buffer)
        for t in range(s.rounds):
            srcs = set(s.base_src[t]) | set(s.cache_src[t])
            dsts = [d for d in s.cache_dst[t] if d != scr]
            if s.global_dst[t] != scr:
                dsts.append(int(s.global_dst[t]))
            assert len(dsts) == len(set(dsts))
            assert not (set(dsts) & (srcs - {scr}))

    def test_tier_explicit_capacity_too_small_raises(self):
        d = federation.precompute_safa_schedule(
            _env(), fraction=0.5, lag_tolerance=2, rounds=6)
        with pytest.raises(ValueError, match='capacity'):
            d.to_tier(capacity=1)


# ---------------------------------------------------------------------------
# Engine bit-identity: sparse == dense
# ---------------------------------------------------------------------------

class TestSparseBitIdentity:
    CASES = [
        ('safa', dict(fraction=0.3, lag_tolerance=2), 'scan', 'f32'),
        ('safa', dict(fraction=0.3, lag_tolerance=2), 'loop', 'f32'),
        ('safa', dict(fraction=0.3, lag_tolerance=30), 'scan', 'int8'),
        ('fedavg', dict(fraction=0.3), 'scan', 'f32'),
        ('fedavg', dict(fraction=0.3, sampler='topk'), 'loop', 'f32'),
        ('fedavg', dict(fraction=0.3), 'scan', 'int8'),
        ('fedcs', dict(fraction=0.3), 'scan', 'f32'),
    ]

    @pytest.mark.parametrize('proto,kw,engine,wire', CASES)
    def test_single(self, reg_task, proto, kw, engine, wire):
        ex = dict(engine=engine, wire=wire, eval_every=4)
        hd = _run(reg_task, proto, kw, dict(ex, schedule='dense'))
        hs = _run(reg_task, proto, kw, dict(ex, schedule='sparse'))
        _trees_equal(hd.final_global, hs.final_global)
        assert hd.best_eval == hs.best_eval

    @pytest.mark.parametrize('proto,kw', [
        ('safa', dict(lag_tolerance=2)), ('fedavg', {})])
    def test_fleet(self, reg_task, proto, kw):
        def members():
            return [federation.SweepMember(env=_env(), fraction=f, **kw)
                    for f in (0.3, 0.5)]
        def sweep(schedule):
            exp = api.Experiment(
                reg_task, _env(), api.spec(proto, fraction=0.3, **kw),
                api.ExecSpec(engine='fleet', schedule=schedule,
                             eval_every=4), rounds=8, seed=0)
            return exp.compile().run_sweep(members())
        hd, hs = sweep('dense'), sweep('sparse')
        for a, b in zip(hd, hs):
            _trees_equal(a.final_global, b.final_global)
            assert a.best_eval == b.best_eval

    def test_sequential_sweep(self, reg_task):
        def members():
            return [federation.SweepMember(env=_env(), fraction=0.3,
                                           lag_tolerance=2)]
        def sweep(schedule):
            exp = api.Experiment(
                reg_task, _env(), api.spec('safa', fraction=0.3),
                api.ExecSpec(engine='sequential', schedule=schedule,
                             eval_every=4), rounds=8, seed=0)
            return exp.compile().run_sweep(members())
        hd, hs = sweep('dense'), sweep('sparse')
        _trees_equal(hd[0].final_global, hs[0].final_global)


# ---------------------------------------------------------------------------
# sparse_delta: allclose to dense (running-aggregate / stateless forms)
# ---------------------------------------------------------------------------

class TestSparseDelta:
    TOL = dict(rtol=2e-5, atol=2e-6)

    @pytest.mark.parametrize('proto,kw,engine', [
        ('safa', dict(fraction=0.3, lag_tolerance=2), 'scan'),
        ('safa', dict(fraction=0.3, lag_tolerance=2), 'loop'),
        ('fedavg', dict(fraction=0.3), 'scan'),
        ('fedcs', dict(fraction=0.3), 'scan'),
    ])
    def test_tree_engines(self, reg_task, proto, kw, engine):
        ex = dict(engine=engine, eval_every=4)
        hd = _run(reg_task, proto, kw, dict(ex, schedule='dense'))
        hs = _run(reg_task, proto, kw, dict(ex, schedule='sparse_delta'))
        _trees_close(hd.final_global, hs.final_global, **self.TOL)

    @pytest.mark.parametrize('wire', ['f32', 'int8'])
    def test_safa_packed(self, reg_task, wire):
        kw = dict(fraction=0.3, lag_tolerance=2)
        hd = _run(reg_task, 'safa', kw,
                  dict(engine='scan', wire=wire, eval_every=4,
                       schedule='dense'))
        hp = _run(reg_task, 'safa', kw,
                  dict(engine='scan', wire=wire, eval_every=4,
                       schedule='sparse_delta', use_kernel='packed'))
        tol = dict(rtol=2e-2, atol=2e-2) if wire == 'int8' else self.TOL
        _trees_close(hd.final_global, hp.final_global, **tol)

    def test_fedavg_stateless_carry(self, reg_task):
        """The stateless sparse_delta carry never materialises the
        [m, ...] local stack."""
        exp = api.Experiment(reg_task, _env(), api.spec('fedavg', fraction=0.3),
                             api.ExecSpec(schedule='sparse_delta'),
                             rounds=4, seed=0)
        r = exp.compile()
        from repro.core.api import _init_state
        st = _init_state(exp.task, M, 0, r._pdef.uses_cache,
                         r._stateless(exp.exec))
        assert st.local_w is None and st.cache is None
        h = r.run()
        assert np.isfinite(h.best_eval['loss'])


# ---------------------------------------------------------------------------
# sparse_tier: lag-tier compressed value buffer
# ---------------------------------------------------------------------------

class TestSparseTier:
    """``schedule='sparse_tier'``: the [m, N] stacks collapse to one
    [capacity+1, N] value buffer.  Allclose to dense (and to
    sparse_delta — same running-aggregate math over different storage);
    *bit*-identical within the form (scan == loop, fleet == sequential;
    fleet members replay the fleet-padded program, so a standalone
    single run is allclose, not bitwise)."""
    TOL = dict(rtol=2e-5, atol=2e-6)
    TOL8 = dict(rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize('engine', ['scan', 'loop'])
    def test_tree_engines_close_to_dense_and_delta(self, reg_task, engine):
        kw = dict(fraction=0.3, lag_tolerance=2)
        ex = dict(engine=engine, eval_every=4)
        hd = _run(reg_task, 'safa', kw, dict(ex, schedule='dense'))
        hs = _run(reg_task, 'safa', kw, dict(ex, schedule='sparse_delta'))
        ht = _run(reg_task, 'safa', kw, dict(ex, schedule='sparse_tier'))
        _trees_close(hd.final_global, ht.final_global, **self.TOL)
        _trees_close(hs.final_global, ht.final_global, **self.TOL)

    def test_scan_equals_loop_bitwise(self, reg_task):
        kw = dict(fraction=0.3, lag_tolerance=2)
        ex = dict(eval_every=4, schedule='sparse_tier')
        hs = _run(reg_task, 'safa', kw, dict(ex, engine='scan'))
        hl = _run(reg_task, 'safa', kw, dict(ex, engine='loop'))
        _trees_equal(hs.final_global, hl.final_global)
        assert hs.best_eval == hl.best_eval

    @pytest.mark.parametrize('wire', ['f32', 'int8'])
    def test_packed_close_to_dense(self, reg_task, wire):
        kw = dict(fraction=0.3, lag_tolerance=2)
        hd = _run(reg_task, 'safa', kw,
                  dict(engine='scan', wire=wire, eval_every=4,
                       schedule='dense'))
        hp = _run(reg_task, 'safa', kw,
                  dict(engine='scan', wire=wire, eval_every=4,
                       schedule='sparse_tier', use_kernel='packed'))
        tol = self.TOL8 if wire == 'int8' else self.TOL
        _trees_close(hd.final_global, hp.final_global, **tol)

    def test_packed_int8_scan_equals_loop_bitwise(self, reg_task):
        kw = dict(fraction=0.3, lag_tolerance=30)
        ex = dict(wire='int8', eval_every=4, schedule='sparse_tier',
                  use_kernel='packed')
        hs = _run(reg_task, 'safa', kw, dict(ex, engine='scan'))
        hl = _run(reg_task, 'safa', kw, dict(ex, engine='loop'))
        _trees_equal(hs.final_global, hl.final_global)

    @pytest.mark.parametrize('exec_kw,tol', [
        (dict(), 'TOL'),
        (dict(use_kernel='packed'), 'TOL'),
        (dict(use_kernel='packed', wire='int8'), 'TOL8'),
    ])
    def test_fleet_equals_sequential(self, reg_task, exec_kw, tol):
        # fresh members per sweep: every precompute consumes its env rng
        def members():
            return [federation.SweepMember(env=_env(), fraction=f,
                                           lag_tolerance=2)
                    for f in (0.3, 0.5)]
        def sweep(engine):
            exp = api.Experiment(
                reg_task, _env(),
                api.spec('safa', fraction=0.3, lag_tolerance=2),
                api.ExecSpec(engine=engine, schedule='sparse_tier',
                             eval_every=4, **exec_kw), rounds=8, seed=0)
            return exp.compile().run_sweep(members())
        hf, hq = sweep('fleet'), sweep('sequential')
        for a, b in zip(hf, hq):
            _trees_equal(a.final_global, b.final_global)
            assert a.best_eval == b.best_eval
        # a standalone run of member 0 replays the same events at its own
        # (unpadded) width/capacity: allclose, not bitwise
        h0 = _run(reg_task, 'safa', dict(fraction=0.3, lag_tolerance=2),
                  dict(engine='scan', schedule='sparse_tier', eval_every=4,
                       **exec_kw))
        _trees_close(hf[0].final_global, h0.final_global,
                     **getattr(self, tol))

    def test_stateless_tier_carry(self, reg_task):
        """No [m, ...] stacks: the carry is global + [capacity+1, ...]
        value buffer + running aggregate, built by prepare_state."""
        exp = api.Experiment(
            reg_task, _env(),
            api.spec('safa', fraction=0.3, lag_tolerance=2),
            api.ExecSpec(schedule='sparse_tier'), rounds=6, seed=0)
        r = exp.compile()
        from repro.core.api import _init_state
        st = _init_state(exp.task, M, 0, r._pdef.uses_cache,
                         r._stateless(exp.exec))
        assert st.local_w is None and st.cache is None
        sched = exp.precompute()
        r._pdef.prepare_state(st, jnp.asarray(exp.env.weights), exp.exec,
                              False, sched)
        assert st.local_w is None
        for leaf in jax.tree.leaves(st.cache):
            assert leaf.shape[0] == sched.capacity + 1
        h = r.run()
        assert np.isfinite(h.best_eval['loss'])


# ---------------------------------------------------------------------------
# check_compat gating
# ---------------------------------------------------------------------------

class TestCompat:
    def test_unknown_schedule(self):
        with pytest.raises(ValueError, match='schedule'):
            api.check_compat(api.SafaSpec(), api.ExecSpec(schedule='csr'))

    def test_sparse_needs_sparse_precompute(self):
        with pytest.raises(ValueError, match='sparse'):
            api.check_compat(api.LocalSpec(), api.ExecSpec(schedule='sparse'))

    def test_sparse_rejects_quantize_uploads(self):
        with pytest.raises(ValueError, match='quantize_uploads'):
            api.check_compat(api.SafaSpec(quantize_uploads=True),
                             api.ExecSpec(schedule='sparse'))

    def test_sparse_delta_rejects_plain_kernel(self):
        with pytest.raises(ValueError, match='use_kernel'):
            api.check_compat(api.SafaSpec(),
                             api.ExecSpec(schedule='sparse_delta',
                                          use_kernel=True))

    def test_unknown_schedule_names_sparse_tier(self):
        with pytest.raises(ValueError, match='sparse_tier'):
            api.check_compat(api.SafaSpec(), api.ExecSpec(schedule='csr'))

    def test_sparse_tier_needs_tier_precompute(self):
        with pytest.raises(ValueError, match='lag-tier'):
            api.check_compat(api.FedAvgSpec(),
                             api.ExecSpec(schedule='sparse_tier'))

    def test_sparse_tier_rejects_plain_kernel(self):
        with pytest.raises(ValueError, match='use_kernel'):
            api.check_compat(api.SafaSpec(),
                             api.ExecSpec(schedule='sparse_tier',
                                          use_kernel=True))

    def test_bad_sampler(self):
        with pytest.raises(ValueError, match='sampler'):
            api.check_compat(api.FedAvgSpec(sampler='bogus'), api.ExecSpec())


# ---------------------------------------------------------------------------
# Samplers
# ---------------------------------------------------------------------------

class TestTopkSampler:
    def test_shape_and_uniqueness(self):
        idx = selection.fedavg_select_topk(
            np.random.default_rng(0), 1000, 0.05, rounds=7)
        assert idx.shape == (7, 50) and idx.dtype == np.int32
        for t in range(7):
            assert len(set(idx[t].tolist())) == 50
            assert idx[t].min() >= 0 and idx[t].max() < 1000
        assert not np.array_equal(idx[0], idx[1])

    def test_chunking_keeps_stream(self):
        """Row-major draws mean the chunked implementation consumes the
        generator exactly like one bulk (rounds, m) draw."""
        rng = np.random.default_rng(7)
        u = rng.random((9, 40))
        want = np.sort(np.argpartition(u, 11, axis=-1)[:, :12], axis=-1)
        got = selection.fedavg_select_topk(
            np.random.default_rng(7), 40, 0.3, rounds=9)
        np.testing.assert_array_equal(got, want.astype(np.int32))

    def test_sampler_reaches_schedule(self):
        a = federation.precompute_sync_schedule(
            _env(), fraction=0.3, rounds=6, seed=0, fedcs=False,
            form='sparse', sampler='topk')
        b = federation.precompute_sync_schedule(
            _env(), fraction=0.3, rounds=6, seed=0, fedcs=False,
            form='sparse', sampler='choice')
        assert not np.array_equal(a.idx, b.idx)


# ---------------------------------------------------------------------------
# Kernels: gather/scatter rows + fused rows-aggregate, vs numpy oracles
# ---------------------------------------------------------------------------

class TestRowsKernels:
    def _buf(self, rng, r, n):
        return jnp.asarray(rng.standard_normal((r, n)).astype(np.float32))

    def test_gather_scatter_roundtrip(self):
        rng = np.random.default_rng(0)
        m, n, tile = 37, 512, 256
        buf = self._buf(rng, m + 1, n)
        rows = jnp.asarray(np.array([3, 9, 14, m, 2], np.int32))
        got = ops.gather_rows(buf, rows, tile=tile)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(buf)[np.asarray(rows)])
        vals = self._buf(rng, 5, n)
        want = np.asarray(buf).copy()           # snapshot: buf is donated
        want[np.asarray(rows)] = np.asarray(vals)   # sentinel -> scratch row
        out = ops.scatter_rows(buf, rows, vals, tile=tile)
        np.testing.assert_array_equal(np.asarray(out), want)

    def test_gather_scatter_fleet(self):
        rng = np.random.default_rng(1)
        s, m, n, k, tile = 3, 21, 256, 4, 256
        buf = self._buf(rng, s * (m + 1), n).reshape(s, m + 1, n)
        rows = jnp.asarray(rng.integers(0, m + 1, (s, k)).astype(np.int32))
        got = ops.gather_rows_fleet(buf, rows, tile=tile)
        want = np.stack([np.asarray(buf)[b][np.asarray(rows)[b]]
                         for b in range(s)])
        np.testing.assert_array_equal(np.asarray(got), want)
        vals = self._buf(rng, s * k, n).reshape(s, k, n)
        want = np.asarray(buf).copy()           # snapshot: buf is donated
        for b in range(s):
            want[b][np.asarray(rows)[b]] = np.asarray(vals)[b]
        out = ops.scatter_rows_fleet(buf, rows, vals, tile=tile)
        np.testing.assert_array_equal(np.asarray(out), want)

    def test_tile_mismatch_raises(self):
        buf = jnp.zeros((4, 300), jnp.float32)
        with pytest.raises(ValueError, match='pad_to'):
            ops.gather_rows(buf, jnp.zeros((2,), jnp.int32), tile=256)

    def test_rows_aggregate_oracle(self):
        rng = np.random.default_rng(2)
        m, n, k, tile = 13, 512, 6, 256
        cache = rng.standard_normal((m + 1, n)).astype(np.float32)
        trained = rng.standard_normal((k, n)).astype(np.float32)
        gprev = rng.standard_normal(n).astype(np.float32)
        agg = rng.standard_normal(n).astype(np.float32)
        rows = np.array([1, 5, 7, m, 2, 9], np.int32)
        pick = np.array([1, 0, 1, 0, 0, 1], bool)
        und = np.array([0, 1, 0, 0, 0, 0], bool)
        dep = np.array([0, 0, 0, 0, 1, 0], bool)
        w = np.where(rows < m, rng.random(k).astype(np.float32), 0.0)

        ng, na, c2 = ops.safa_aggregate_packed_rows(
            jnp.asarray(cache), jnp.asarray(trained), jnp.asarray(gprev),
            jnp.asarray(agg), jnp.asarray(rows), jnp.asarray(pick),
            jnp.asarray(und), jnp.asarray(dep), jnp.asarray(w), tile=tile)

        c0 = cache[rows]                       # sentinel gathers scratch row
        c1 = np.where(pick[:, None], trained,
                      np.where(dep[:, None], gprev[None], c0))
        ng_w = agg + (w[:, None] * (c1 - c0)).sum(0)
        c2_w = np.where(und[:, None], trained, c1)
        na_w = ng_w + (w[:, None] * (c2_w - c1)).sum(0)
        np.testing.assert_allclose(np.asarray(ng), ng_w, rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(na), na_w, rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(c2), c2_w, rtol=1e-6,
                                   atol=0)


# ---------------------------------------------------------------------------
# pack_spec validation
# ---------------------------------------------------------------------------

class TestPackSpecValidation:
    def test_rejects_non_positive(self):
        tree = {'w': jnp.zeros((8,), jnp.float32)}
        with pytest.raises(ValueError, match='pad_to'):
            ops.pack_spec(tree, pad_to=0)
        with pytest.raises(ValueError, match='align'):
            ops.pack_spec(tree, pad_to=128, align=0)

    def test_rejects_misaligned_pad(self):
        tree = {'w': jnp.zeros((8,), jnp.float32)}
        with pytest.raises(ValueError, match='multiple'):
            ops.pack_spec(tree, pad_to=100, align=64)


# ---------------------------------------------------------------------------
# Memory: quota-bounded schedules at m=10_000
# ---------------------------------------------------------------------------

class TestMemorySmoke:
    def test_quota_bounded_schedule_and_state(self):
        from benchmarks.scale import ScaleTask, make_scale_env
        m, quota, rounds = 10_000, 20, 6
        env = make_scale_env(m, quota)
        s = federation.precompute_safa_schedule(
            env, fraction=quota / m, lag_tolerance=10 * rounds,
            rounds=rounds, form='sparse')
        # active set ~2.5*quota by regime construction, never O(m)
        assert s.capacity <= 4 * quota
        assert s.nbytes <= rounds * 4 * quota * 5
        dense_bytes = rounds * m * 5    # five [rounds, m] bool masks
        assert s.nbytes < dense_bytes / 50

        # stateless fedavg sparse_delta at m=10_000: O(d) resident state
        env2 = make_scale_env(m, quota, bound_active=False)
        exp = api.Experiment(
            ScaleTask(), env2, api.spec('fedavg', fraction=quota / m,
                                        sampler='topk'),
            api.ExecSpec(schedule='sparse_delta', eval_every=rounds),
            rounds=rounds, seed=0)
        r = exp.compile()
        from repro.core.api import _init_state
        st = _init_state(exp.task, m, 0, r._pdef.uses_cache,
                         r._stateless(exp.exec))
        state_bytes = sum(getattr(l, 'nbytes', 0)
                          for l in jax.tree.leaves(st.tree()))
        assert state_bytes < 10_000          # D floats, not m*D
        h = r.run()
        assert np.isfinite(h.best_eval['loss'])

    def test_tier_state_is_quota_bounded(self):
        """SAFA sparse_tier at m=10_000: the whole carry is
        O((tau + quota) * D), independent of m."""
        from benchmarks.scale import ScaleTask, make_scale_env
        m, quota, rounds = 10_000, 20, 6
        env = make_scale_env(m, quota)
        exp = api.Experiment(
            ScaleTask(), env,
            api.spec('safa', fraction=quota / m,
                     lag_tolerance=10 * rounds),
            api.ExecSpec(schedule='sparse_tier', eval_every=rounds),
            rounds=rounds, seed=0)
        r = exp.compile()
        sched = exp.precompute()
        # slot capacity tracks the active-set bound, never O(m)
        assert sched.capacity <= 8 * quota
        from repro.core.api import _init_state
        st = _init_state(exp.task, m, 0, r._pdef.uses_cache,
                         r._stateless(exp.exec))
        r._pdef.prepare_state(st, jnp.asarray(env.weights), exp.exec,
                              False, sched)
        state_bytes = sum(getattr(l, 'nbytes', 0)
                          for l in jax.tree.leaves(st.tree()))
        d = sum(l.size for l in jax.tree.leaves(st.global_w))
        # (capacity+1 buffer rows + global + agg) * 4 bytes, with slack
        assert state_bytes <= (sched.capacity + 4) * d * 4
        assert state_bytes < m * d              # << the [m, D] stack
        h = r.run()
        assert np.isfinite(h.best_eval['loss'])
