"""Scan-compiled round engine + packed aggregation: regression tests.

No hypothesis dependency — this module must run in a bare environment.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federation, protocol, selection
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv
from repro.kernels import ops as kops


def _env(**kw):
    base = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
                epochs=3, t_lim=830.0, seed=3)
    base.update(kw)
    return FLEnv(**base)


@pytest.fixture(scope='module')
def reg_task():
    env = _env()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, 5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _tree(key, m, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f'p{i}': jax.random.normal(k, (m,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def _global(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f'p{i}': jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


class TestScanEngine:
    def test_safa_scan_bit_identical_to_loop(self, reg_task):
        """The compiled engine is a pure perf change: same seed => same
        bits out as the per-round Python-loop reference path."""
        hists = {}
        for engine in ('loop', 'scan'):
            h = federation.run_safa(reg_task, _env(), fraction=0.5,
                                    lag_tolerance=5, rounds=12, eval_every=6,
                                    engine=engine)
            hists[engine] = h
        gl = jax.tree.leaves(hists['loop'].final_global)
        gs = jax.tree.leaves(hists['scan'].final_global)
        for a, b in zip(gl, gs):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # evals run at the same rounds and agree exactly
        assert hists['loop'].evals() == hists['scan'].evals()
        assert hists['loop'].futility == hists['scan'].futility

    def test_fedavg_scan_bit_identical_to_loop(self, reg_task):
        hists = {}
        for engine in ('loop', 'scan'):
            hists[engine] = federation.run_fedavg(
                reg_task, _env(), fraction=0.5, rounds=10, eval_every=5,
                engine=engine)
        for a, b in zip(jax.tree.leaves(hists['loop'].final_global),
                        jax.tree.leaves(hists['scan'].final_global)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fedcs_scan_bit_identical_to_loop(self, reg_task):
        hists = {}
        for engine in ('loop', 'scan'):
            hists[engine] = federation.run_fedcs(
                reg_task, _env(), fraction=0.5, rounds=10, eval_every=5,
                engine=engine)
        for a, b in zip(jax.tree.leaves(hists['loop'].final_global),
                        jax.tree.leaves(hists['scan'].final_global)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_local_scan_bit_identical_to_loop(self, reg_task):
        """run_local rides the same scan engine contract: one donated-carry
        dispatch per eval segment, bit-identical to the per-round loop."""
        hists = {}
        for engine in ('loop', 'scan'):
            hists[engine] = federation.run_local(
                reg_task, _env(), fraction=0.5, rounds=12, eval_every=6,
                engine=engine)
        for a, b in zip(jax.tree.leaves(hists['loop'].final_global),
                        jax.tree.leaves(hists['scan'].final_global)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert hists['loop'].evals() == hists['scan'].evals()

    def test_fedasync_scan_bit_identical_to_loop(self, reg_task):
        """The arrival-ordered sequential merges compile into an inner
        lax.scan over the precomputed merge-order/alpha schedule without
        changing a bit vs the per-round loop."""
        hists = {}
        for engine in ('loop', 'scan'):
            hists[engine] = federation.run_fedasync(
                reg_task, _env(), rounds=12, eval_every=6, engine=engine)
        for a, b in zip(jax.tree.leaves(hists['loop'].final_global),
                        jax.tree.leaves(hists['scan'].final_global)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert hists['loop'].evals() == hists['scan'].evals()

    def test_every_runner_accepts_scan_engine(self, reg_task):
        """Acceptance criterion: every RUNNERS entry takes engine='scan'
        (and 'loop'), returning evals at the same rounds."""
        assert set(federation.RUNNERS) == {'safa', 'fedavg', 'fedcs',
                                           'local', 'fedasync'}
        for name, fn in federation.RUNNERS.items():
            kw = dict(fraction=0.5, rounds=4, eval_every=2, engine='scan')
            if name == 'safa':
                kw['lag_tolerance'] = 5
            h = fn(reg_task, _env(), **kw)
            assert [r for r, _ in h.evals()] == [2, 4], name

    def test_unknown_engine_rejected(self, reg_task):
        with pytest.raises(ValueError, match='engine'):
            federation.run_safa(reg_task, _env(), fraction=0.5,
                                lag_tolerance=5, rounds=2, engine='warp')
        with pytest.raises(ValueError, match='engine'):
            federation.run_local(reg_task, _env(), fraction=0.5, rounds=2,
                                 engine='warp')
        with pytest.raises(ValueError, match='engine'):
            federation.run_fedasync(reg_task, _env(), rounds=2,
                                    engine='warp')

    def test_schedule_independent_of_numeric_mode(self):
        """Timing metrics come from the precomputed schedule alone."""
        h_timing = federation.run_safa(None, _env(), fraction=0.5,
                                       lag_tolerance=5, rounds=15,
                                       numeric=False)
        sched = federation.precompute_safa_schedule(
            _env(), fraction=0.5, lag_tolerance=5, rounds=15)
        assert [r.round_len for r in h_timing.records] == \
            [r.round_len for r in sched.records]
        assert h_timing.futility == sched.futility

    def test_draw_rounds_matches_sequential_stream(self):
        e1, e2 = _env(seed=7), _env(seed=7)
        c_all, f_all = e1.draw_rounds(4)
        for t in range(4):
            c, f = e2.draw_round()
            np.testing.assert_array_equal(c_all[t], c)
            np.testing.assert_array_equal(f_all[t], f)


class TestBatchSelectors:
    def test_fedcs_select_batch_row_identity(self):
        """The rank-comparison form == the scalar greedy loop, row for
        row, over random estimate/fraction/deadline grids."""
        rng = np.random.default_rng(0)
        for m in (1, 2, 5, 33, 100):
            est = rng.exponential(100.0, (16, m)) + 5.0
            # inject duplicate estimates so stable tie-breaks are exercised
            est[:, : m // 2] = np.round(est[:, : m // 2], -1)
            fraction = rng.choice([0.1, 0.3, 0.5, 0.9, 1.0], 16)
            deadline = rng.choice([50.0, 120.0, 400.0, 1e9], 16)
            batch = selection.fedcs_select_batch(est, fraction, deadline)
            for s in range(16):
                ref = selection.fedcs_select(est[s], fraction[s], deadline[s])
                np.testing.assert_array_equal(batch[s], ref, err_msg=f'{m}/{s}')

    def test_fedcs_select_batch_degenerate_no_fit(self):
        """No client fits the deadline -> the single fastest is admitted,
        in every row (including rows where some clients do fit)."""
        est = np.array([[90.0, 50.0, 70.0],     # nothing fits deadline=10
                        [90.0, 5.0, 70.0],      # one fits
                        [50.0, 50.0, 50.0]])    # tie: stable pick of idx 0
        deadline = np.array([10.0, 10.0, 10.0])
        batch = selection.fedcs_select_batch(est, 0.7, deadline)
        for s in range(3):
            ref = selection.fedcs_select(est[s], 0.7, deadline[s])
            np.testing.assert_array_equal(batch[s], ref)
        np.testing.assert_array_equal(batch[0], [False, True, False])
        np.testing.assert_array_equal(batch[2], [True, False, False])

    def test_fedavg_select_batch_row_identity(self):
        """Batched selections == sequential scalar calls consuming
        identically-seeded generators — the sync fleet precompute's rng
        contract."""
        m, rounds = 7, 5
        fractions = np.array([0.1, 0.5, 1.0, 0.43])
        batch = selection.fedavg_select_batch(
            [np.random.default_rng(100 + s) for s in range(4)], m,
            fractions, rounds)
        assert batch.shape == (4, rounds, m)
        for s in range(4):
            rng = np.random.default_rng(100 + s)
            for t in range(rounds):
                ref = selection.fedavg_select(rng, m, fractions[s])
                np.testing.assert_array_equal(batch[s, t], ref)


class TestPackedAggregation:
    SHAPES = ((4, 3), (64,), (8, 33), (2, 5, 7))

    def _operands(self, m=6):
        cache = _tree(jax.random.PRNGKey(0), m, self.SHAPES)
        trained = _tree(jax.random.PRNGKey(1), m, self.SHAPES)
        g = _global(jax.random.PRNGKey(2), self.SHAPES)
        masks = dict(picked=jnp.array([1, 0, 0, 1, 0, 0], bool),
                     undrafted=jnp.array([0, 1, 0, 0, 1, 0], bool),
                     deprecated=jnp.array([0, 0, 1, 1, 0, 0], bool),
                     weights=jnp.asarray(
                         np.random.default_rng(0).dirichlet(np.ones(m)),
                         jnp.float32))
        return cache, trained, g, masks

    def test_packed_equals_leafwise_equals_reference(self):
        """packed kernel == leaf-wise kernel == 3-step Eq. 6-8 reference."""
        cache, trained, g, masks = self._operands()
        ref = protocol.discriminative_aggregation(
            cache, trained, g, use_kernel=False, **masks)
        leaf = protocol.discriminative_aggregation(
            cache, trained, g, use_kernel=True, **masks)
        packed = protocol.discriminative_aggregation(
            cache, trained, g, use_kernel='packed', **masks)
        for k in cache:
            for other in (leaf, packed):
                np.testing.assert_allclose(np.asarray(other.new_global[k]),
                                           np.asarray(ref.new_global[k]),
                                           atol=1e-5)
                np.testing.assert_allclose(np.asarray(other.new_cache[k]),
                                           np.asarray(ref.new_cache[k]),
                                           atol=1e-6)

    def test_packed_single_dispatch(self):
        """Exactly one pallas_call regardless of leaf count."""
        cache, trained, g, masks = self._operands()
        count = kops.count_pallas_calls

        def agg(mode, c, t, gg):
            return protocol.discriminative_aggregation(
                c, t, gg, use_kernel=mode, **masks)

        n_packed = count(jax.make_jaxpr(
            lambda c, t, gg: agg('packed', c, t, gg))(cache, trained, g).jaxpr)
        n_leaf = count(jax.make_jaxpr(
            lambda c, t, gg: agg(True, c, t, gg))(cache, trained, g).jaxpr)
        assert n_packed == 1
        assert n_leaf == len(self.SHAPES)

    def test_unknown_use_kernel_rejected(self):
        cache, trained, g, masks = self._operands()
        with pytest.raises(ValueError, match='use_kernel'):
            protocol.discriminative_aggregation(
                cache, trained, g, use_kernel='Packed', **masks)

    def test_counter_descends_into_cond_branches(self):
        """count_pallas_calls must see dispatches inside lax.cond branches
        (tuple-of-ClosedJaxpr params)."""
        from repro.kernels.comm_quant import QBLOCK, quantize
        n = 2048

        def f(x):
            return jax.lax.cond(
                x[0] > 0, lambda v: quantize(v),
                lambda v: (jnp.zeros(n, jnp.int8),
                           jnp.ones(n // QBLOCK, jnp.float32)), x)

        jaxpr = jax.make_jaxpr(f)(jnp.ones(n))
        assert kops.count_pallas_calls(jaxpr.jaxpr) == 1

    def test_packed_rejects_non_f32(self):
        """The pack buffer computes in f32 — other dtypes must fail loud,
        not silently diverge from the leaf-wise path."""
        cache, trained, g, masks = self._operands()
        g16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
        c16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), cache)
        t16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), trained)
        with pytest.raises(TypeError, match='float32'):
            protocol.discriminative_aggregation(
                c16, t16, g16, use_kernel='packed', **masks)

    def test_pack_unpack_roundtrip(self):
        m = 4
        tree = _tree(jax.random.PRNGKey(3), m, self.SHAPES)
        g = _global(jax.random.PRNGKey(4), self.SHAPES)
        spec = kops.pack_spec(g)
        assert spec.n_padded % 2048 == 0
        back = kops.unpack_stacked(kops.pack_stacked(tree, spec), spec)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]))
        gback = kops.unpack_global(kops.pack_global(g, spec), spec)
        for k in g:
            np.testing.assert_array_equal(np.asarray(gback[k]),
                                          np.asarray(g[k]))


class TestQuantizeTree:
    def test_roundtrip_nested_multileaf(self):
        """dequantize(quantize(tree)) on a nested pytree with dict/list/
        tuple structure — the layout the old is_leaf-based flattening
        mishandled (a structural tuple was mistaken for a (q, scales)
        pair)."""
        key = jax.random.PRNGKey(9)
        ks = jax.random.split(key, 4)
        tree = {
            'layers': [
                {'w': jax.random.normal(ks[0], (16, 8)),
                 'b': jax.random.normal(ks[1], (8,))},
                (jax.random.normal(ks[2], (5, 3, 2)),
                 jax.random.normal(ks[3], (7,))),
            ],
        }
        out = kops.dequantize_tree(kops.quantize_tree(tree), tree)
        assert jax.tree_util.tree_structure(out) == \
            jax.tree_util.tree_structure(tree)
        for orig, deq in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert deq.shape == orig.shape and deq.dtype == orig.dtype
            # int8 symmetric per-block: error bounded by half a quant step
            tol = float(jnp.max(jnp.abs(orig))) / 127.0
            np.testing.assert_allclose(np.asarray(deq), np.asarray(orig),
                                       atol=tol)


class TestFedAsyncGuard:
    def test_all_crash_round_len_finite(self):
        env = _env(m=4, crash_prob=1.0, dataset_size=100, epochs=1,
                   t_lim=100.0, seed=0)
        h = federation.run_fedasync(None, env, rounds=6, numeric=False)
        lens = [r.round_len for r in h.records]
        assert all(np.isfinite(lens))
        assert all(l == env.t_lim for l in lens)
