"""Monte-Carlo validation of the bias analysis (paper §III-E, Appendix A).

Simulates CFCFM selection with a fastest client A and slowest client B and
checks the steady-state pick probabilities against the recurrence solution
(the corrected sigma — see repro.core.bias.sigma docstring).
"""
import numpy as np
import pytest

from repro.core import bias, selection


def simulate(m=30, cr=0.3, C=0.1, rounds=3000, seed=0):
    rng = np.random.default_rng(seed)
    picked_prev = np.zeros(m, bool)
    picked_A, picked_B, undrafted_B = [], [], []
    for _ in range(rounds):
        crashed = rng.random(m) < cr
        # A = client 0 is always fastest; B = client m-1 always slowest
        arrival = rng.uniform(10, 20, m)
        arrival[0] = 1.0
        arrival[-1] = 100.0
        arrival = np.where(~crashed, arrival, np.inf)
        sel = selection.cfcfm(arrival, ~crashed, picked_prev, C, 1e9)
        picked_A.append(bool(sel.picked[0]))
        picked_B.append(bool(sel.picked[-1]))
        undrafted_B.append(bool(sel.undrafted[-1]))
        picked_prev = sel.picked
    half = rounds // 2  # steady state
    return (np.mean(picked_A[half:]), np.mean(picked_B[half:]),
            np.mean(undrafted_B[half:]))


class TestBiasMonteCarlo:
    def test_case1_everyone_picked(self):
        """C >= 1-R: every committed update is aggregated; P = 1-cr."""
        cr = 0.3
        pA, pB, _ = simulate(cr=cr, C=1.0, rounds=2000)
        assert pA == pytest.approx(1 - cr, abs=0.04)
        assert pB == pytest.approx(1 - cr, abs=0.04)

    def test_case3_fast_client_alternation(self):
        """C < (1-C)(1-R): A is picked iff it missed the previous round;
        steady state P_D(A) = (1-cr)/(2-cr) = (1-cr) sigma^(inf)."""
        cr = 0.3
        pA, pB, uB = simulate(cr=cr, C=0.1, rounds=4000)
        expect = (1 - cr) / (2 - cr)
        assert pA == pytest.approx(expect, abs=0.03)
        # B never reaches the quota directly but commits via the bypass
        assert pB == pytest.approx(0.0, abs=0.01)
        assert uB == pytest.approx(1 - cr, abs=0.04)

    def test_sigma_limit_matches_fixed_point(self):
        cr = 0.3
        assert bias.sigma(cr, 500) == pytest.approx(1 / (2 - cr), rel=1e-9)
        # P_D(inf) = (1-cr) * sigma(inf)
        assert (1 - cr) * bias.sigma(cr, 500) == pytest.approx(
            (1 - cr) / (2 - cr), rel=1e-9)

    def test_compensation_reduces_bias_case2_paper_faithful(self):
        """Fig. 5 (paper-faithful formulas): case-2 bias below FedAvg's."""
        b_fed = bias.bias_fedavg(0.3, 0.3)
        b_safa = bias.bias_safa(0.3, 0.3, C=0.5, R=0.3, r=20, faithful=True)
        assert b_safa < b_fed
