"""End-to-end federation runs + bias theory + timing model."""
import numpy as np
import pytest

from repro.core import bias, federation
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv

# one built Env's rng is single-shot (see Env.draw_rounds) — tests that
# launch several runs build a fresh env per run from this recipe; same
# seed => same client population, so one partition serves them all
REG_ENV_KW = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
                  epochs=3, t_lim=830.0, seed=3)


@pytest.fixture(scope='module')
def reg_setup():
    env = FLEnv(**REG_ENV_KW)
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, 5, seed=1)
    task = regression_task(data, lr=1e-3, epochs=3)
    return env, task


class TestProtocolRuns:
    def test_safa_converges(self, reg_setup):
        env, task = reg_setup
        h = federation.run_safa(task, FLEnv(**REG_ENV_KW), fraction=0.5,
                                lag_tolerance=5, rounds=40, eval_every=10)
        evals = [e['loss'] for _, e in h.evals()]
        assert evals[-1] < evals[0] * 0.5
        assert 0 <= h.futility <= 1
        assert all(r.round_len <= env.t_lim for r in h.records)

    def test_all_protocols_run_timing_only(self, reg_setup):
        env, _ = reg_setup
        for name, fn in federation.PROTOCOLS.items():
            kw = dict(fraction=0.3, rounds=20, numeric=False)
            if name == 'safa':
                kw['lag_tolerance'] = 5
            h = fn(None, FLEnv(**REG_ENV_KW), **kw)
            assert len(h.records) == 20, name
            assert h.mean('round_len') > 0

    def test_safa_round_shorter_than_fedavg(self):
        """Paper's headline: SAFA shortens rounds, esp. at small C."""
        env_kw = dict(m=100, crash_prob=0.3, dataset_size=70000,
                      batch_size=40, epochs=5, t_lim=5600.0, seed=0)
        hs = federation.run_safa(None, FLEnv(**env_kw), fraction=0.1,
                                 lag_tolerance=5, rounds=30, numeric=False)
        hf = federation.run_fedavg(None, FLEnv(**env_kw), fraction=0.1,
                                   rounds=30, numeric=False)
        assert hs.mean('round_len') < 0.5 * hf.mean('round_len')

    def test_eur_improves_over_fedavg(self):
        env_kw = dict(m=100, crash_prob=0.3, dataset_size=70000,
                      batch_size=40, epochs=5, t_lim=5600.0, seed=1)
        hs = federation.run_safa(None, FLEnv(**env_kw), fraction=0.3,
                                 lag_tolerance=5, rounds=30, numeric=False)
        hf = federation.run_fedavg(None, FLEnv(**env_kw), fraction=0.3,
                                   rounds=30, numeric=False)
        assert hs.mean('eur') > hf.mean('eur')

    def test_sr_decreases_with_lag_tolerance(self):
        """Fig. 3(b): larger tau => fewer forced synchronisations."""
        env_kw = dict(m=100, crash_prob=0.5, dataset_size=70000,
                      batch_size=40, epochs=5, t_lim=5600.0)
        srs = []
        for tau in (1, 5, 10):
            env = FLEnv(seed=2, **env_kw)
            h = federation.run_safa(None, env, fraction=0.3,
                                    lag_tolerance=tau, rounds=40,
                                    numeric=False)
            srs.append(h.mean('sr'))
        assert srs[0] >= srs[1] >= srs[2]

    def test_vv_increases_with_lag_tolerance(self):
        """Fig. 4(b): larger tau => higher version variance."""
        env_kw = dict(m=100, crash_prob=0.5, dataset_size=70000,
                      batch_size=40, epochs=5, t_lim=5600.0)
        vvs = []
        for tau in (1, 10):
            env = FLEnv(seed=2, **env_kw)
            h = federation.run_safa(None, env, fraction=0.3,
                                    lag_tolerance=tau, rounds=40,
                                    numeric=False)
            vvs.append(h.mean('vv'))
        assert vvs[1] > vvs[0]

    def test_futility_smaller_than_fedavg(self):
        """SAFA preserves straggler progress (Tables XI/XIII/XV)."""
        env_kw = dict(m=100, crash_prob=0.5, dataset_size=70000,
                      batch_size=40, epochs=5, t_lim=5600.0, seed=4)
        hs = federation.run_safa(None, FLEnv(**env_kw), fraction=0.3,
                                 lag_tolerance=5, rounds=40, numeric=False)
        hf = federation.run_fedavg(None, FLEnv(**env_kw), fraction=0.3,
                                   rounds=40, numeric=False)
        assert hs.futility < hf.futility


class TestBiasTheory:
    @pytest.mark.parametrize('cr', [0.1, 0.3, 0.7])
    def test_sigma_closed_form_matches_recurrence(self, cr):
        """Eq. 15 closed form == unrolled case-3 recurrence of Eq. 22:
        P_D^(r) = (1-cr)(1 - P_D^(r-1)), sigma^(k) = 1 - P_D^(k)."""
        pd = 1 - cr  # P_D^(1)
        for k in range(1, 12):
            assert bias.sigma(cr, k) == pytest.approx(1 - pd, rel=1e-9)
            pd = (1 - cr) * (1 - pd)  # P_D^(k+1)

    def test_case_selection(self):
        assert bias.case_of(0.8, 0.5) == 1   # C >= 1-R
        assert bias.case_of(0.5, 0.3) == 2
        assert bias.case_of(0.1, 0.3) == 3   # C < (1-C)(1-R)

    def test_fedavg_bias_constant(self):
        assert bias.bias_fedavg(0.3, 0.3) == pytest.approx(1.0)
        assert bias.bias_fedavg(0.1, 0.5) == pytest.approx(0.9 / 0.5)

    def test_safa_bias_case1_equals_fedavg(self):
        for r in range(2, 10):
            assert bias.bias_safa(0.3, 0.3, C=0.9, R=0.5, r=r) == \
                pytest.approx(bias.bias_fedavg(0.3, 0.3))

    def test_bias_converges(self):
        """Fig. 5: bias converges after a few rounds in all cases."""
        for C, R in [(0.9, 0.5), (0.5, 0.3), (0.05, 0.3)]:
            curve = bias.bias_curve(0.3, 0.3, C, R, 40)
            assert np.all(np.isfinite(curve))
            assert abs(curve[-1] - curve[-2]) < 1e-6


class TestTimingModel:
    def test_eq18_train_time(self):
        env = FLEnv(m=10, crash_prob=0.0, dataset_size=1000, batch_size=10,
                    epochs=3, t_lim=100.0, seed=0)
        tt = env.full_train_time()
        expect = env.n_batches * env.epochs / env.perf
        np.testing.assert_allclose(tt, expect)

    def test_eq19_t_dist_linear_in_copies(self):
        env = FLEnv(m=10, crash_prob=0.0, dataset_size=1000, batch_size=10,
                    epochs=1, t_lim=100.0)
        assert env.t_dist(10) == pytest.approx(10 * env.t_dist(1))

    def test_partition_imbalance(self):
        env = FLEnv(m=200, crash_prob=0.0, dataset_size=20000, batch_size=10,
                    epochs=1, t_lim=100.0, seed=1)
        sizes = env.partition_sizes
        mu = 20000 / 200
        assert abs(sizes.mean() - mu) < 0.15 * mu
        assert 0.15 * mu < sizes.std() < 0.5 * mu  # ~N(mu, 0.3mu)


class TestQuantizedUplink:
    def test_safa_with_int8_uploads_converges(self, reg_setup):
        """Beyond-paper: int8-compressed client uploads barely change the
        global model trajectory (comm_quant kernel in the loop)."""
        _, task = reg_setup
        h_q = federation.run_safa(task, FLEnv(**REG_ENV_KW), fraction=0.5,
                                  lag_tolerance=5, rounds=25, eval_every=25,
                                  quantize_uploads=True)
        h_f = federation.run_safa(task, FLEnv(**REG_ENV_KW), fraction=0.5,
                                  lag_tolerance=5, rounds=25, eval_every=25)
        assert h_q.best_eval['loss'] < h_f.best_eval['loss'] * 1.5 + 1.0


class TestFedAsync:
    def test_fedasync_converges_with_higher_comm(self, reg_setup):
        """FedAsync (related-work baseline): converges, but every client
        syncs every round (SR=1) and the server does ~m merges per round —
        the communication pressure SAFA's semi-async design avoids."""
        _, task = reg_setup
        ha = federation.run_fedasync(task, FLEnv(**REG_ENV_KW), rounds=40,
                                     eval_every=20)
        hs = federation.run_safa(task, FLEnv(**REG_ENV_KW), fraction=0.5,
                                 lag_tolerance=5, rounds=40, eval_every=20)
        assert ha.best_eval['loss'] < 5.0
        assert ha.mean('sr') == 1.0
        assert hs.mean('sr') < 1.0  # SAFA syncs only up-to-date + deprecated

    def test_staleness_scaling(self):
        import jax.numpy as jnp
        from repro.core import protocol
        g = {'w': jnp.zeros(3)}
        trained = {'w': jnp.stack([jnp.ones(3), 2 * jnp.ones(3)])}
        out = protocol.fedasync_merge(
            g, trained, order=jnp.array([0, 1]),
            alphas=jnp.array([0.5, 0.5]))
        # w = 0.5*1 after first merge; 0.5*0.5 + 0.5*2 = 1.25 after second
        np.testing.assert_allclose(np.asarray(out['w']), 1.25 * np.ones(3))
