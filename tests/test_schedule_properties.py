"""Property-based invariants of the staleness-adaptive schedule family,
over randomised ``FLEnv`` configurations (hypothesis).

The weighted-merge engine trusts its precomputed schedules blindly — a
weight row summing past 1 would flip the residual global weight negative
inside a compiled scan where nothing checks it.  These properties pin the
host-side contracts instead: discounts stay in (0, 1], weight rows are
zero off the committed set and bounded by alpha, cluster labels
partition the population, sentinel slots carry zero weight, and the
sparse schedule round-trips through its dense form.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import agg_schemes, federation, protocol, selection
from repro.fedsim import FLEnv

SETTINGS = dict(max_examples=20, deadline=None)

env_configs = st.fixed_dictionaries({
    'm': st.integers(2, 8),
    'crash_prob': st.floats(0.0, 0.9),
    'seed': st.integers(0, 2**16),
    't_lim': st.sampled_from([200.0, 830.0, 5000.0]),
})


def make_env(cfg) -> FLEnv:
    return FLEnv(dataset_size=506, batch_size=5, epochs=3, **cfg)


discount_args = st.fixed_dictionaries({
    'fn': st.sampled_from(('constant', 'hinge', 'poly')),
    'staleness_exp': st.floats(0.0, 3.0),
    'hinge_a': st.floats(0.01, 50.0),
    'hinge_b': st.integers(0, 10),
})


@settings(**SETTINGS)
@given(args=discount_args,
       staleness=st.lists(st.floats(0.0, 1e4), min_size=1, max_size=32))
def test_discount_in_unit_interval(args, staleness):
    fn = args.pop('fn')
    d = agg_schemes.staleness_discount(np.asarray(staleness), fn, **args)
    assert np.all(d > 0.0), (fn, d)
    assert np.all(d <= 1.0), (fn, d)


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       alpha=st.floats(0.05, 1.0),
       fn=st.sampled_from(('constant', 'hinge', 'poly')),
       use_loss=st.booleans())
def test_seafl_rows_sum_to_alpha_on_committed(cfg, rounds, alpha, fn,
                                              use_loss):
    sched = agg_schemes.precompute_weighted_schedule(
        make_env(cfg), rounds=rounds, scheme='seafl', alpha=alpha,
        staleness_fn=fn, use_loss=use_loss)
    assert np.all(sched.wrow >= 0.0)
    assert np.all(sched.wrow[~sched.committed] == 0.0)
    sums = sched.wrow.sum(axis=-1)
    nonempty = sched.committed.any(axis=-1)
    np.testing.assert_allclose(sums[nonempty], alpha, rtol=1e-12)
    assert np.all(sums[~nonempty] == 0.0)


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       alpha=st.floats(0.05, 1.0), clusters=st.integers(1, 6),
       fn=st.sampled_from(('constant', 'hinge', 'poly')))
def test_csafl_rows_bounded_by_alpha(cfg, rounds, alpha, clusters, fn):
    sched = agg_schemes.precompute_weighted_schedule(
        make_env(cfg), rounds=rounds, scheme='csafl', alpha=alpha,
        staleness_fn=fn, clusters=clusters)
    assert np.all(sched.wrow >= 0.0)
    assert np.all(sched.wrow[~sched.committed] == 0.0)
    # sum_g disc_g * W_g <= sum_g W_g = 1, so rows never exceed alpha:
    # the residual global weight 1 - sum(wrow) stays non-negative
    assert np.all(sched.wrow.sum(axis=-1) <= alpha * (1 + 1e-12))


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       alpha=st.floats(0.05, 1.0),
       fn=st.sampled_from(('constant', 'hinge', 'poly')))
def test_fedasync_fold_matches_sequential_residual(cfg, rounds, alpha, fn):
    """The folded chain's residual 1 - sum(wrow) must equal
    prod(1 - a_k) — the telescoping identity the fold relies on."""
    env = make_env(cfg)
    async_sched = agg_schemes.precompute_async_schedule(
        FLEnv(dataset_size=506, batch_size=5, epochs=3, **cfg),
        rounds=rounds, alpha=alpha, staleness_fn=fn)
    sched = agg_schemes.precompute_weighted_schedule(
        env, rounds=rounds, scheme='fedasync', alpha=alpha, staleness_fn=fn)
    assert np.all(sched.wrow >= 0.0)
    assert np.all(sched.wrow[~sched.committed] == 0.0)
    np.testing.assert_allclose(
        1.0 - sched.wrow.sum(axis=-1),
        np.prod(1.0 - async_sched.alphas, axis=-1), rtol=1e-9)


@settings(**SETTINGS)
@given(m=st.integers(1, 64), clusters=st.integers(1, 10),
       seed=st.integers(0, 2**16))
def test_cluster_labels_partition_and_balance(m, clusters, seed):
    profile = np.random.default_rng(seed).exponential(size=m)
    labels = selection.cluster_by_profile(profile, clusters)
    k = min(clusters, m)
    assert labels.shape == (m,)
    assert labels.min() >= 0 and labels.max() == k - 1
    sizes = np.bincount(labels, minlength=k)
    assert np.all(sizes >= 1)                      # a partition, no empties
    assert sizes.max() - sizes.min() <= 1          # balanced within one


@settings(**SETTINGS)
@given(m=st.integers(1, 16), cap=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_sentinel_slots_carry_zero_weight(m, cap, seed):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    n_real = rng.integers(0, min(m, cap) + 1)
    idx = np.full(cap, m, np.int32)                # sentinel index == m
    idx[:n_real] = rng.choice(m, size=n_real, replace=False)
    weights = rng.random(m)
    w = np.asarray(protocol._slot_weights(jnp.asarray(idx),
                                          jnp.asarray(weights)))
    assert np.all(w[n_real:] == 0.0)
    np.testing.assert_allclose(w[:n_real], weights[idx[:n_real]], rtol=1e-6)


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 6),
       fraction=st.floats(0.2, 1.0), lag=st.integers(1, 6))
def test_sparse_schedule_dense_roundtrip(cfg, rounds, fraction, lag):
    dense = federation.precompute_safa_schedule(
        make_env(cfg), fraction=fraction, lag_tolerance=lag, rounds=rounds)
    sparse = federation.precompute_safa_schedule(
        make_env(cfg), fraction=fraction, lag_tolerance=lag, rounds=rounds,
        form='sparse')
    back = sparse.to_dense()
    for field in ('committed', 'picked', 'undrafted', 'deprecated'):
        np.testing.assert_array_equal(getattr(back, field),
                                      getattr(dense, field), err_msg=field)
    # round 1's population-wide bootstrap sync is elided by design
    np.testing.assert_array_equal(back.sync[1:], dense.sync[1:])


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 6),
       fraction=st.floats(0.2, 1.0), lag=st.integers(1, 6))
def test_tier_schedule_slot_invariants(cfg, rounds, fraction, lag):
    """The lag-tier slot maps: same event stream as the sparse form,
    every slot inside the [capacity+1] buffer, per-round writes distinct
    and disjoint from reads (the aliased-kernel contract), and the dense
    masks reconstructible."""
    dense = federation.precompute_safa_schedule(
        make_env(cfg), fraction=fraction, lag_tolerance=lag, rounds=rounds)
    tier = federation.precompute_safa_schedule(
        make_env(cfg), fraction=fraction, lag_tolerance=lag, rounds=rounds,
        form='sparse_tier')
    ref = dense.to_tier()
    for f in ('idx', 'roles', 'base_src', 'cache_src', 'cache_dst',
              'global_dst'):
        np.testing.assert_array_equal(getattr(tier, f), getattr(ref, f),
                                      err_msg=f)
    assert tier.capacity == ref.capacity
    # slots are reused: peak live rows never exceeds the value count
    assert tier.capacity <= tier.versions_stored + tier.commits_stored
    scr = tier.scratch
    for f in ('base_src', 'cache_src', 'cache_dst'):
        a = getattr(tier, f)
        assert a.min() >= 0 and a.max() <= scr, f
    assert tier.global_dst.min() >= 0 and tier.global_dst.max() <= scr
    for t in range(tier.rounds):
        srcs = set(tier.base_src[t]) | set(tier.cache_src[t])
        dsts = [d for d in tier.cache_dst[t] if d != scr]
        if tier.global_dst[t] != scr:
            dsts.append(int(tier.global_dst[t]))
        assert len(dsts) == len(set(dsts)), t
        assert not (set(dsts) & (srcs - {scr})), t
    back = tier.to_dense()
    for field in ('committed', 'picked', 'undrafted', 'deprecated'):
        np.testing.assert_array_equal(getattr(back, field),
                                      getattr(dense, field), err_msg=field)
    # round 1's population-wide bootstrap sync is elided by design
    np.testing.assert_array_equal(back.sync[1:], dense.sync[1:])


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 6),
       fraction=st.floats(0.2, 1.0), lag=st.integers(1, 6))
def test_tier_base_slots_partition_clients_by_lag(cfg, rounds, fraction,
                                                  lag):
    """Clients at the same base version share a base slot and clients at
    different versions never do — the 'tier' in lag-tier.  Versions are
    replayed from the dense masks: sync resets to the current round,
    commit advances to the round's output."""
    dense = federation.precompute_safa_schedule(
        make_env(cfg), fraction=fraction, lag_tolerance=lag, rounds=rounds)
    tier = dense.to_tier()
    m = tier.m
    v = np.zeros(m, np.int64)
    for t in range(tier.rounds):
        v[dense.sync[t]] = t
        idx, roles = tier.idx[t], tier.roles[t]
        com_ns = (idx < m) & ((roles & protocol.ROLE_COMMITTED) != 0) \
            & ((roles & protocol.ROLE_SYNC) == 0)
        bver = v[np.where(idx < m, idx, 0)]
        js = np.flatnonzero(com_ns)
        for a in js:
            for b in js:
                assert (bver[a] == bver[b]) == \
                    (tier.base_src[t, a] == tier.base_src[t, b]), (t, a, b)
        v[dense.committed[t]] = t + 1


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 6),
       alpha=st.floats(0.05, 1.0))
def test_async_commit_masks_match_weighted(cfg, rounds, alpha):
    """The weighted precompute replays FedAsync's event process exactly:
    same commits, same records, whatever the scheme."""
    a = agg_schemes.precompute_async_schedule(make_env(cfg), rounds=rounds,
                                              alpha=alpha)
    w = agg_schemes.precompute_weighted_schedule(make_env(cfg),
                                                 rounds=rounds,
                                                 scheme='seafl', alpha=alpha)
    np.testing.assert_array_equal(a.committed, w.committed)
    import dataclasses
    assert [dataclasses.asdict(r) for r in a.records] == \
        [dataclasses.asdict(r) for r in w.records]


if __name__ == '__main__':
    pytest.main([__file__, '-q'])
