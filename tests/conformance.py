"""Registry-wide protocol conformance harness (library, no tests).

``tests/test_conformance.py`` drives every spec in ``api.PROTOCOLS``
through the invariant matrix the engines promise — scan == loop, fleet ==
sequential == single run, sparse == dense, the int8 wire's engine parity
(plus the per-leaf ``quantize_uploads`` reference where the spec has
one), checkpoint/resume bit-identity, and the ``History`` dict
round-trip.  The case list is **auto-discovered** from the registry: a
protocol registered via ``api.register`` is conformance-tested with zero
test edits, and a failure names the offending spec in the test id.

Everything here is deliberately tiny (m=5 regression task, 6 rounds) so
the whole matrix stays tier-1 fast; the point is engine *identity*, not
learning quality.

Environments are consumed: every precompute advances its ``FLEnv`` rng,
so each run (and each sweep member) gets a ``fresh_env`` — two runs that
must replay the same event stream get two envs built with the same seed.
"""
import dataclasses

import jax
import numpy as np

from repro import api
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import FLEnv

ROUNDS = 6
EVAL_EVERY = 3
M = 5
ENV_SEED = 3
BASE_ENV = dict(m=M, crash_prob=0.3, dataset_size=506, batch_size=5,
                epochs=3, t_lim=830.0)

#: named non-default field variants ridden through the same matrix; keys
#: must not collide with registry names.
VARIANTS = {
    'fedasync-constant': lambda: api.FedAsyncSpec(staleness_fn='constant'),
    'fedasync-hinge': lambda: api.FedAsyncSpec(staleness_fn='hinge',
                                               hinge_b=1),
    'seafl-hinge': lambda: api.SeaflSpec(staleness_fn='hinge', hinge_b=1),
    'seafl-loss': lambda: api.SeaflSpec(use_loss=True),
    'csafl-3': lambda: api.CsaflSpec(clusters=3),
}


def fresh_env(seed: int = ENV_SEED) -> FLEnv:
    return FLEnv(seed=seed, **BASE_ENV)


_TASK = None


def shared_task():
    """One tiny regression task shared by every case (module-cached so
    its jitted train steps compile once per test session)."""
    global _TASK
    if _TASK is None:
        env = fresh_env()
        x, y = make_regression()
        data = partition(x, y, env.partition_sizes, M, seed=1)
        _TASK = regression_task(data, lr=1e-3, epochs=3)
    return _TASK


def cases() -> dict:
    """case id -> spec factory.  One default-spec case per registered
    protocol (auto-discovery) plus the named ``VARIANTS``."""
    out = {p.name: p.spec_cls for p in api.PROTOCOLS.values()}
    overlap = set(out) & set(VARIANTS)
    assert not overlap, f'variant ids shadow registry names: {overlap}'
    out.update(VARIANTS)
    return out


def pdef_of(spec) -> api.ProtocolDef:
    return api.PROTOCOLS[type(spec)]


def member_for(spec, env, seed: int = 0) -> api.SweepMember:
    """A SweepMember replaying exactly ``spec`` on ``env``: the member
    hyper columns mirror the spec's, and — for the staleness-adaptive
    family — the remaining spec fields ride in ``overrides`` so the fleet
    precompute reproduces the single-run schedule bit-for-bit."""
    kw = dict(seed=seed)
    for f in ('fraction', 'lag_tolerance', 'alpha', 'staleness_exp'):
        if hasattr(spec, f):
            kw[f] = getattr(spec, f)
    if hasattr(spec, 'staleness_fn'):
        kw['overrides'] = {
            f.name: getattr(spec, f.name)
            for f in dataclasses.fields(spec)
            if f.name not in ('fraction', 'lag_tolerance', 'alpha',
                              'staleness_exp')}
    return api.SweepMember(env=env, **kw)


def run_single(spec, *, engine=None, exec_kw=None, env_seed: int = ENV_SEED,
               seed: int = 0, checkpoint=None, max_segments=None):
    ex = api.ExecSpec(engine=engine, eval_every=EVAL_EVERY,
                      **(exec_kw or {}))
    exp = api.Experiment(shared_task(), fresh_env(env_seed), spec, ex,
                         rounds=ROUNDS, seed=seed)
    return exp.compile().run(checkpoint=checkpoint,
                             max_segments=max_segments)


def run_sweep(spec, members, *, engine='fleet', exec_kw=None):
    ex = api.ExecSpec(engine=engine, eval_every=EVAL_EVERY,
                      **(exec_kw or {}))
    exp = api.Experiment(shared_task(), fresh_env(), spec, ex,
                         rounds=ROUNDS)
    return exp.compile().run_sweep(members)


def assert_tree_equal(a, b, context: str = ''):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), f'{context}: tree structures differ'
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f'{context}: leaf {i} differs')


def assert_history_equal(ha, hb, context: str = ''):
    """Full-run identity: final model bit-equality, identical eval
    trajectories, and identical host event records."""
    assert_tree_equal(ha.final_global, hb.final_global,
                      f'{context}: final_global')
    assert ha.evals() == hb.evals(), f'{context}: eval trajectories differ'
    ra = [dataclasses.asdict(r) for r in ha.records]
    rb = [dataclasses.asdict(r) for r in hb.records]
    assert ra == rb, f'{context}: round records differ'
