"""Model substrate correctness: attention paths, decode==forward, MoE, etc."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.model import build_model


def tiny(family='dense', **kw):
    base = dict(arch_id=f'tiny-{family}', family=family, n_layers=2,
                d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=128,
                dtype=jnp.float32, remat=False, q_block=8, kv_block=8,
                vocab_pad_multiple=64)
    if family == 'ssm':
        base.update(n_heads=0, n_kv_heads=0, d_ff=0, ssm_state=16,
                    ssm_headdim=32, ssm_chunk=8)
    if family == 'hybrid':
        base.update(ssm_state=16, ssm_headdim=32, ssm_chunk=8, attn_every=1,
                    n_kv_heads=4)
    if family == 'vlm':
        base.update(n_patches=4)
    if family == 'audio':
        base.update(enc_layers=2, enc_seq=8, mlp_kind='gelu')
    base.update(kw)
    return ModelConfig(**base)


def make_batch(cfg, key, B=2, S=12):
    kt, kl = jax.random.split(key)
    batch = {'tokens': jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             'labels': jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.family == 'vlm':
        batch['patch_embeds'] = 0.1 * jax.random.normal(
            kt, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == 'audio':
        batch['frame_embeds'] = 0.1 * jax.random.normal(
            kt, (B, cfg.enc_seq, cfg.d_model))
    return batch


class TestAttention:
    @pytest.mark.parametrize('window', [None, 5])
    @pytest.mark.parametrize('gqa', [1, 2, 4])
    def test_flash_matches_naive(self, window, gqa):
        key = jax.random.PRNGKey(0)
        B, S, H, D = 2, 37, 4, 16
        KH = H // gqa
        q = jax.random.normal(key, (B, S, H, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KH, D))
        out = attn_mod.flash_attention(q, k, v, causal=True, window=window,
                                       q_block=8, kv_block=8)
        ref = attn_mod.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_noncausal(self):
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (1, 20, 2, 8))
        k = jax.random.normal(key, (1, 14, 2, 8))
        v = jax.random.normal(jax.random.PRNGKey(4), (1, 14, 2, 8))
        out = attn_mod.flash_attention(
            q, k, v, causal=False, q_block=8, kv_block=8,
            q_positions=jnp.arange(20), k_positions=jnp.arange(14))
        ref = attn_mod.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize('family', ['dense', 'moe', 'ssm', 'hybrid', 'vlm',
                                    'audio'])
class TestDecodeMatchesForward:
    def test_prefill_equals_forward(self, family):
        """Token-by-token decode must reproduce the parallel forward logits
        (teacher forcing) — validates caches, positions and RoPE offsets."""
        cfg = tiny(family, n_experts=4 if family == 'moe' else 0,
                   capacity_factor=8.0 if family == 'moe' else 1.25)
        model = build_model(cfg)
        key = jax.random.PRNGKey(7)
        params = model.init(key)
        B, S = 2, 10
        batch = make_batch(cfg, key, B, S)

        full_logits, _ = model.logits(params, batch)

        cache = model.init_cache(B, S)
        if family == 'audio':
            # encoder K/V must be precomputed into the cache
            from repro.models import transformer as tfm
            from repro.models import common as cm
            frames = batch['frame_embeds'].astype(cfg.dtype) + params['enc_pos'][None]
            enc, _ = tfm.run_dense_stack(params['enc_layers'], frames, cfg,
                                         causal=False)
            enc = cm.rms_norm(enc, params['enc_ln_f'])
            xks, xvs = [], []
            layers = params['dec_layers']
            for i in range(cfg.n_layers):
                layer = jax.tree.map(lambda a, j=i: a[j], layers)
                kk, vv = tfm.project_enc_kv(layer['xattn'], enc, cfg)
                xks.append(kk)
                xvs.append(vv)
            cache['xk'] = jnp.stack(xks)
            cache['xv'] = jnp.stack(xvs)
        if family == 'vlm':
            pytest.skip('vlm decode serves text-only continuation; '
                        'patch context covered by smoke test')

        cache, step_logits = model.prefill(params, cache, batch['tokens'])
        if family == 'moe':
            # top-1 routing makes the comparison discontinuous: fp-rounding
            # differences between the blocked and step-by-step paths can
            # flip near-tie argmax routing for individual tokens, which then
            # cascades through attention.  Require the bulk of positions to
            # match instead of every element.
            a, b = np.asarray(step_logits), np.asarray(full_logits)
            close = np.isclose(a, b, atol=3e-3, rtol=1e-2).mean()
            assert close > 0.95, f'only {close:.2%} of logits match'
        else:
            np.testing.assert_allclose(np.asarray(step_logits),
                                       np.asarray(full_logits),
                                       atol=3e-4, rtol=2e-3)


class TestSlidingWindowDecode:
    def test_ring_buffer_matches_full_recompute(self):
        """Decode with a ring-buffer window cache == full forward with the
        same window mask, for a prompt longer than the window."""
        cfg = tiny('dense', window=4)
        model = build_model(cfg)
        key = jax.random.PRNGKey(9)
        params = model.init(key)
        B, S = 1, 11  # prompt ~3x window
        batch = make_batch(cfg, key, B, S)
        full_logits, _ = model.logits(params, batch)
        cache = model.init_cache(B, S)  # ring buffer: window slots only
        assert cache['k'].shape[3 - 1] == cfg.window  # S dim == window
        cache, step_logits = model.prefill(params, cache, batch['tokens'])
        np.testing.assert_allclose(np.asarray(step_logits),
                                   np.asarray(full_logits),
                                   atol=3e-4, rtol=2e-3)


class TestMoE:
    def test_mass_conservation_and_shapes(self):
        key = jax.random.PRNGKey(11)
        p = cm.unbox(moe_mod.init_moe(key, 32, 64, 4, jnp.float32, shared_expert=False))[0]
        x = jax.random.normal(key, (2, 16, 32))
        y, aux = moe_mod.apply_moe(p, x, capacity_factor=2.0)
        assert y.shape == x.shape
        assert float(aux['dropped_frac']) <= 0.5
        assert float(aux['load_balance_loss']) >= 0.99  # >= 1 at balance

    def test_high_capacity_keeps_all_tokens(self):
        key = jax.random.PRNGKey(12)
        p = cm.unbox(moe_mod.init_moe(key, 16, 32, 2, jnp.float32, shared_expert=False))[0]
        x = jax.random.normal(key, (1, 8, 16))
        _, aux = moe_mod.apply_moe(p, x, capacity_factor=8.0)
        assert float(aux['dropped_frac']) == pytest.approx(0.0, abs=1e-6)

    def test_grad_flows(self):
        key = jax.random.PRNGKey(13)
        p = cm.unbox(moe_mod.init_moe(key, 16, 32, 2, jnp.float32))[0]
        x = jax.random.normal(key, (1, 8, 16))
        g = jax.grad(lambda pp: jnp.sum(moe_mod.apply_moe(pp, x)[0] ** 2))(p)
        norms = [float(jnp.abs(l).sum()) for l in jax.tree.leaves(g)]
        assert all(np.isfinite(norms))
        assert sum(norms) > 0


class TestQKNormAndVariants:
    @pytest.mark.parametrize('kw', [dict(qk_norm=True),
                                    dict(mlp_kind='relu2'),
                                    dict(window=6),
                                    dict(rope_theta=1e6)])
    def test_variants_train_step(self, kw):
        cfg = tiny('dense', **kw)
        model = build_model(cfg)
        key = jax.random.PRNGKey(15)
        params = model.init(key)
        batch = make_batch(cfg, key)
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        assert np.isfinite(float(loss))
        gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0
