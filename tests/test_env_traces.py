"""Trace-driven heterogeneity contracts (``fedsim.EnvSpec`` + traces).

Four families of invariants:

* **Golden shim** — ``FLEnv(...)`` and ``EnvSpec(...).build()`` (and the
  constant-trace variant) produce bit-identical runs for every protocol
  in the ``api.PROTOCOLS`` registry, so the deprecation is a spelling
  change, not a behaviour change.
* **Stream preservation** — ``draw_rounds`` consumes the rng exactly as
  sequential ``draw_round`` calls would; availability traces raise the
  crash *threshold* without touching the uniforms.
* **Trace semantics** — availability 0 forces a crash, bandwidth
  scaling moves comm times monotonically, generators are deterministic
  in their own seeds (the randomised hypothesis forms live in
  ``tests/test_env_trace_properties.py``; this module must run in a
  bare environment).
* **Wire-derived comm** — under ``EnvSpec(comm='wire')`` the int8 and
  f32 wires ship different byte counts, so round lengths AND FedCS
  selections genuinely differ end-to-end.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import conformance as C
from repro import api
from repro.core import federation
from repro.fedsim import (
    ConstantTrace,
    DayNight,
    DeviceClass,
    DeviceClasses,
    EnvSpec,
    FLEnv,
    MarkovChurn,
    Replay,
    env_grid,
)

BASE = EnvSpec(seed=C.ENV_SEED, **C.BASE_ENV)


def legacy_env() -> FLEnv:
    with warnings.catch_warnings():
        warnings.simplefilter('ignore', DeprecationWarning)
        return FLEnv(seed=C.ENV_SEED, **C.BASE_ENV)


def run_on_env(spec, env):
    ex = api.ExecSpec(eval_every=C.EVAL_EVERY)
    exp = api.Experiment(C.shared_task(), env, spec, ex, rounds=C.ROUNDS)
    return exp.compile().run()


# ---------------------------------------------------------------------------
# golden shim: FLEnv == EnvSpec.build() == constant traces, all protocols
# ---------------------------------------------------------------------------

@pytest.mark.parametrize('name', sorted(p.name for p in api.PROTOCOLS.values()))
def test_flenv_shim_bit_identical(name):
    pdef = next(p for p in api.PROTOCOLS.values() if p.name == name)
    ref = run_on_env(pdef.spec_cls(), legacy_env())
    new = run_on_env(pdef.spec_cls(), BASE.build())
    C.assert_history_equal(ref, new, f'{name}: FLEnv vs EnvSpec.build()')
    # the declarative spelling (api builds the env) is the same run too
    decl = run_on_env(pdef.spec_cls(), BASE)
    C.assert_history_equal(ref, decl, f'{name}: FLEnv vs declarative EnvSpec')


@pytest.mark.parametrize('name', sorted(p.name for p in api.PROTOCOLS.values()))
def test_constant_traces_bit_identical(name):
    """A no-op trace bundle must not perturb anything: the trace-aware
    precompute path reproduces the static path bit for bit."""
    pdef = next(p for p in api.PROTOCOLS.values() if p.name == name)
    ref = run_on_env(pdef.spec_cls(), BASE.build())
    traced = run_on_env(pdef.spec_cls(),
                        BASE.replace(traces=ConstantTrace()).build())
    C.assert_history_equal(ref, traced, f'{name}: constant traces')


def test_flenv_warns_deprecation():
    with pytest.warns(DeprecationWarning, match='FLEnv is deprecated'):
        FLEnv(seed=C.ENV_SEED, **C.BASE_ENV)


# ---------------------------------------------------------------------------
# rng stream preservation
# ---------------------------------------------------------------------------

def test_draw_rounds_matches_sequential_draw_round():
    seq = BASE.build()
    pairs = [seq.draw_round() for _ in range(C.ROUNDS)]
    bulk = BASE.build().draw_rounds(C.ROUNDS)
    np.testing.assert_array_equal(bulk[0], np.stack([p[0] for p in pairs]))
    np.testing.assert_array_equal(bulk[1], np.stack([p[1] for p in pairs]))


def test_constant_traces_preserve_draw_stream():
    """Traces modulate only the comparison threshold, never the uniform
    draws — availability 1 everywhere keeps the legacy masks exactly."""
    ref = BASE.build().draw_rounds(C.ROUNDS)
    traced = BASE.replace(traces=ConstantTrace()).build().draw_rounds(C.ROUNDS)
    np.testing.assert_array_equal(ref[0], traced[0])
    np.testing.assert_array_equal(ref[1], traced[1])


def test_draw_seed_gives_independent_crash_histories_same_population():
    """The fleet contract: a multi-stream sweep shares one population
    (partitions, perf) while each member sees its own failure history."""
    envs = [BASE.replace(draw_seed=k).build() for k in range(3)]
    for e in envs[1:]:
        np.testing.assert_array_equal(envs[0].partition_sizes,
                                      e.partition_sizes)
        np.testing.assert_array_equal(envs[0].perf, e.perf)
    masks = [e.draw_rounds(C.ROUNDS)[0] for e in envs]
    for i in range(len(masks)):
        for j in range(i + 1, len(masks)):
            assert not np.array_equal(masks[i], masks[j]), (i, j)


# ---------------------------------------------------------------------------
# trace semantics (deterministic forms; randomised hypothesis variants in
# tests/test_env_trace_properties.py)
# ---------------------------------------------------------------------------

def test_availability_zero_forces_crash():
    rounds = 8
    a = np.random.default_rng(7).integers(0, 2, (rounds, C.M)).astype(float)
    env = BASE.replace(traces=Replay(availability=a)).build()
    crashed, _ = env.draw_rounds(rounds)
    assert crashed[a == 0.0].all()


def test_bandwidth_scaling_monotone_in_comm_time():
    rounds = 8
    bw = np.random.default_rng(7).uniform(0.25, 4.0, (rounds, C.M))
    slow = BASE.replace(traces=Replay(bandwidth=bw)).build()
    fast = BASE.replace(traces=Replay(bandwidth=bw * 3.0)).build()
    ts, tf = slow.round_timing(rounds), fast.round_timing(rounds)
    assert np.all(tf.t_up < ts.t_up)
    assert np.all(tf.t_down < ts.t_down)
    np.testing.assert_array_equal(tf.full_tt, ts.full_tt)


def test_speed_scaling_monotone_in_train_time():
    rounds = 8
    sp = np.random.default_rng(7).uniform(0.25, 4.0, (rounds, C.M))
    env = BASE.replace(traces=Replay(speed=sp)).build()
    faster = BASE.replace(traces=Replay(speed=sp * 3.0)).build()
    assert np.all(faster.round_timing(rounds).full_tt
                  < env.round_timing(rounds).full_tt)


def test_generators_deterministic_and_shaped():
    rounds, m = 12, 7
    for gen in (DayNight(period=5, seed=4),
                MarkovChurn(p_off=0.3, p_on=0.5, seed=4),
                DeviceClasses((DeviceClass('a', speed=2.0),
                               DeviceClass('b', bandwidth=0.5)))):
        t1, t2 = gen.realize(rounds, m), gen.realize(rounds, m)
        for f in ('availability', 'bandwidth', 'speed'):
            a1, a2 = getattr(t1, f), getattr(t2, f)
            assert a1.shape == (rounds, m), (gen, f)
            np.testing.assert_array_equal(a1, a2)
        assert t1.availability.min() >= 0.0 and t1.availability.max() <= 1.0
        assert t1.bandwidth.min() > 0.0 and t1.speed.min() > 0.0


def test_device_classes_largest_remainder_split():
    dc = DeviceClasses((DeviceClass('fast', speed=2.0),
                        DeviceClass('mid'),
                        DeviceClass('slow', speed=0.5)),
                       mix=(0.5, 0.3, 0.2))
    labels = dc.assignments(10)
    assert labels.tolist() == [0] * 5 + [1] * 3 + [2] * 2
    # remainders go to the largest fractional parts, population exact
    assert len(dc.assignments(7)) == 7


def test_replay_validation():
    with pytest.raises(ValueError, match=r'availability trace must lie in'):
        BASE.replace(traces=Replay(availability=np.full((2, C.M), 1.5))
                     ).build().draw_rounds(2)
    with pytest.raises(ValueError):
        BASE.replace(traces=Replay(bandwidth=np.zeros((2, C.M)))
                     ).build().round_timing(2)


# ---------------------------------------------------------------------------
# EnvSpec validation (check_compat golden messages)
# ---------------------------------------------------------------------------

def test_check_compat_validates_env_spec():
    sp = api.SafaSpec()
    with pytest.raises(ValueError, match=r'm must be >= 1, got 0'):
        api.check_compat(sp, env=BASE.replace(m=0))
    with pytest.raises(ValueError,
                       match=r'crash_prob must be in \[0, 1\], got 1.5'):
        api.check_compat(sp, env=BASE.replace(crash_prob=1.5))
    with pytest.raises(ValueError,
                       match=r"unknown comm 'carrier-pigeon' \(want "
                             r"'static' or 'wire'\)"):
        api.check_compat(sp, env=BASE.replace(comm='carrier-pigeon'))
    with pytest.raises(TypeError, match=r'traces must be a fedsim TraceSpec'):
        api.check_compat(sp, env=BASE.replace(traces=123))


def test_wire_comm_needs_a_task():
    with pytest.raises(ValueError, match=r'no Task to measure'):
        api.Experiment(None, BASE.replace(comm='wire'), api.SafaSpec(),
                       api.ExecSpec(numeric=False), rounds=C.ROUNDS)


# ---------------------------------------------------------------------------
# env_grid + member env overrides
# ---------------------------------------------------------------------------

def test_env_grid_on_specs_row_major():
    specs = env_grid(BASE, crash_prob=(0.1, 0.7), draw_seed=(0, 1, 2))
    assert [s.crash_prob for s in specs] == [0.1] * 3 + [0.7] * 3
    assert [s.draw_seed for s in specs] == [0, 1, 2, 0, 1, 2]
    assert all(isinstance(s, EnvSpec) for s in specs)


def test_member_env_overrides_mix_scenarios_in_one_sweep():
    """One fleet dispatch, members differing only through EnvSpec-field
    overrides — each member's history matches its own single run."""
    churn = MarkovChurn(p_off=0.3, p_on=0.5, seed=0)
    members = [
        api.SweepMember(env=BASE, fraction=0.5, lag_tolerance=5),
        api.SweepMember(env=BASE, fraction=0.5, lag_tolerance=5,
                        overrides={'crash_prob': 0.7}),
        api.SweepMember(env=BASE, fraction=0.5, lag_tolerance=5,
                        overrides={'traces': churn}),
    ]
    hists = C.run_sweep(api.SafaSpec(), members)
    singles = [run_on_env(api.SafaSpec(), BASE),
               run_on_env(api.SafaSpec(), BASE.replace(crash_prob=0.7)),
               run_on_env(api.SafaSpec(), BASE.replace(traces=churn))]
    for i, (h, s) in enumerate(zip(hists, singles)):
        C.assert_history_equal(h, s, f'member {i}')


def test_env_override_messages_are_golden():
    exp = api.Experiment(C.shared_task(), BASE, api.SafaSpec(),
                         api.ExecSpec(eval_every=C.EVAL_EVERY),
                         rounds=C.ROUNDS).compile()
    with pytest.raises(ValueError,
                       match=r"unknown member override keys \['bogus'\]; "
                             r"protocol 'safa' takes env-field overrides "
                             r"only"):
        exp.run_sweep([api.SweepMember(env=BASE, overrides={'bogus': 1})])
    with pytest.raises(ValueError,
                       match=r"member override keys \['crash_prob'\] are "
                             r"EnvSpec fields; env overrides need a "
                             r"declarative member env"):
        exp.run_sweep([api.SweepMember(env=BASE.build(),
                                       overrides={'crash_prob': 0.5})])


# ---------------------------------------------------------------------------
# wire-derived comm: the int8 wire changes the event stream
# ---------------------------------------------------------------------------

WIRED = BASE.replace(comm='wire', client_bw_mbps=2e-4,
                     traces=Replay(bandwidth=np.linspace(0.5, 2.0, C.M)))


def _wire_run(spec_cls, wire):
    ex = api.ExecSpec(eval_every=C.EVAL_EVERY, wire=wire)
    exp = api.Experiment(C.shared_task(), WIRED, spec_cls, ex,
                         rounds=C.ROUNDS)
    return exp.compile().run()


def test_wire_layout_changes_round_lengths():
    """With comm='wire' and a bandwidth trace active, the f32 and int8
    wires ship different byte counts — round lengths must differ."""
    for spec in (api.SafaSpec(), api.FedAvgSpec(), api.FedCSSpec()):
        f32 = _wire_run(spec, 'f32')
        q8 = _wire_run(spec, 'int8')
        rl_f32 = [r.round_len for r in f32.records]
        rl_q8 = [r.round_len for r in q8.records]
        assert rl_f32 != rl_q8, type(spec).__name__


def test_wire_layout_changes_fedcs_selection():
    """FedCS picks fastest-first under the deadline from wire-derived
    comm estimates, so the wire layout shifts *who is selected*."""
    from repro.core.api import _wire_mb_of
    task = C.shared_task()
    masks = {}
    for wire in ('f32', 'int8'):
        env = WIRED.replace(t_lim=90.0).build()
        env.set_wire_mb(*_wire_mb_of(task, wire))
        sched = federation.precompute_sync_schedule(
            env, fraction=0.5, rounds=C.ROUNDS, seed=0, fedcs=True)
        masks[wire] = sched.selected
    assert not np.array_equal(masks['f32'], masks['int8'])


def test_wire_static_unaffected_by_exec_wire():
    """comm='static' keeps the paper's model_size_mb constant: the exec
    wire changes numerics, never the event process."""
    sched = {}
    for wire in ('f32', 'int8'):
        h = run_on_env(api.SafaSpec(), BASE) if wire == 'f32' else None
        ex = api.ExecSpec(eval_every=C.EVAL_EVERY, wire=wire)
        exp = api.Experiment(C.shared_task(), BASE, api.SafaSpec(), ex,
                             rounds=C.ROUNDS)
        sched[wire] = [dataclasses.replace(r, eval=None)
                       for r in exp.compile().run().records]
    assert sched['f32'] == sched['int8']
