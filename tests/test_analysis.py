"""Mutation tests for ``repro.analysis``: every rule must FIRE on a
seeded broken fixture, and the real registry must pass CLEAN.

A static checker that never fails is indistinguishable from one that
never runs, so each rule here gets a deliberately-broken input — a
corrupted schedule, a registry def with a wrong budget, a source tree
with the exact smell the AST rule hunts — and the test asserts that rule
(and only that rule is asserted; collateral findings are fine) reports
the violation.  The clean-side tests pin the pass/fail boundary from the
other side: conventions and schedule passes green over the whole
registry, and the flagship compressed-SAFA jaxpr cells green under their
declared 2-dispatch budget.
"""
import copy
import dataclasses
import itertools
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro import analysis, api, fedsim
from repro.analysis import jaxpr_checks
from repro.analysis.conventions import check_conventions
from repro.core import agg_schemes, federation, protocol

ROUNDS = 8
ENV = dict(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
           t_lim=830.0)


def fresh_env(seed=3):
    return fedsim.EnvSpec(seed=seed, **ENV).build()


def safa_schedule(form='dense'):
    return federation.precompute_safa_schedule(
        fresh_env(), fraction=0.5, lag_tolerance=2, rounds=ROUNDS,
        form=form)


def failed_rules(report):
    return {f.rule for f in report.failures}


# ---------------------------------------------------------------------------
# Clean side: the real registry passes
# ---------------------------------------------------------------------------

class TestRegistryClean:
    def test_conventions_pass(self):
        rep = check_conventions()
        assert rep.ok, '\n'.join(str(f) for f in rep.failures)

    def test_schedules_pass(self):
        rep = analysis.check_schedules()
        assert rep.ok, '\n'.join(str(f) for f in rep.failures)
        # every schedule rule actually ran against some subject
        assert {'SCH001', 'SCH002', 'SCH003', 'SCH004', 'SCH005',
                'SCH006'} <= rep.rules()

    def test_flagship_compressed_cells_pass(self):
        # the "fully compressed SAFA round is exactly 2 dispatches"
        # invariant, proven on the lowered programs of both engines
        pdef = api.PROTOCOLS[api.SafaSpec]
        cells = [
            jaxpr_checks.Cell(pdef, api.SafaSpec(), api.ExecSpec(
                engine=engine, wire='int8', use_kernel='packed',
                schedule='dense', eval_every=jaxpr_checks.SEG))
            for engine in ('scan', 'fleet')]
        assert all(pdef.dispatch_budget(c.ex) == 2 for c in cells)
        rep = jaxpr_checks.check_cells(cells=cells)
        assert rep.ok, '\n'.join(str(f) for f in rep.failures)
        assert {'JAX001', 'JAX002', 'JAX003', 'JAX004', 'JAX005',
                'JAX006'} <= rep.rules()


# ---------------------------------------------------------------------------
# SCH rules: corrupted schedules
# ---------------------------------------------------------------------------

class TestScheduleMutations:
    def test_sch004_role_subset_violation_fires(self):
        sched = safa_schedule()
        t, k = next((t, k) for t in range(ROUNDS) for k in range(ENV['m'])
                    if not sched.committed[t, k])
        sched.picked[t, k] = True       # picked but never committed
        assert 'SCH004' in failed_rules(analysis.verify_schedule(sched))

    def test_sch004_lag_bound_fires(self):
        sched = safa_schedule()
        # never sync, never commit: every client's version pins at 0 and
        # staleness grows past any tau (other masks cleared so the
        # subset structure stays valid and only the lag bound trips)
        for mask in (sched.sync, sched.committed, sched.picked,
                     sched.undrafted, sched.deprecated):
            mask[:] = False
        rep = analysis.verify_schedule(sched, lag_tolerance=2)
        assert 'SCH004' in failed_rules(rep)
        assert any('staleness' in f.detail for f in rep.failures)

    def test_sch006_unsorted_indices_fire(self):
        sched = safa_schedule(form='sparse')
        t = next(t for t in range(ROUNDS)
                 if (sched.idx[t] < sched.m).sum() >= 2)
        sched.idx[t, [0, 1]] = sched.idx[t, [1, 0]]
        assert 'SCH006' in failed_rules(analysis.verify_schedule(sched))

    def test_sch003_live_sentinel_fires(self):
        sched = safa_schedule(form='sparse')
        t = next(t for t in range(ROUNDS)
                 if (sched.idx[t] >= sched.m).any())
        sched.roles[t, -1] = protocol.ROLE_PICKED   # sentinel grows a role
        assert 'SCH003' in failed_rules(analysis.verify_schedule(sched))

    def test_sch001_read_write_clash_fires(self):
        sched = safa_schedule(form='sparse_tier')
        t, j = next(
            (t, j) for t in range(ROUNDS) for j in range(sched.width)
            if sched.global_dst[t] != sched.scratch
            and sched.idx[t, j] < sched.m
            and sched.cache_src[t, j] != sched.scratch)
        # the round's global write now also feeds a cache read: in-place
        # aliasing would clobber the row mid-kernel
        sched.cache_src[t, j] = sched.global_dst[t]
        assert 'SCH001' in failed_rules(analysis.verify_schedule(sched))

    def test_sch002_padded_capacity_fires(self):
        sched = copy.deepcopy(safa_schedule(form='sparse_tier'))
        old_scratch = sched.scratch
        sched.capacity += 1             # claim one dead row
        for arr in (sched.base_src, sched.cache_src, sched.cache_dst):
            arr[arr == old_scratch] = sched.scratch
        sched.global_dst[sched.global_dst == old_scratch] = sched.scratch
        rep = analysis.verify_schedule(sched)
        assert 'SCH002' in failed_rules(rep)

    def test_sch005_negative_weight_fires(self):
        sched = agg_schemes.precompute_weighted_schedule(
            fresh_env(), rounds=ROUNDS, scheme='seafl')
        t, k = next((t, k) for t in range(ROUNDS) for k in range(ENV['m'])
                    if sched.committed[t, k])
        sched.wrow[t, k] = -0.1
        assert 'SCH005' in failed_rules(analysis.verify_schedule(sched))

    def test_sch005_async_order_fires(self):
        sched = federation.precompute_fedasync_schedule(
            fresh_env(), rounds=ROUNDS)
        sched.order[0, 0] = sched.order[0, 1]   # no longer a permutation
        assert 'SCH005' in failed_rules(analysis.verify_schedule(sched))


# ---------------------------------------------------------------------------
# REP rules: seeded source trees (and a poisoned registry for REP003)
# ---------------------------------------------------------------------------

def fixture_root(tmp_path, files=None):
    """Minimal tree ``check_conventions`` can walk: the paths REP002
    scans unconditionally, plus the seeded broken ``files``."""
    (tmp_path / 'tests').mkdir()
    (tmp_path / 'src/repro/kernels').mkdir(parents=True)
    (tmp_path / 'src/repro/core').mkdir(parents=True)
    (tmp_path / 'src/repro/core/protocol.py').write_text('')
    for rel, text in (files or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


class TestConventionMutations:
    def test_rep001_uncovered_spec_fires(self, tmp_path):
        # a tests tree with no pytest.raises+check_compat golden module:
        # every registered spec type is uncovered
        rep = check_conventions(fixture_root(tmp_path))
        bad = [f for f in rep.failures if f.rule == 'REP001']
        assert {f.subject for f in bad} \
            == {cls.__name__ for cls in api.PROTOCOLS}

    def test_rep002_np_random_in_round_math_fires(self, tmp_path):
        root = fixture_root(tmp_path, {
            'src/repro/core/protocol.py': '''
                import numpy as np
                noise = np.random.rand(3)
            ''',
            'src/repro/kernels/bad.py': '''
                import jax.numpy as jnp
                ACC = jnp.float64
            ''',
        })
        bad = [f for f in check_conventions(root).failures
               if f.rule == 'REP002']
        assert any('np.random' in f.detail for f in bad)
        assert any('float64' in f.detail for f in bad)

    def test_rep003_unfrozen_spec_fires(self, tmp_path):
        @dataclasses.dataclass          # NOT frozen (and can't subclass
        class MeltedSpec:               # the frozen ProtocolSpec base)
            fraction: float = 0.5

        pdef = dataclasses.replace(api.PROTOCOLS[api.SafaSpec],
                                   name='melted', spec_cls=MeltedSpec)
        api.register(pdef)
        try:
            rep = check_conventions(fixture_root(tmp_path))
            assert any(f.rule == 'REP003' and f.subject == 'MeltedSpec'
                       for f in rep.failures)
        finally:
            from repro.core import api as core_api
            del core_api.PROTOCOLS[MeltedSpec]
            del core_api._BY_NAME['melted']

    def test_rep004_silent_deprecation_fires(self, tmp_path):
        root = fixture_root(tmp_path, {
            'src/repro/shims.py': '''
                def run_old(x):
                    """Deprecated shim over run_new."""
                    return x
            ''',
        })
        bad = [f for f in check_conventions(root).failures
               if f.rule == 'REP004']
        assert any('run_old' in f.detail for f in bad)

    def test_rep004_protocol_lag_term_is_not_a_shim(self, tmp_path):
        # "deprecated" mid-docstring is SAFA's client lag state
        root = fixture_root(tmp_path, {
            'src/repro/lagmath.py': '''
                def classify(lag):
                    """Clients whose lag exceeds tau are deprecated."""
                    return lag
            ''',
        })
        assert not [f for f in check_conventions(root).failures
                    if f.rule == 'REP004']

    def test_rep005_uninventoried_kernel_fires(self, tmp_path):
        root = fixture_root(tmp_path, {
            'src/repro/kernels/rogue.py': '''
                from jax.experimental import pallas as pl

                def _rogue_kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...]

                def launch(x):
                    return pl.pallas_call(
                        _rogue_kernel,
                        input_output_aliases={0: 0},
                    )(x)
            ''',
        })
        bad = [f for f in check_conventions(root).failures
               if f.rule == 'REP005']
        assert any('ALIAS_CONTRACTS' in f.detail for f in bad)

    def test_rep005_undeclared_alias_form_fires(self, tmp_path):
        root = fixture_root(tmp_path, {
            'src/repro/kernels/sneaky.py': '''
                from jax.experimental import pallas as pl

                ALIAS_CONTRACTS = {'_sneaky_kernel': ((),)}

                def _sneaky_kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...]

                def launch(x):
                    return pl.pallas_call(
                        _sneaky_kernel,
                        input_output_aliases={0: 0},
                    )(x)
            ''',
        })
        bad = [f for f in check_conventions(root).failures
               if f.rule == 'REP005']
        assert any('not admitted' in f.detail for f in bad)

    def test_rep006_reused_built_env_fires(self, tmp_path):
        root = fixture_root(tmp_path, {
            'tests/test_reuse.py': '''
                from repro import api, fedsim

                def sweep_twice(runner, spec):
                    env = fedsim.EnvSpec(m=5).build()
                    a = runner.run_sweep(api.SweepSpec(
                        members=(api.SweepMember(env=env),)))
                    b = runner.run_sweep(api.SweepSpec(
                        members=(api.SweepMember(env=env),)))
                    return a, b
            ''',
        })
        bad = [f for f in check_conventions(root).failures
               if f.rule == 'REP006']
        assert any('single-shot' in f.detail for f in bad)


# ---------------------------------------------------------------------------
# JAX rules: wrong registrations and poisoned programs
# ---------------------------------------------------------------------------

def safa_cell(**exec_kw):
    pdef = api.PROTOCOLS[api.SafaSpec]
    kw = dict(engine='scan', schedule='dense', wire='f32',
              use_kernel=False, eval_every=jaxpr_checks.SEG)
    kw.update(exec_kw)
    return jaxpr_checks.Cell(pdef, api.SafaSpec(), api.ExecSpec(**kw))


class TestJaxprMutations:
    def test_jax001_wrong_budget_fires(self):
        cell = safa_cell(wire='int8', use_kernel='packed')
        fake = dataclasses.replace(cell.pdef,
                                   dispatch_budget=lambda ex: 99)
        rep = jaxpr_checks.check_cells(
            cells=[dataclasses.replace(cell, pdef=fake)])
        bad = [f for f in rep.failures if f.rule == 'JAX001']
        assert bad and 'budget 99' in bad[0].detail

    def test_jax002_dropped_donation_fires(self):
        # donated input has no same-shape output: XLA drops the donation
        inner = jax.jit(lambda a: jnp.zeros((3, 7), jnp.float32),
                        donate_argnums=(0,))
        j = jax.make_jaxpr(lambda a: inner(a))(jnp.ones((5,), jnp.float32))
        ok, detail = jaxpr_checks._check_donations(j.jaxpr)
        assert not ok and 'donat' in detail

    def test_jax002_effective_donation_passes(self):
        inner = jax.jit(lambda a: a * 2.0, donate_argnums=(0,))
        j = jax.make_jaxpr(lambda a: inner(a))(jnp.ones((5,), jnp.float32))
        ok, _ = jaxpr_checks._check_donations(j.jaxpr)
        assert ok

    def test_jax003_phantom_claim_fires(self):
        cell = safa_cell()
        fake = dataclasses.replace(
            cell.pdef, alias_claims=lambda ex: {'_ghost_kernel': ((0, 1),)})
        rep = jaxpr_checks.check_cells(
            cells=[dataclasses.replace(cell, pdef=fake)])
        bad = [f for f in rep.failures if f.rule == 'JAX003']
        assert bad and '_ghost_kernel' in bad[0].detail

    def test_jax004_f64_promotion_fires(self):
        with jax.experimental.enable_x64():
            j = jax.make_jaxpr(lambda x: jnp.sin(x))(
                jnp.asarray(1.0, jnp.float64))
        f64, _ = jaxpr_checks._check_dtypes_and_callbacks(j.jaxpr)
        assert f64 is not None and 'f64' in f64

    def test_jax005_callback_in_scan_body_fires(self):
        cell = safa_cell()
        orig = cell.pdef.scan_segment

        def noisy_segment(st, seg, w, train_fn, ex):
            def tf(*a, **kw):
                jax.debug.print('round')        # host sync per round
                return train_fn(*a, **kw)
            return orig(st, seg, w, tf, ex)

        fake = dataclasses.replace(cell.pdef, scan_segment=noisy_segment)
        rep = jaxpr_checks.check_cells(
            cells=[dataclasses.replace(cell, pdef=fake)])
        assert 'JAX005' in failed_rules(rep)

    def test_jax006_baked_constant_fires(self):
        cell = safa_cell()
        orig = cell.pdef.scan_segment
        counter = itertools.count()

        def drifting_segment(st, seg, w, train_fn, ex):
            orig(st, seg, w, train_fn, ex)
            # a fresh python constant per trace: the two consecutive
            # segment traces bake different literals
            drift = float(next(counter))
            st.global_w = jax.tree.map(lambda x: x + drift, st.global_w)

        fake = dataclasses.replace(cell.pdef, scan_segment=drifting_segment)
        rep = jaxpr_checks.check_cells(
            cells=[dataclasses.replace(cell, pdef=fake)])
        assert 'JAX006' in failed_rules(rep)


# ---------------------------------------------------------------------------
# Env rng single-shot guard (the runtime half of REP006)
# ---------------------------------------------------------------------------

class TestEnvRngGuard:
    def test_draw_rounds_is_single_shot(self):
        env = fresh_env()
        env.draw_rounds(3)
        with pytest.raises(RuntimeError, match='already consumed'):
            env.draw_rounds(3)

    def test_fresh_env_draws_again(self):
        a = fresh_env().draw_rounds(3)
        b = fresh_env().draw_rounds(3)
        assert (a[0] == b[0]).all() and (a[1] == b[1]).all()

    def test_draw_round_stays_unrestricted(self):
        env = fresh_env()
        env.draw_round()
        env.draw_round()                # legitimate per-round stream use
