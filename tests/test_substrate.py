"""Substrate tests: sharding rules, checkpointing, data pipeline, optim."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import checkpoint, optim
from repro import sharding as shd
from repro.data import (make_images, make_lm_tokens, make_regression,
                        make_svm, partition)
from repro.launch import mesh as mesh_lib


class TestShardingRules:
    def _mesh(self):
        # axis sizes 1x1 on CPU; divisibility logic tested via fake sizes
        return mesh_lib.make_local_mesh()

    def test_spec_drops_nondivisible(self):
        mesh = jax.make_mesh((1, 1), ('data', 'model'))
        # vocab divisible by 1 -> sharded on 'model'
        s = shd.spec_for(('vocab', 'embed'), (256, 64), mesh)
        assert s == P('model')
        s2 = shd.spec_for((None, 'mlp'), (4, 63), mesh)  # 63 % 1 == 0
        assert s2 == P(None, 'model')

    def test_missing_axis_dropped(self):
        mesh = jax.make_mesh((1, 1), ('data', 'model'))
        s = shd.spec_for(('clients', None), (8, 3), mesh)  # no 'pod' axis
        assert s == P('data')

    def test_no_axis_reuse(self):
        mesh = jax.make_mesh((1, 1), ('data', 'model'))
        s = shd.spec_for(('mlp', 'vocab'), (16, 256), mesh)
        # 'model' used by mlp; vocab falls back to replicated
        assert s == P('model')

    def test_constrain_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        assert shd.constrain(x, None, 'batch', None) is x


class TestCheckpoint:
    def test_roundtrip_with_protocol_state(self):
        tree = {
            'model': {'w': jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                      'b': jnp.ones(())},
            'cache': jnp.zeros((3, 2, 3)),
            'versions': jnp.array([1, 2, 3]),
        }
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, 'ckpt.npz')
            checkpoint.save(path, tree, {'round': 7, 'protocol': 'safa'})
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
            restored, meta = checkpoint.restore(path, like)
            assert meta == {'round': 7, 'protocol': 'safa'}
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestData:
    def test_partition_shapes_and_weights(self):
        x, y = make_regression(n=300, d=5)
        sizes = np.array([50, 100, 150])
        fd = partition(x, y, sizes, batch_size=10, seed=0)
        assert fd.x.shape[0] == 3 and fd.x.shape[2] == 10
        assert fd.x.shape[-1] == 5
        # partition sizes roughly proportional
        assert fd.partition_sizes[2] > fd.partition_sizes[0]

    def test_dirichlet_label_skew(self):
        x, y = make_images(n=600)
        fd = partition(x, y, np.full(6, 100), batch_size=10,
                       dirichlet_alpha=0.1, seed=0)
        # with alpha=0.1 most clients should be dominated by few classes
        fracs = []
        for c in range(6):
            labels = fd.y[c].reshape(-1)
            _, counts = np.unique(labels, return_counts=True)
            fracs.append(counts.max() / counts.sum())
        assert np.mean(fracs) > 0.4

    def test_svm_labels(self):
        x, y = make_svm(n=500)
        assert set(np.unique(y)) == {-1.0, 1.0}

    def test_lm_tokens_range(self):
        t = make_lm_tokens(n_docs=8, seq_len=16, vocab=32)
        assert t.shape == (8, 17)
        assert t.min() >= 0 and t.max() < 32


class TestOptim:
    def _quad_losses(self, opt, steps=200):
        params = {'w': jnp.array([3.0, -2.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(jnp.square(p['w']))
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        return float(loss(params))

    def test_sgd_converges(self):
        assert self._quad_losses(optim.sgd(0.1)) < 1e-6

    def test_sgd_momentum_converges(self):
        assert self._quad_losses(optim.sgd(0.05, momentum=0.9)) < 1e-6

    def test_adamw_converges(self):
        assert self._quad_losses(optim.adamw(0.1), steps=400) < 1e-4

    def test_clip_by_global_norm(self):
        g = {'a': jnp.full((3,), 10.0)}
        clipped, norm = optim.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(300), rel=1e-5)
        cn = np.sqrt(np.sum(np.square(np.asarray(clipped['a']))))
        assert cn == pytest.approx(1.0, rel=1e-4)


class TestHLOParse:
    def test_while_trip_count_multiplies(self):
        from repro.launch import hlo_parse
        hlo = '''HloModule test
%cond (x: (s32[])) -> pred[] {
  %c = s32[] constant(10)
}
%body (x: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%p), replica_groups={}
}
ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[128]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[64]) while(%t), condition=%cond, body=%body
}
'''
        res = hlo_parse.analyze_collectives(hlo)
        assert res['counts']['all-gather'] == 1
        assert res['counts']['all-reduce'] == 10        # x trip count
        assert res['bytes']['all-reduce'] == 10 * 64 * 4
        assert res['bytes']['all-gather'] == 128 * 4
