"""Property-based trace semantics over randomised ``EnvSpec``
configurations (hypothesis; the deterministic trace/env contracts live
in ``tests/test_env_traces.py``, which runs in a bare environment).

The schedule precomputes trust the realized trace bundles blindly, so
the invariants are pinned here at the env layer: availability 0 must
force a crash (the threshold reaches 1.0 and draws lie in [0, 1)),
bandwidth scaling must move comm times monotonically without touching
train times, and speed scaling the reverse.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.fedsim import EnvSpec, Replay

SETTINGS = dict(max_examples=20, deadline=None)

env_configs = st.fixed_dictionaries({
    'm': st.integers(2, 8),
    'crash_prob': st.floats(0.0, 0.9),
    'seed': st.integers(0, 2**16),
})


def spec_of(cfg, **kw) -> EnvSpec:
    return EnvSpec(dataset_size=506, batch_size=5, epochs=3, t_lim=830.0,
                   **cfg, **kw)


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       avail_seed=st.integers(0, 2**16))
def test_availability_zero_forces_crash(cfg, rounds, avail_seed):
    a = np.random.default_rng(avail_seed).integers(
        0, 2, (rounds, cfg['m'])).astype(float)
    env = spec_of(cfg, traces=Replay(availability=a)).build()
    crashed, _ = env.draw_rounds(rounds)
    assert crashed[a == 0.0].all()


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       scale=st.floats(1.1, 16.0))
def test_bandwidth_scaling_monotone_in_comm_time(cfg, rounds, scale):
    bw = np.random.default_rng(cfg['seed']).uniform(
        0.25, 4.0, (rounds, cfg['m']))
    slow = spec_of(cfg, traces=Replay(bandwidth=bw)).build()
    fast = spec_of(cfg, traces=Replay(bandwidth=bw * scale)).build()
    ts, tf = slow.round_timing(rounds), fast.round_timing(rounds)
    assert np.all(tf.t_up < ts.t_up)
    assert np.all(tf.t_down < ts.t_down)
    np.testing.assert_array_equal(tf.full_tt, ts.full_tt)


@settings(**SETTINGS)
@given(cfg=env_configs, rounds=st.integers(1, 8),
       scale=st.floats(1.1, 16.0))
def test_speed_scaling_monotone_in_train_time(cfg, rounds, scale):
    sp = np.random.default_rng(cfg['seed']).uniform(
        0.25, 4.0, (rounds, cfg['m']))
    env = spec_of(cfg, traces=Replay(speed=sp)).build()
    faster = spec_of(cfg, traces=Replay(speed=sp * scale)).build()
    assert np.all(faster.round_timing(rounds).full_tt
                  < env.round_timing(rounds).full_tt)
