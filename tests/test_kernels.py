"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.comm_quant import dequantize, quantize
from repro.kernels.safa_aggregate import safa_aggregate
from repro.kernels.swa_attention import swa_attention


class TestSafaAggregateKernel:
    @pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize('m,n,tile', [(3, 100, 64), (16, 4096, 1024),
                                          (5, 1, 128), (32, 777, 256)])
    def test_sweep(self, m, n, tile, dtype):
        key = jax.random.PRNGKey(m * n)
        ks = jax.random.split(key, 7)
        cache = jax.random.normal(ks[0], (m, n)).astype(dtype)
        trained = jax.random.normal(ks[1], (m, n)).astype(dtype)
        g = jax.random.normal(ks[2], (n,)).astype(dtype)
        picked = jax.random.bernoulli(ks[3], 0.4, (m,))
        undrafted = jax.random.bernoulli(ks[4], 0.4, (m,)) & ~picked
        dep = jax.random.bernoulli(ks[5], 0.3, (m,))
        w = jax.nn.softmax(jax.random.normal(ks[6], (m,)))
        ng, nc = safa_aggregate(cache, trained, g, picked, undrafted, dep, w,
                                tile=tile)
        rg, rc = ref.safa_aggregate_ref(cache, trained, g, picked, undrafted,
                                        dep, w)
        atol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(ng, np.float32),
                                   np.asarray(rg, np.float32), atol=atol)
        np.testing.assert_array_equal(np.asarray(nc), np.asarray(rc))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 24), st.integers(1, 3000), st.integers(0, 99))
    def test_property_random(self, m, n, seed):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 7)
        cache = jax.random.normal(ks[0], (m, n))
        trained = jax.random.normal(ks[1], (m, n))
        g = jax.random.normal(ks[2], (n,))
        picked = jax.random.bernoulli(ks[3], 0.5, (m,))
        undrafted = jax.random.bernoulli(ks[4], 0.5, (m,)) & ~picked
        dep = jax.random.bernoulli(ks[5], 0.5, (m,))
        w = jax.nn.softmax(jax.random.normal(ks[6], (m,)))
        ng, nc = safa_aggregate(cache, trained, g, picked, undrafted, dep, w,
                                tile=256)
        rg, rc = ref.safa_aggregate_ref(cache, trained, g, picked, undrafted,
                                        dep, w)
        np.testing.assert_allclose(np.asarray(ng), np.asarray(rg), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(nc), np.asarray(rc))


class TestCommQuantKernel:
    @pytest.mark.parametrize('n', [1, 127, 128, 1000, 4096, 10_001])
    def test_roundtrip_error_bound(self, n):
        x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 3.0
        q, s = quantize(x, tile=512)
        rq, rs = ref.quantize_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(rq))
        np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)
        xd = dequantize(q, s, n=n, tile=512)
        rd = ref.dequantize_ref(rq, rs, n)
        np.testing.assert_allclose(np.asarray(xd), np.asarray(rd), atol=1e-6)
        # int8 symmetric quantisation error <= scale/2 per block
        err = np.abs(np.asarray(xd - x))
        per_block_bound = np.repeat(np.asarray(rs) / 2 + 1e-7,
                                    128)[:n]
        assert np.all(err <= per_block_bound + 1e-6)

    def test_bf16_input(self):
        x = (jax.random.normal(jax.random.PRNGKey(5), (513,)) * 2).astype(jnp.bfloat16)
        q, s = quantize(x.astype(jnp.float32), tile=512)
        xd = dequantize(q, s, n=513, tile=512)
        assert np.all(np.isfinite(np.asarray(xd)))


class TestSWAKernel:
    @pytest.mark.parametrize('dtype', [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize('B,S,H,KH,D,win,bq,bk', [
        (1, 64, 2, 2, 16, None, 16, 16),
        (2, 100, 4, 2, 32, 17, 16, 16),
        (1, 33, 4, 1, 16, 8, 16, 16),
        (1, 128, 2, 2, 64, 32, 32, 32),
    ])
    def test_sweep(self, B, S, H, KH, D, win, bq, bk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(S + (win or 0)), 3)
        q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
        k = jax.random.normal(ks[1], (B, S, KH, D)).astype(dtype)
        v = jax.random.normal(ks[2], (B, S, KH, D)).astype(dtype)
        out = swa_attention(q, k, v, window=win, block_q=bq, block_k=bk)
        refo = ref.swa_attention_ref(q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), window=win)
        atol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(refo, np.float32), atol=atol)

    def test_matches_model_flash_path(self):
        """Kernel == the pure-jnp flash implementation used by the models."""
        from repro.models.attention import flash_attention
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 48, 4, 16))
        k = jax.random.normal(ks[1], (2, 48, 2, 16))
        v = jax.random.normal(ks[2], (2, 48, 2, 16))
        a = swa_attention(q, k, v, window=9, block_q=16, block_k=16)
        b = flash_attention(q, k, v, causal=True, window=9, q_block=16,
                            kv_block=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
