"""Paper Tables X / XII / XIV — best accuracy of the global model.

Numeric federated training on synthetic stand-in datasets (offline
container; DESIGN.md §6).  Task 1 runs at full paper scale; tasks 2/3 run
scaled-down by default (--full for paper scale — hours on 1 CPU core).

Every protocol's C-grid runs through the batched fleet engine
(``federation.run_sweep``, one vmapped-scan dispatch per protocol per
eval segment) — including local and fedasync, whose runners share the
scan/fleet engines since the every-protocol unification.
"""
from __future__ import annotations

from benchmarks.common import emit, make_env, sweep_members
from repro.core import federation
from repro.data import make_images, make_regression, make_svm, partition
from repro.data import tasks as task_mod

PROTOS = ('local', 'fedavg', 'fedcs', 'fedasync', 'safa')


def _bench(task_name, build, rounds, crs, cs, seed=0, scale=1.0):
    for cr in crs:
        env = make_env(task_name, cr, seed=seed, scale=scale)
        task = build(env)
        eval_every = max(2, rounds // 5)
        # the C grid is one fleet per protocol
        results = {}
        for proto in PROTOS:
            members = sweep_members(task_name, [(cr, C) for C in cs],
                                    seed=seed, scale=scale)
            hists = federation.run_sweep(task, members, rounds=rounds,
                                         proto=proto, eval_every=eval_every)
            results.update({(proto, C): h for C, h in zip(cs, hists)})
        for C in cs:
            for proto in PROTOS:
                h = results[(proto, C)]
                acc = h.best_eval['acc'] if h.best_eval else float('nan')
                emit(f'accuracy/{task_name}/{proto}/cr{cr}/C{C}',
                     f'{acc:.4f}',
                     f'loss={h.best_eval["loss"]:.4f};rounds={rounds}')


def run(full: bool = False, seed: int = 0):
    # Task 1: full paper scale (m=5)
    def build1(env):
        x, y = make_regression(n=env.dataset_size, seed=seed)
        data = partition(x, y, env.partition_sizes, env.batch_size, seed=seed)
        return task_mod.regression_task(data, lr=1e-3, epochs=env.epochs)
    _bench('task1_regression', build1, rounds=60 if not full else 100,
           crs=(0.1, 0.7), cs=(0.1, 0.3, 1.0), seed=seed)

    # Task 3: SVM, scaled m=50 by default
    def build3(env):
        x, y = make_svm(n=env.dataset_size, seed=seed)
        data = partition(x, y, env.partition_sizes, env.batch_size, seed=seed)
        return task_mod.svm_task(data, lr=1e-2, epochs=env.epochs)
    _bench('task3_svm', build3, rounds=25 if not full else 100,
           crs=(0.3,), cs=(0.1, 0.3), seed=seed,
           scale=1.0 if full else 0.1)

    # Task 2: CNN, small demo by default (convs are slow on 1 CPU core);
    # --full runs the paper-scale m=100 configuration
    def build2(env):
        x, y = make_images(n=env.dataset_size, seed=seed)
        data = partition(x, y, env.partition_sizes, env.batch_size,
                         dirichlet_alpha=None, seed=seed)
        return task_mod.cnn_task(data, lr=1e-3,
                                 epochs=env.epochs if full else 1)
    _bench('task2_cnn', build2, rounds=5 if not full else 50,
           crs=(0.3,), cs=(0.3,), seed=seed,
           scale=1.0 if full else 0.04)


if __name__ == '__main__':
    run()
