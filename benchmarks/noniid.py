"""Beyond-paper ablation: protocol robustness under non-IID (Dirichlet
label-skew) federated splits.  Standalone (CNN training is slow on 1 CPU
core): PYTHONPATH=src python -m benchmarks.noniid  (~5 min).

The paper's experiments use size-imbalanced but label-IID partitions; real
edge data is label-skewed.  Staleness-tolerant aggregation interacts with
client drift, so we sweep Dirichlet alpha on the image-classification task
and compare FedAvg vs SAFA best accuracy.
"""
from __future__ import annotations

from benchmarks.common import emit, make_env, run_protocol
from repro.data import make_images, partition
from repro.data.tasks import cnn_task


def run(rounds=4, seed=0):
    for alpha in (None, 1.0, 0.1):
        env = make_env('task2_cnn', cr=0.3, seed=seed, scale=0.02)
        x, y = make_images(n=env.dataset_size, seed=seed)
        data = partition(x, y, env.partition_sizes, env.batch_size,
                         dirichlet_alpha=alpha, seed=seed)
        task = cnn_task(data, lr=1e-3, epochs=1)
        tag = 'iid' if alpha is None else f'dirichlet{alpha}'
        for proto in ('fedavg', 'safa'):
            # fresh env per run: a built env's rng is single-shot
            h = run_protocol(proto,
                             make_env('task2_cnn', cr=0.3, seed=seed,
                                      scale=0.02),
                             0.5, rounds, task=task, eval_every=rounds)
            emit(f'noniid/{tag}/{proto}', f'{h.best_eval["acc"]:.4f}',
                 f'loss={h.best_eval["loss"]:.4f}')


if __name__ == '__main__':
    run()
