"""Kernel microbenchmarks: us/call for the Pallas kernels (interpret mode on
CPU — structural validation; real perf is a TPU measurement) vs their jnp
oracles, plus communication-compression byte accounting."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.kernels import ref
from repro.kernels.comm_quant import (QBLOCK, dequantize, dequantize_packed,
                                      quantize, quantize_packed)
from repro.kernels.ops import comm_bytes
from repro.kernels.safa_aggregate import safa_aggregate
from repro.kernels.swa_attention import swa_attention


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    with Timer() as t:
        for _ in range(reps):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
    return t.us / reps


def run():
    key = jax.random.PRNGKey(0)
    # --- safa_aggregate: m=16 silo clients, 1M params -----------------------
    m, n = 16, 1_000_000
    ks = jax.random.split(key, 7)
    cache = jax.random.normal(ks[0], (m, n))
    trained = jax.random.normal(ks[1], (m, n))
    g = jax.random.normal(ks[2], (n,))
    picked = jax.random.bernoulli(ks[3], 0.4, (m,))
    undrafted = jax.random.bernoulli(ks[4], 0.3, (m,)) & ~picked
    dep = jax.random.bernoulli(ks[5], 0.2, (m,))
    w = jax.nn.softmax(jax.random.normal(ks[6], (m,)))

    us_k = _time(safa_aggregate, cache, trained, g, picked, undrafted, dep, w)
    jref = jax.jit(ref.safa_aggregate_ref)
    us_r = _time(jref, cache, trained, g, picked, undrafted, dep, w)
    hbm_naive = (5 * m + 2) * n * 4   # 3-step: reads c,t,g x stages
    hbm_fused = (2 * m + 1 + m + 1) * n * 4
    emit('kernel/safa_aggregate/16x1M', f'{us_k:.0f}',
         f'jnp_ref_us={us_r:.0f};hbm_bytes_fused={hbm_fused};'
         f'hbm_bytes_3step={hbm_naive};traffic_saving='
         f'{hbm_naive / hbm_fused:.2f}x')

    # --- comm_quant ----------------------------------------------------------
    x = jax.random.normal(key, (4_000_000,))
    us_q = _time(quantize, x)
    q, s = quantize(x)
    us_d = _time(dequantize, q, s, n=x.shape[0])
    # ceiling form, matching ops.comm_bytes: one f32 scale per started block
    raw, wire = 4 * x.size, x.size + 4 * (-(-x.size // QBLOCK))
    # the packed wire format ships tile padding + full scale rows — report
    # both layouts so accounting matches what each path actually sends
    tree = {'x': x}
    wire_packed = comm_bytes(tree, quantized=True, layout='packed')
    raw_packed = comm_bytes(tree, quantized=False, layout='packed')
    emit('kernel/comm_quant/4M', f'{us_q:.0f}',
         f'dequant_us={us_d:.0f};wire_bytes_tree={wire};raw_bytes_tree={raw};'
         f'wire_bytes_packed={wire_packed};raw_bytes_packed={raw_packed};'
         f'compression_tree={raw / wire:.2f}x;'
         f'compression_packed={raw_packed / wire_packed:.2f}x')

    # --- quantize_packed: whole [m, N] upload buffer in one dispatch ---------
    m_q, n_q = 16, 1_048_576
    xp = jax.random.normal(key, (m_q, n_q))
    us_qp = _time(quantize_packed, xp)
    qp, sp = quantize_packed(xp)
    us_dp = _time(dequantize_packed, qp, sp)
    emit('kernel/quantize_packed/16x1M', f'{us_qp:.0f}',
         f'dequant_packed_us={us_dp:.0f};dispatches=1;'
         f'per_leaf_equivalent_dispatches={m_q}')

    # --- swa_attention (interpret mode: correctness-scale shapes) ------------
    B, S, H, KH, D = 1, 512, 4, 2, 64
    ks = jax.random.split(key, 3)
    q4 = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k4 = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v4 = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    for win in (None, 128):
        us = _time(swa_attention, q4, k4, v4, window=win, block_q=128,
                   block_k=128, reps=2)
        full_blocks = (S // 128) * (S // 128 + 1) // 2
        win_blocks = (S // 128) * 2 if win else full_blocks
        emit(f'kernel/swa_attention/S512_win{win}', f'{us:.0f}',
             f'kv_blocks_visited~{win_blocks};full_causal={full_blocks};'
             f'interpret_mode=True')


if __name__ == '__main__':
    run()
