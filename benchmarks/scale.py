"""Quota-bounded scale benchmark: million-client populations on one host.

    PYTHONPATH=src python -m benchmarks.scale [--smoke] [--xl] [--json F]

The tentpole claim of the sparse active-set schedules: at a *fixed
absolute quota* (``--quota``, default 50 clients/round), per-round
compiled cost and resident memory are functions of the quota, not of the
population size m.  This script sweeps m over decades while holding the
quota constant and reports, per (protocol, schedule) cell:

  * ``rounds_per_sec``  — the steady-state rate of the compiled scan
    engine: one warm full-segment dispatch on device-resident state, so
    per-run O(m) setup (state init, weights transfer) and host schedule
    precompute are excluded (the latter is reported as ``precompute_s``,
    the run-level rate including setup as ``rounds_per_sec_total``);
  * ``sched_mb`` / ``state_mb`` — deterministic nbytes accounting of the
    [rounds, K] event tensors and the device-resident model state;
  * ``vm_hwm_mb`` — the kernel's peak-RSS high-water mark.  In the default
    mode every cell runs in its own subprocess so the figure is an honest
    per-cell peak; under ``--smoke``/``--inproc`` cells share the process
    and the column is monotone (still an upper bound per cell).

Acceptance regime (see ISSUE/ROADMAP): ``rounds_per_sec`` flat within
~20% across m in {1e3, 1e4, 1e5}; ``--xl`` adds the m=1e6 cells — FedAvg
``sparse_delta`` (stateless O(d) carry) and SAFA ``sparse_tier`` (the
lag-tier value buffer: O((tau+quota)·d) resident state, so SAFA's
stateful protocol also runs at a million clients on one host).

``--guard`` is the CI memory-regression gate: it runs the m=1e5 SAFA
``sparse_tier`` cell in its own subprocess and fails if its per-cell
``vm_hwm_mb`` exceeds ``TIER_HWM_BUDGET_MB``.

The environment is tuned so the active set stays O(quota) as m grows:
``lag_tolerance >= rounds`` (no mass forced-sync of stale clients) and
``t_lim`` pinned to the ~2.5*quota-th fastest client's round time, so the
number of *completing* clients per round — which bounds SAFA's active set
— is quota-bounded by construction rather than O(m).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

QUOTA = 50          # fixed absolute quota (clients aggregated per round)
ROUNDS = 40
SMOKE_M = 10_000
M_GRID = (1_000, 10_000, 100_000)
XL_M = 1_000_000
D = 64              # model dimension (per-client state is D floats)

# (protocol, schedule) cells; ``max_m`` gates cells whose resident state
# is O(m * D) — at m=1e6 only the stateless fedavg delta engine runs.
CELLS = (
    ('fedavg', 'dense', 10_000),
    ('safa', 'dense', 10_000),
    ('fedavg', 'sparse', 100_000),
    ('safa', 'sparse', 100_000),
    ('fedavg', 'sparse_delta', None),       # stateless: O(D) carry
    ('safa', 'sparse_delta', 100_000),
    ('safa', 'sparse_tier', None),          # lag-tier: O((tau+quota)*D)
)

#: committed per-cell peak-RSS budget for the m=1e5 SAFA sparse_tier cell
#: (``--guard``).  The cell's honest subprocess HWM is dominated by the
#: jax/XLA runtime plus the O(m) host event machine; a reintroduced
#: [m, D] device stack at m=1e5 adds ~25 MB per copy and the engines keep
#: several live, so the budget is set with ~2.5x headroom over the
#: measured ~205 MB — tight enough that an O(m·D) state regression trips.
TIER_HWM_BUDGET_MB = 512.0


class ScaleTask:
    """Minimal rows-contract task with *index-derived* data: client k's
    target is a deterministic function of k, so the task itself holds no
    [m, ...] tensors and memory scales only with the model and the active
    set.  The train step is an elementwise pull toward the target, which
    makes ``local_train_rows`` trivially bit-identical to ``local_train``
    (the sparse==dense contract)."""

    def __init__(self, d: int = D, lr: float = 0.3):
        self.d, self.lr = d, lr

    def _targets(self, rows):
        import jax.numpy as jnp
        k = rows[:, None].astype(jnp.float32)
        j = jnp.arange(self.d, dtype=jnp.float32)[None, :]
        return jnp.sin(k * 0.7 + j * 0.13)

    def init_global(self, key):
        import jax
        return {'w': 0.01 * jax.random.normal(key, (self.d,),
                                              dtype='float32')}

    def local_train(self, stacked_params, round_idx):
        import jax.numpy as jnp
        m = stacked_params['w'].shape[0]
        rows = jnp.arange(m, dtype=jnp.int32)
        return self.local_train_rows(stacked_params, rows, round_idx)

    def local_train_rows(self, params_rows, rows, round_idx):  # noqa: ARG002
        p = params_rows['w']
        return {'w': p + self.lr * (self._targets(rows) - p)}

    def evaluate(self, global_params) -> dict:
        import jax.numpy as jnp
        t = self._targets(jnp.arange(256, dtype=jnp.int32))
        return {'loss': float(jnp.mean(
            (global_params['w'][None, :] - t) ** 2))}


def make_scale_env(m: int, quota: int, seed: int = 0, *,
                   bound_active: bool = True):
    """Environment for the quota-bounded regime.

    ``bound_active=True`` (SAFA) pins ``t_lim`` at the ~2.5*quota-th
    fastest client's training time, so the number of *completing* clients
    per round — which bounds SAFA's active set (committed + undrafted,
    plus last round's committed as sync) — is ~2.5*quota at every m.
    Communication terms are made negligible (``model_size_mb``) so the
    sync/non-sync arrival asymmetry cannot reopen the deadline to O(m)
    completions.  ``bound_active=False`` (FedAvg/FedCS, whose active set
    is the selection quota by construction) keeps a permissive deadline
    so selected clients actually complete."""
    from repro.fedsim import EnvSpec
    # crash_prob=0: a crashed straggler carries partial progress and can
    # slip under next round's deadline, so at crash_prob>0 the completing
    # population grows as O(crash_prob * m) — a protocol-faithful effect,
    # but this benchmark isolates the quota-bounded server path.
    spec = EnvSpec(m=m, crash_prob=0.0, dataset_size=20 * m, batch_size=10,
                   epochs=1, t_lim=1e9, seed=seed, model_size_mb=1e-3)
    env = spec.build()
    if not bound_active:
        return env
    base = env.t_updown + env.full_train_time()
    k = min(m - 1, int(round(2.5 * quota)))
    t_lim = float(np.partition(base, k)[k])
    return spec.replace(t_lim=t_lim).build()


def _vm_mb(field: str) -> float:
    try:
        with open('/proc/self/status') as f:
            for line in f:
                if line.startswith(field + ':'):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    return float('nan')


def _tree_nbytes(tree) -> int:
    import jax
    return sum(getattr(l, 'nbytes', 0)
               for l in jax.tree_util.tree_leaves(tree))


def _build(protocol: str, schedule: str, m: int, quota: int, rounds: int,
           seed: int):
    from repro import api
    env = make_scale_env(m, quota, seed=seed,
                         bound_active=(protocol == 'safa'))
    proto_kw = {'fraction': quota / m}
    if protocol == 'safa':
        # > any round count used here: no mass forced-sync of stale clients
        proto_kw['lag_tolerance'] = 10 * rounds
    if protocol == 'fedavg':
        proto_kw['sampler'] = 'topk'             # O(m) vectorised draw
    return api.Experiment(
        ScaleTask(), env, api.spec(protocol, **proto_kw),
        api.ExecSpec(engine='scan', schedule=schedule, eval_every=rounds),
        rounds=rounds, seed=seed)


def _timed_run(runner, reps: int = 3) -> float:
    """Best-of-``reps`` wall time of a fully warm ``run()``."""
    best = float('inf')
    for _ in range(reps):
        t0 = time.perf_counter()
        runner.run()
        best = min(best, time.perf_counter() - t0)
    return best


def _timed_segment(runner, reps: int = 5):
    """Best-of-``reps`` wall time of one warm full-segment scan dispatch
    on device-resident state — the steady-state compiled round, with no
    per-run O(m) setup in the measurement window.  The scan engines
    donate their carry, so repeated dispatches chain on the same state
    exactly as consecutive eval segments do in ``run()``.  Returns
    ``(seconds, state_nbytes)``; the state-bytes figure is taken from
    the same prepared state the timing uses."""
    import jax
    import jax.numpy as jnp
    from repro.core import api as _api
    exp = runner.exp
    ex = exp.exec
    st = _api._init_state(exp.task, exp.env.m, exp.seed,
                          runner._pdef.uses_cache, runner._stateless(ex))
    weights_j = jnp.asarray(exp.env.weights)
    if runner._pdef.prepare_state is not None:
        runner._pdef.prepare_state(st, weights_j, ex, False, exp.precompute())
    state_b = _tree_nbytes(st.tree())
    train_fn = runner._train_fn(exp.task)
    seg = jax.tree.map(lambda a: a[0:exp.rounds], runner._dev)
    runner._pdef.scan_segment(st, seg, weights_j, train_fn, ex)
    jax.block_until_ready(st.global_w)
    best = float('inf')
    for _ in range(reps):
        t0 = time.perf_counter()
        runner._pdef.scan_segment(st, seg, weights_j, train_fn, ex)
        jax.block_until_ready(st.global_w)
        best = min(best, time.perf_counter() - t0)
    return best, state_b


def run_cell(protocol: str, schedule: str, m: int, *, quota: int = QUOTA,
             rounds: int = ROUNDS, seed: int = 0) -> dict:
    """One (protocol, schedule, m) measurement; returns a result dict.

    ``rounds_per_sec`` times the compiled full-segment scan dispatch on
    warm device-resident state (``_timed_segment``) — the steady-state
    per-round cost the quota-bounded claim is about.  Per-run O(m) setup
    (state init, weights transfer) is excluded there and shows up in
    ``rounds_per_sec_total``, the plain R/wall rate of a full ``run()``;
    ``precompute_s`` is the host schedule build."""
    exp = _build(protocol, schedule, m, quota, rounds, seed)

    t0 = time.perf_counter()
    sched = exp.precompute()
    pre_s = time.perf_counter() - t0
    runner = exp.compile()
    hist = runner.run()                      # compile + warm; loss sanity
    t_total = _timed_run(runner)
    t_seg, state_b = _timed_segment(runner)

    sched_b = getattr(sched, 'nbytes', None) or _tree_nbytes(
        sched.__dict__ if hasattr(sched, '__dict__') else sched)
    return {
        'protocol': protocol, 'schedule': schedule, 'm': m,
        'quota': quota, 'rounds': rounds,
        'capacity': getattr(sched, 'capacity', m),
        'rounds_per_sec': rounds / t_seg,
        'rounds_per_sec_total': rounds / t_total,
        'precompute_s': pre_s,
        'sched_mb': sched_b / 1e6,
        'state_mb': state_b / 1e6,
        'vm_hwm_mb': _vm_mb('VmHWM'),
        'vm_rss_mb': _vm_mb('VmRSS'),
        'loss': hist.best_eval['loss'],
    }


def _cell_subprocess(protocol, schedule, m, quota, rounds) -> dict:
    """Run one cell in a child interpreter so VmHWM is a per-cell peak."""
    cmd = [sys.executable, '-m', 'benchmarks.scale', '--cell',
           f'{protocol}:{schedule}:{m}', '--quota', str(quota),
           '--rounds', str(rounds)]
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env['PYTHONPATH'] = os.pathsep.join(
        p for p in (os.path.join(root, 'src'), root,
                    env.get('PYTHONPATH', '')) if p)
    out = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(f'cell {protocol}:{schedule}:{m} failed:\n'
                           f'{out.stderr[-2000:]}')
    return json.loads(out.stdout.strip().splitlines()[-1])


def collect(ms, *, quota: int = QUOTA, rounds: int = ROUNDS,
            inproc: bool = False, xl: bool = False, echo=print) -> list:
    """All (cell, m) measurements; echoes one CSV row per result."""
    results = []
    jobs = [(p, s, m) for m in ms for (p, s, max_m) in CELLS
            if max_m is None or m <= max_m]
    if xl:
        jobs += [('fedavg', 'sparse_delta', XL_M),
                 ('safa', 'sparse_tier', XL_M)]
    for p, s, m in jobs:
        r = (run_cell(p, s, m, quota=quota, rounds=rounds) if inproc
             else _cell_subprocess(p, s, m, quota, rounds))
        results.append(r)
        echo(f'scale/{p}/{s}/m={m},{r["rounds_per_sec"]:.2f},'
             f'rounds_per_sec '
             f'(K={r["capacity"]} sched={r["sched_mb"]:.2f}MB '
             f'state={r["state_mb"]:.1f}MB hwm={r["vm_hwm_mb"]:.0f}MB '
             f'pre={r["precompute_s"]:.2f}s)')
    return results


def run(*, smoke: bool = False, xl: bool = False, quota: int = QUOTA,
        rounds: int = ROUNDS, json_path: str | None = None) -> list:
    """Entry point used by ``benchmarks.run``: smoke runs a single
    in-process m so CI stays fast; full runs the decade sweep with
    per-cell subprocesses for honest peak-RSS."""
    ms = (SMOKE_M,) if smoke else M_GRID
    rounds = 8 if smoke else rounds
    results = collect(ms, quota=quota, rounds=rounds,
                      inproc=smoke, xl=xl and not smoke)
    if json_path:
        with open(json_path, 'w') as f:
            json.dump({'quota': quota, 'rounds': rounds,
                       'cells': results}, f, indent=1)
        print(f'# wrote {json_path}', flush=True)
    return results


def guard(*, budget_mb: float = TIER_HWM_BUDGET_MB, quota: int = QUOTA,
          rounds: int = ROUNDS) -> dict:
    """CI memory-regression gate: the m=1e5 SAFA ``sparse_tier`` cell in
    its own subprocess (honest per-cell VmHWM) against the committed
    budget.  Raises ``SystemExit`` on regression."""
    r = _cell_subprocess('safa', 'sparse_tier', 100_000, quota, rounds)
    hwm = r['vm_hwm_mb']
    print(f'scale-guard/safa/sparse_tier/m=100000,{hwm:.0f},'
          f'vm_hwm_mb (budget {budget_mb:.0f}MB)', flush=True)
    if not hwm <= budget_mb:
        raise SystemExit(
            f'memory regression: m=1e5 safa sparse_tier VmHWM '
            f'{hwm:.0f} MB exceeds the committed budget {budget_mb:.0f} MB '
            f'(benchmarks/scale.py TIER_HWM_BUDGET_MB)')
    return r


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument('--smoke', action='store_true',
                    help=f'single in-process m={SMOKE_M} pass (CI guard)')
    ap.add_argument('--xl', action='store_true',
                    help=f'add the m={XL_M} fedavg sparse_delta and '
                         f'safa sparse_tier cells')
    ap.add_argument('--guard', action='store_true',
                    help='memory-regression gate: fail if the m=1e5 safa '
                         'sparse_tier cell peaks above '
                         f'{TIER_HWM_BUDGET_MB:.0f} MB RSS')
    ap.add_argument('--inproc', action='store_true',
                    help='no per-cell subprocesses (VmHWM then monotone)')
    ap.add_argument('--quota', type=int, default=QUOTA)
    ap.add_argument('--rounds', type=int, default=ROUNDS)
    ap.add_argument('--json', default=None, metavar='FILE')
    ap.add_argument('--cell', default=None, metavar='P:S:M',
                    help='internal: run one cell, print its JSON')
    args = ap.parse_args(argv)
    if args.cell:
        p, s, m = args.cell.split(':')
        print(json.dumps(run_cell(p, s, int(m), quota=args.quota,
                                  rounds=args.rounds)))
        return
    print('name,us_per_call,derived')
    if args.guard:
        guard(quota=args.quota, rounds=args.rounds)
        return
    if args.smoke:
        run(smoke=True, quota=args.quota, json_path=args.json)
    else:
        results = collect(M_GRID, quota=args.quota, rounds=args.rounds,
                          inproc=args.inproc, xl=args.xl)
        if args.json:
            with open(args.json, 'w') as f:
                json.dump({'quota': args.quota, 'rounds': args.rounds,
                           'cells': results}, f, indent=1)
            print(f'# wrote {args.json}', flush=True)


if __name__ == '__main__':
    main()
