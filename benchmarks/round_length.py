"""Paper Tables IV / VI / VIII — average federated round length (s), and
Tables V / VII / IX — average model distribution overhead T_dist (s).

Timing metrics depend only on the event process (as in the paper), so these
run at the full paper scale (m up to 500) with numeric training disabled.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (C_GRID, CR_GRID, PROTOCOLS, emit, make_env,
                               run_protocol)

TASKS = ('task1_regression', 'task2_cnn', 'task3_svm')


def run(rounds: int = 30, seed: int = 0):
    for task_name in TASKS:
        for proto in PROTOCOLS:
            for cr in CR_GRID:
                for C in C_GRID:
                    env = make_env(task_name, cr, seed=seed)
                    h = run_protocol(proto, env, C, rounds)
                    emit(f'round_length/{task_name}/{proto}/cr{cr}/C{C}',
                         f'{h.mean("round_len"):.2f}',
                         f'tdist={h.mean("t_dist"):.2f};eur={h.mean("eur"):.3f}')


def summarize(rounds: int = 30, seed: int = 0):
    """Headline claim check: SAFA speedup over FedAvg/FedCS at small C."""
    for task_name in TASKS:
        for cr in (0.3, 0.7):
            env = {p: make_env(task_name, cr, seed=seed) for p in PROTOCOLS}
            lens = {p: run_protocol(p, env[p], 0.1, rounds).mean('round_len')
                    for p in PROTOCOLS}
            emit(f'speedup/{task_name}/cr{cr}/C0.1',
                 f'{lens["fedavg"] / lens["safa"]:.2f}',
                 f'safa={lens["safa"]:.0f}s;fedavg={lens["fedavg"]:.0f}s;'
                 f'fedcs={lens["fedcs"]:.0f}s')


if __name__ == '__main__':
    run()
    summarize()
