"""Paper Tables IV / VI / VIII — average federated round length (s), and
Tables V / VII / IX — average model distribution overhead T_dist (s).

Timing metrics depend only on the event process (as in the paper), so these
run at the full paper scale (m up to 500) with numeric training disabled.

Each (task, protocol) grid — crash rate x selection fraction — is ONE
``run_sweep`` fleet: a single fleet-major schedule precompute per protocol
instead of a python loop of per-cell runs.
"""
from __future__ import annotations

import itertools

from benchmarks.common import C_GRID, CR_GRID, PROTOCOLS, emit, sweep_members
from repro.core import federation

TASKS = ('task1_regression', 'task2_cnn', 'task3_svm')


def run(rounds: int = 30, seed: int = 0):
    grid = list(itertools.product(CR_GRID, C_GRID))
    for task_name in TASKS:
        for proto in PROTOCOLS:
            members = sweep_members(task_name, grid, seed=seed)
            hists = federation.run_sweep(None, members, rounds=rounds,
                                         proto=proto, numeric=False)
            for (cr, C), h in zip(grid, hists):
                emit(f'round_length/{task_name}/{proto}/cr{cr}/C{C}',
                     f'{h.mean("round_len"):.2f}',
                     f'tdist={h.mean("t_dist"):.2f};eur={h.mean("eur"):.3f}')


def summarize(rounds: int = 30, seed: int = 0):
    """Headline claim check: SAFA speedup over FedAvg/FedCS at small C."""
    crs = (0.3, 0.7)
    for task_name in TASKS:
        lens = {}
        for proto in PROTOCOLS:
            members = sweep_members(task_name, [(cr, 0.1) for cr in crs],
                                    seed=seed)
            hists = federation.run_sweep(None, members, rounds=rounds,
                                         proto=proto, numeric=False)
            lens[proto] = {cr: h.mean('round_len')
                           for cr, h in zip(crs, hists)}
        for cr in crs:
            emit(f'speedup/{task_name}/cr{cr}/C0.1',
                 f'{lens["fedavg"][cr] / lens["safa"][cr]:.2f}',
                 f'safa={lens["safa"][cr]:.0f}s;fedavg={lens["fedavg"][cr]:.0f}s;'
                 f'fedcs={lens["fedcs"][cr]:.0f}s')


if __name__ == '__main__':
    run()
    summarize()
