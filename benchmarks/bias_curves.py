"""Paper Fig. 5 — bias between fastest/slowest clients vs round index, for
FedAvg and the three SAFA selection cases.

Emits both the paper-faithful curves (printed Eq. 15) and the corrected
recurrence-solution curves (see repro.core.bias.sigma docstrings), plus a
Monte-Carlo estimate from actual CFCFM selection.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import bias, selection


def monte_carlo_pick_rate(C, cr=0.3, m=30, rounds=3000, seed=0):
    rng = np.random.default_rng(seed)
    prev = np.zeros(m, bool)
    pa, pb = [], []
    for _ in range(rounds):
        crashed = rng.random(m) < cr
        arrival = rng.uniform(10, 20, m)
        arrival[0], arrival[-1] = 1.0, 100.0
        arrival = np.where(~crashed, arrival, np.inf)
        sel = selection.cfcfm(arrival, ~crashed, prev, C, 1e9)
        pa.append(sel.picked[0])
        pb.append(sel.picked[-1])
        prev = sel.picked
    h = rounds // 2
    return float(np.mean(pa[h:])), float(np.mean(pb[h:]))


def run():
    cr = 0.3
    emit('bias/fedavg', f'{bias.bias_fedavg(cr, cr):.4f}', 'constant')
    for (C, R), case in [((0.9, 0.5), 1), ((0.5, 0.3), 2), ((0.05, 0.3), 3)]:
        for faithful in (True, False):
            curve = bias.bias_curve(cr, cr, C, R, 30, faithful=faithful)
            tag = 'paper_eq15' if faithful else 'corrected'
            emit(f'bias/safa_case{case}/{tag}', f'{curve[-1]:.4f}',
                 f'r5={curve[3]:.4f};r10={curve[8]:.4f};converged='
                 f'{abs(curve[-1] - curve[-2]) < 1e-6}')
    # Monte-Carlo ground truth for the steady-state pick probabilities
    for C, case in [(1.0, 1), (0.1, 3)]:
        pa, pb = monte_carlo_pick_rate(C, cr)
        emit(f'bias/montecarlo_case{case}', f'{pa:.4f}',
             f'pick_rate_B={pb:.4f};theory_A='
             f'{(1 - cr) if case == 1 else (1 - cr) / (2 - cr):.4f}')


if __name__ == '__main__':
    run()
