"""Aggregation-family shoot-out: every staleness-adaptive scheme in ONE
fleet dispatch.

    PYTHONPATH=src python -m benchmarks.agg_schemes [--smoke] [--json F]

The weighted-merge lowering makes the scheme *data*, not trace: SEAFL
(plain / loss-term / hinge-discount), CSAFL (2 and 4 clusters), folded
FedAsync, and a constant-discount ablation all ride one
``run_sweep(engine='fleet')`` call as members of a single ``SeaflSpec``
umbrella experiment, differing only in their ``SweepMember.overrides``.
Every member is built on a same-seed env, so all schemes replay the SAME
crash/arrival event stream — the comparison isolates the aggregation
rule from the luck of the draws.

Emits one CSV row per scheme (final eval loss) plus the fleet's
aggregate rounds/sec, and — with ``--json`` — writes the per-scheme eval
trajectories to ``BENCH_agg_schemes.json`` for the CI artifact.
"""
from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import Timer, emit
from repro import api
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec

ROUNDS = 60
BASE = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
               t_lim=830.0, seed=3)

#: scheme name -> SweepMember overrides on the SeaflSpec umbrella (None ==
#: the umbrella spec's own defaults).
SCHEMES = {
    'seafl': None,
    'seafl_loss': {'use_loss': True},
    'seafl_hinge': {'staleness_fn': 'hinge', 'hinge_b': 1},
    'seafl_constant': {'staleness_fn': 'constant'},
    'csafl_k2': {'scheme': 'csafl', 'clusters': 2},
    'csafl_k4': {'scheme': 'csafl', 'clusters': 4},
    'fedasync_fold': {'scheme': 'fedasync'},
}


def _quickstart_task():
    env = BASE.build()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _members():
    """One member per scheme — declarative same-seed env specs (the sweep
    builds each member a fresh env), so every scheme sees identical event
    draws."""
    return [api.SweepMember(env=BASE, overrides=ov)
            for ov in SCHEMES.values()]


def _time(fn, reps: int) -> float:
    fn()                                   # warm the jit caches
    times = []
    for _ in range(reps):
        with Timer() as t:
            fn()
        times.append(t.dt)
    return min(times)


def run(rounds: int = ROUNDS, reps: int = 3,
        json_path: str | None = None) -> dict:
    task = _quickstart_task()
    ex = api.ExecSpec(engine='fleet', eval_every=max(1, rounds // 4))
    exp = api.Experiment(task, BASE, api.SeaflSpec(), ex,
                         rounds=rounds)

    def sweep():
        hists = exp.compile().run_sweep(_members())
        jax.block_until_ready(hists[-1].final_global)
        return hists

    sec = _time(sweep, reps)
    hists = sweep()
    total_rounds = len(SCHEMES) * rounds
    emit('agg_schemes/fleet/rounds_per_sec', f'{total_rounds / sec:.1f}',
         f'sec_per_sweep={sec:.3f};S={len(SCHEMES)};rounds={rounds}')

    out = {'rounds': rounds, 'm': BASE.m, 'engine': 'fleet',
           'sec_per_sweep': sec, 'schemes': []}
    for name, hist in zip(SCHEMES, hists):
        evals = [(r, e['loss']) for r, e in hist.evals()]
        emit(f'agg_schemes/{name}/final_loss', f'{evals[-1][1]:.6f}',
             f'best={hist.best_eval["loss"]:.6f};rounds={rounds}')
        out['schemes'].append({'name': name,
                               'overrides': SCHEMES[name],
                               'final_loss': evals[-1][1],
                               'best_loss': hist.best_eval['loss'],
                               'evals': evals})
    if json_path:
        with open(json_path, 'w') as f:
            json.dump(out, f, indent=1)
        print(f'# wrote {json_path}', flush=True)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--smoke', action='store_true',
                    help='tiny-parameter CI pass (6 rounds, 1 rep)')
    ap.add_argument('--json', default=None, metavar='FILE',
                    help='write per-scheme eval trajectories '
                         '(e.g. BENCH_agg_schemes.json)')
    args = ap.parse_args(argv)
    if args.smoke:
        run(rounds=6, reps=1, json_path=args.json)
    else:
        run(json_path=args.json)


if __name__ == '__main__':
    main()
