"""Paper Eq. 5 / Fig. 2 — Effective Update Ratio: theory vs simulation for
SAFA's post-training selection and FedAvg's pre-training selection."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_env, run_protocol
from repro.core import metrics


def run(rounds: int = 40, seed: int = 0):
    for cr in (0.1, 0.3, 0.5, 0.7):
        for C in (0.1, 0.3, 0.5, 0.9):
            env = make_env('task2_cnn', cr, seed=seed)
            hs = run_protocol('safa', env, C, rounds)
            hf = run_protocol('fedavg', env, C, rounds)
            emit(f'eur/cr{cr}/C{C}', f'{hs.mean("eur"):.4f}',
                 f'theory_safa={metrics.eur_theory_safa(C, cr):.4f};'
                 f'fedavg={hf.mean("eur"):.4f};'
                 f'theory_fedavg={metrics.eur_theory_fedavg(C, cr):.4f}')


if __name__ == '__main__':
    run()
