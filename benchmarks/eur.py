"""Paper Eq. 5 / Fig. 2 — Effective Update Ratio: theory vs simulation for
SAFA's post-training selection and FedAvg's pre-training selection.

The cr x C comparison grid runs as ONE fleet per protocol
(``run_sweep(numeric=False)``): a single fleet-major schedule precompute
covers all 16 cells instead of 32 per-cell python runs.
"""
from __future__ import annotations

import itertools

from benchmarks.common import emit, sweep_members
from repro.core import federation, metrics


def run(rounds: int = 40, seed: int = 0):
    grid = list(itertools.product((0.1, 0.3, 0.5, 0.7), (0.1, 0.3, 0.5, 0.9)))
    hists = {proto: federation.run_sweep(
        None, sweep_members('task2_cnn', grid, seed=seed), rounds=rounds,
        proto=proto, numeric=False) for proto in ('safa', 'fedavg')}
    for i, (cr, C) in enumerate(grid):
        emit(f'eur/cr{cr}/C{C}', f'{hists["safa"][i].mean("eur"):.4f}',
             f'theory_safa={metrics.eur_theory_safa(C, cr):.4f};'
             f'fedavg={hists["fedavg"][i].mean("eur"):.4f};'
             f'theory_fedavg={metrics.eur_theory_fedavg(C, cr):.4f}')


if __name__ == '__main__':
    run()
