"""Fleet-engine benchmark: one vmapped-scan dispatch for a whole sweep vs
looping the single-run scan engine.

S = 16 quickstart-task configurations (crash rate x rng stream, with
per-member fraction / lag tolerance) run three ways:

* ``loop_scan``  — the pre-fleet path: one ``federation.run_safa``
  (``engine='scan'``) call per cell, exactly what the sweep benchmarks did
  before the fleet engine existed (per-cell schedule precompute + one scan
  dispatch per cell);
* ``sequential`` — ``run_sweep(engine='sequential')``: fleet-major schedule
  precompute (one vectorised host pass), then S per-member scan dispatches;
* ``fleet``      — ``run_sweep(engine='fleet')``: same precompute, all S
  simulations in ONE ``jax.vmap``-over-``lax.scan`` dispatch with donated
  fleet-major carries, the fleet axis sharded across host devices (this
  module forces one XLA host device per CPU core — every op in the fleet
  program is fleet-parallel, so the shards run with zero communication;
  the per-cell loop has no batch axis to shard and cannot use the extra
  cores).

All three produce bit-identical per-member results (tests/test_fleet.py),
so the rows differ only in wall clock: aggregate rounds/sec across the
fleet.

    PYTHONPATH=src python -m benchmarks.fleet_sweep
"""
from __future__ import annotations

import itertools
import os

if 'xla_force_host_platform_device_count' not in os.environ.get('XLA_FLAGS', ''):
    os.environ['XLA_FLAGS'] = (
        os.environ.get('XLA_FLAGS', '')
        + f' --xla_force_host_platform_device_count={os.cpu_count()}').strip()

import jax

from benchmarks.common import Timer, emit
from repro.core import federation
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec, env_grid

ROUNDS = 60
BASE = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
               t_lim=830.0, seed=3)
FRACTIONS = (0.5, 0.3, 1.0, 0.1)
TAUS = (5, 2, 10, 1)


def _quickstart_task():
    env = BASE.build()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _members(s: int = 16):
    """Fresh fleet of ``s`` members: crash rate x draw stream, with
    fraction / lag tolerance cycling per member.  The declarative specs
    build each call (envs are consumables, specs are values)."""
    specs = env_grid(BASE, crash_prob=(0.1, 0.3, 0.5, 0.7),
                     draw_seed=(0, 1, 2, 3))[:s]
    hyper = itertools.cycle(zip(FRACTIONS, TAUS))
    return [federation.SweepMember(env=e, fraction=f, lag_tolerance=tau)
            for e, (f, tau) in zip(specs, hyper)]


def _time(fn, reps: int = 5) -> float:
    """Steady-state seconds per whole-sweep run: best of ``reps`` timed
    runs (schedule precompute included; jit caches warm after rep 0).
    Min-of-reps rejects background-load noise on shared CPUs."""
    fn()
    times = []
    for _ in range(reps):
        with Timer() as t:
            fn()
        times.append(t.dt)
    return min(times)


def run(rounds: int = ROUNDS, s: int = 16, reps: int = 5):
    task = _quickstart_task()
    s_count = len(_members(s))
    total_rounds = s_count * rounds

    def loop_scan():
        h = None
        for mem in _members(s):
            h = federation.run_safa(task, mem.env, fraction=mem.fraction,
                                    lag_tolerance=mem.lag_tolerance,
                                    rounds=rounds, eval_every=rounds,
                                    engine='scan')
        jax.block_until_ready(h.final_global)

    def sweep(engine):
        hists = federation.run_sweep(task, _members(s), rounds=rounds,
                                     eval_every=rounds, engine=engine)
        jax.block_until_ready(hists[-1].final_global)

    secs = {
        'loop_scan': _time(loop_scan, reps),
        'sequential': _time(lambda: sweep('sequential'), reps),
        'fleet': _time(lambda: sweep('fleet'), reps),
    }
    base_rps = total_rounds / secs['loop_scan']
    for name, sec in secs.items():
        rps = total_rounds / sec
        emit(f'fleet_sweep/{name}/rounds_per_sec', f'{rps:.1f}',
             f'sec_per_sweep={sec:.3f};S={s_count};rounds={rounds};'
             f'speedup={rps / base_rps:.2f}x')


if __name__ == '__main__':
    run()
