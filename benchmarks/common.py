"""Shared benchmark plumbing: paper environment grids + CSV output.

Benchmark runs go through the declarative experiment API
(``repro.api.Experiment``): a protocol name resolves to its registered
spec, execution knobs land in ``ExecSpec``, and ``run_protocol`` compiles
and runs the experiment — so benchmark configs *are* specs.
"""
from __future__ import annotations

import dataclasses
import time

from repro import api
from repro.configs import PAPER_TASKS
from repro.fedsim import Env, EnvSpec

CR_GRID = (0.1, 0.3, 0.5, 0.7)
C_GRID = (0.1, 0.3, 0.5, 0.7, 1.0)
PROTOCOLS = ('fedavg', 'fedcs', 'safa')

#: ``run_protocol``/``build_experiment`` kwargs routed into ``ExecSpec``.
EXEC_KEYS = tuple(f.name for f in dataclasses.fields(api.ExecSpec))


def make_env(task_name: str, cr: float, seed: int = 0,
             scale: float = 1.0) -> Env:
    t = PAPER_TASKS[task_name]
    m = max(2, int(t['m'] * scale))
    n = max(m * t['batch_size'], int(t['dataset_size'] * scale))
    return EnvSpec(m=m, crash_prob=cr, dataset_size=n,
                   batch_size=t['batch_size'], epochs=t['epochs'],
                   t_lim=t['t_lim'], seed=seed).build()


def build_experiment(name: str, env: Env, C: float, rounds: int,
                     lag_tolerance: int = 5, task=None, seed: int = 0,
                     **kw) -> api.Experiment:
    """A benchmark cell as a declarative spec: protocol fields from the
    grid, execution knobs (``EXEC_KEYS``) into ``ExecSpec``."""
    proto_kw = {}
    if name != 'fedasync':          # fedasync is fully asynchronous: no C
        proto_kw['fraction'] = C
    if name == 'safa':
        proto_kw['lag_tolerance'] = lag_tolerance
    exec_kw = {k: kw.pop(k) for k in EXEC_KEYS if k in kw}
    exec_kw.setdefault('numeric', task is not None)
    if kw:
        raise TypeError(f'unknown run_protocol kwargs: {sorted(kw)}')
    return api.Experiment(task, env, api.spec(name, **proto_kw),
                          api.ExecSpec(**exec_kw), rounds=rounds, seed=seed)


def run_protocol(name: str, env: Env, C: float, rounds: int,
                 lag_tolerance: int = 5, task=None, **kw):
    return build_experiment(name, env, C, rounds,
                            lag_tolerance=lag_tolerance, task=task,
                            **kw).compile().run()


def sweep_members(task_name: str, grid, seed: int = 0, scale: float = 1.0,
                  lag_tolerance: int = 5):
    """One ``SweepMember`` per (cr, C) cell — fresh envs per member (the
    event draws consume the env rng), same ``seed`` so the fleet shares one
    client population."""
    return [api.SweepMember(
        env=make_env(task_name, cr, seed=seed, scale=scale), fraction=C,
        lag_tolerance=lag_tolerance) for cr, C in grid]


def emit(name: str, value, derived: str = ''):
    """CSV row: name,us_per_call,derived."""
    print(f'{name},{value},{derived}', flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.dt * 1e6
