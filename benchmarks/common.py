"""Shared benchmark plumbing: paper environment grids + CSV output."""
from __future__ import annotations

import time

from repro.configs import PAPER_TASKS
from repro.core import federation
from repro.fedsim import FLEnv

CR_GRID = (0.1, 0.3, 0.5, 0.7)
C_GRID = (0.1, 0.3, 0.5, 0.7, 1.0)
PROTOCOLS = ('fedavg', 'fedcs', 'safa')


def make_env(task_name: str, cr: float, seed: int = 0, scale: float = 1.0) -> FLEnv:
    t = PAPER_TASKS[task_name]
    m = max(2, int(t['m'] * scale))
    n = max(m * t['batch_size'], int(t['dataset_size'] * scale))
    return FLEnv(m=m, crash_prob=cr, dataset_size=n,
                 batch_size=t['batch_size'], epochs=t['epochs'],
                 t_lim=t['t_lim'], seed=seed)


def run_protocol(name: str, env: FLEnv, C: float, rounds: int,
                 lag_tolerance: int = 5, task=None, **kw):
    fn = federation.RUNNERS[name]
    kwargs = dict(fraction=C, rounds=rounds, numeric=task is not None, **kw)
    if name == 'safa':
        kwargs['lag_tolerance'] = lag_tolerance
    return fn(task, env, **kwargs)


def sweep_members(task_name: str, grid, seed: int = 0, scale: float = 1.0,
                  lag_tolerance: int = 5):
    """One ``SweepMember`` per (cr, C) cell — fresh envs per member (the
    event draws consume the env rng), same ``seed`` so the fleet shares one
    client population."""
    return [federation.SweepMember(
        env=make_env(task_name, cr, seed=seed, scale=scale), fraction=C,
        lag_tolerance=lag_tolerance) for cr, C in grid]


def emit(name: str, value, derived: str = ''):
    """CSV row: name,us_per_call,derived."""
    print(f'{name},{value},{derived}', flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.dt * 1e6
