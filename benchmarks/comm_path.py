"""Compressed-wire path benchmark: rounds/sec + wire bytes for three
upload formats of the same SAFA run:

* ``f32``    — uncompressed uploads, packed aggregation (1 dispatch/round);
* ``perleaf``— int8 uplink via the per-leaf reference wrapper
  (``quantize_uploads=True``: 2 pallas dispatches per leaf per client);
* ``packed`` — the quantized-wire fast path (``wire='int8'``: one packed
  quantize + one fused dequant-aggregate, exactly 2 dispatches per round).

All three run the scan engine at quickstart scale; wire-bytes accounting
(``ops.comm_bytes``, tree vs packed layout) is also reported for the
paper-scale CNN model.  The dispatch-count invariant of the fast path is
asserted on every run — including the CI ``--smoke`` pass — so the
2-dispatch contract cannot silently regress.

    PYTHONPATH=src python -m benchmarks.comm_path
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core import federation, protocol
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec
from repro.kernels.ops import comm_bytes, count_pallas_calls

ROUNDS = 40

SPEC = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
               epochs=3, t_lim=830.0, seed=3)


def _quickstart_setup():
    env = SPEC.build()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
    task = regression_task(data, lr=1e-3, epochs=3)
    return env, task


_MODES = {
    'f32': dict(use_kernel='packed'),
    'perleaf': dict(quantize_uploads=True, use_kernel='packed'),
    'packed': dict(wire='int8'),
}


def _time_mode(task, mode: str, reps: int, rounds: int) -> float:
    def once():
        h = federation.run_safa(task, SPEC.build(), fraction=0.5,
                                lag_tolerance=5,
                                rounds=rounds, eval_every=rounds,
                                engine='scan', **_MODES[mode])
        jax.block_until_ready(h.final_global)
    once()                                  # warm up compile caches
    with Timer() as t:
        for _ in range(reps):
            once()
    return t.dt / reps


def _dispatches_per_round(task, env, mode: str) -> int:
    """pallas_calls in one scanned round body for the given upload mode."""
    sched = federation.precompute_safa_schedule(env, fraction=0.5,
                                                lag_tolerance=5, rounds=2)
    ns = federation._NumericState(task, env.m, 0)
    w = jnp.asarray(env.weights)
    train_fn = task.local_train
    use_kernel, wire = 'packed', 'f32'
    if mode == 'perleaf':
        train_fn = federation._quantized_train_fn(task.local_train)
    elif mode == 'packed':
        use_kernel, wire = False, 'int8'
    jaxpr = jax.make_jaxpr(
        lambda g, l, c, s, ww: protocol._safa_scan(
            g, l, c, s, ww, train_fn, use_kernel, wire)
    )(ns.global_w, ns.local_w, ns.cache, sched.to_device(), w)
    return count_pallas_calls(jaxpr.jaxpr)


def _wire_bytes_rows(name: str, tree):
    """Uplink bytes for one client's model transfer, every format."""
    raw = comm_bytes(tree, quantized=False)
    for fmt, kw in (('f32_tree', dict(quantized=False)),
                    ('int8_tree', dict(quantized=True)),
                    ('f32_packed', dict(quantized=False, layout='packed')),
                    ('int8_packed', dict(quantized=True, layout='packed'))):
        b = comm_bytes(tree, **kw)
        emit(f'comm_path/wire_bytes/{name}/{fmt}', b,
             f'compression={raw / b:.2f}x')


def run(rounds: int = ROUNDS, reps: int = 3):
    _, task = _quickstart_setup()

    # dispatch counts first: the fast-path invariant is asserted, not just
    # reported, so the CI smoke pass guards it
    # a built env's rng is single-shot: each mode's precompute gets a
    # fresh build of the same spec
    counts = {m: _dispatches_per_round(task, SPEC.build(), m)
              for m in _MODES}
    assert counts['packed'] == 2, (
        f"compressed fast path must be exactly 2 pallas dispatches per "
        f"round, got {counts['packed']}")
    emit('comm_path/dispatches_per_round', counts['packed'],
         f"f32_packed={counts['f32']};perleaf_int8={counts['perleaf']};"
         f"packed_int8={counts['packed']}")

    secs = {m: _time_mode(task, m, reps, rounds) for m in _MODES}
    for mode, s in secs.items():
        emit(f'comm_path/{mode}/rounds_per_sec', f'{rounds / s:.1f}',
             f'sec_per_run={s:.3f};rounds={rounds};'
             f'speedup_vs_perleaf={secs["perleaf"] / s:.2f}x')

    # wire accounting: quickstart model and the paper-scale CNN
    _wire_bytes_rows('quickstart', task.init_global(jax.random.PRNGKey(0)))
    from repro.data.tasks import _cnn_init
    _wire_bytes_rows('paper_cnn', _cnn_init(jax.random.PRNGKey(0)))


if __name__ == '__main__':
    run()
