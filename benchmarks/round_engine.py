"""Round-engine benchmark: Python-loop vs scan-compiled numeric runs, and
leaf-wise vs packed aggregation dispatch counts.

Two claims are measured:

* the scanned engine (one ``lax.scan`` dispatch per eval segment, donated
  carry) beats the per-round Python loop on rounds/sec — on CPU the loop
  path is dominated by per-op dispatch and host->device mask shuttling;
* the packed aggregation path issues exactly ONE ``pallas_call`` per round
  regardless of how many pytree leaves the model has, vs one per leaf for
  the leaf-wise path.

    PYTHONPATH=src python -m benchmarks.round_engine
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core import federation, protocol
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import EnvSpec
from repro.kernels.ops import count_pallas_calls

ROUNDS = 60


def _quickstart_setup():
    """The quickstart task: m=5 unreliable clients, linear regression."""
    env = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5,
                  epochs=3, t_lim=830.0, seed=3).build()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
    task = regression_task(data, lr=1e-3, epochs=3)
    return env, task


def _time_engine(task, engine: str, reps: int = 3,
                 rounds: int = ROUNDS) -> float:
    """Steady-state seconds per numeric SAFA run (fresh env each rep so the
    schedule precompute is included; jit caches are warm after rep 0)."""
    def once():
        env = EnvSpec(m=5, crash_prob=0.3, dataset_size=506,
                      batch_size=5, epochs=3, t_lim=830.0, seed=3).build()
        h = federation.run_safa(task, env, fraction=0.5, lag_tolerance=5,
                                rounds=rounds, eval_every=rounds,
                                engine=engine)
        jax.block_until_ready(h.final_global)
    once()                                  # warm up compile caches
    with Timer() as t:
        for _ in range(reps):
            once()
    return t.dt / reps


def _dispatches_per_round(use_kernel) -> tuple[int, int]:
    """(pallas dispatches, leaf count) for one aggregation on a deep model."""
    from repro.data.tasks import _cnn_init
    g = _cnn_init(jax.random.PRNGKey(0))
    leaves = jax.tree.leaves(g)
    m = 8
    cache = protocol.broadcast_global(g, m)
    trained = protocol.broadcast_global(g, m)
    masks = dict(picked=jnp.zeros(m, bool).at[0].set(True),
                 undrafted=jnp.zeros(m, bool).at[1].set(True),
                 deprecated=jnp.zeros(m, bool).at[2].set(True),
                 weights=jnp.full((m,), 1.0 / m))

    def agg(cache, trained, g):
        return protocol.discriminative_aggregation(
            cache, trained, g, use_kernel=use_kernel, **masks)

    jaxpr = jax.make_jaxpr(agg)(cache, trained, g)
    return count_pallas_calls(jaxpr.jaxpr), len(leaves)


def run(rounds: int = ROUNDS, reps: int = 3):
    env, task = _quickstart_setup()
    del env

    s_loop = _time_engine(task, 'loop', reps, rounds)
    s_scan = _time_engine(task, 'scan', reps, rounds)
    rps_loop = rounds / s_loop
    rps_scan = rounds / s_scan
    emit('round_engine/loop/rounds_per_sec', f'{rps_loop:.1f}',
         f'sec_per_run={s_loop:.3f};rounds={rounds}')
    emit('round_engine/scan/rounds_per_sec', f'{rps_scan:.1f}',
         f'sec_per_run={s_scan:.3f};rounds={rounds};'
         f'speedup={rps_scan / rps_loop:.2f}x')

    d_leaf, n_leaves = _dispatches_per_round(True)
    d_packed, _ = _dispatches_per_round('packed')
    emit('round_engine/aggregation/dispatches_per_round',
         f'{d_packed}',
         f'leafwise_dispatches={d_leaf};model_leaves={n_leaves};'
         f'packed_dispatches={d_packed}')


if __name__ == '__main__':
    run()
