"""Benchmark entrypoint: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only SECTION]

Prints ``name,us_per_call,derived`` CSV rows (values are seconds for the
protocol-timing tables, accuracy for the accuracy tables, us/call for the
kernel microbenches — the ``derived`` column says which).

``--smoke`` runs the engine/protocol-comparison sections with tiny
round/fleet counts — a CI guard that the benchmark scripts themselves
keep importing and running, not a measurement.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (accuracy, agg_schemes, bias_curves, comm_path, eur,
                        heterogeneity, kernels_bench, lag_tolerance,
                        roofline_table, round_engine, round_length,
                        selection_ablation, sr_futility)

SECTIONS = {
    'round_length': lambda full: (round_length.run(), round_length.summarize()),
    'round_engine': lambda full: round_engine.run(),
    'comm_path': lambda full: comm_path.run(),
    'sr_futility': lambda full: sr_futility.run(),
    'accuracy': lambda full: accuracy.run(full=full),
    'lag_tolerance': lambda full: lag_tolerance.run(),
    'bias': lambda full: bias_curves.run(),
    'eur': lambda full: eur.run(),
    'selection_ablation': lambda full: selection_ablation.run(),
    'agg_schemes': lambda full: agg_schemes.run(
        json_path='BENCH_agg_schemes.json'),
    'heterogeneity': lambda full: heterogeneity.run(
        json_path='BENCH_heterogeneity.json'),
    'kernels': lambda full: kernels_bench.run(),
    'roofline': lambda full: roofline_table.run(),
    # imported lazily: fleet_sweep forces one XLA host device per core at
    # import, which must happen before jax initializes to take effect —
    # run it standalone (python -m benchmarks.fleet_sweep) for the
    # sharded-fleet numbers; here it runs unsharded on one device
    'fleet_sweep': lambda full: __import__(
        'benchmarks.fleet_sweep', fromlist=['run']).run(),
    # lazy too: the full sweep spawns one subprocess per cell for honest
    # per-cell peak-RSS (see benchmarks/scale.py)
    'scale': lambda full: __import__(
        'benchmarks.scale', fromlist=['run']).run(
            smoke=not full, json_path=_JSON_PATH['path']),
}

#: ``--json FILE`` routes the scale section's cell measurements
#: (rounds/sec + peak RSS per protocol x schedule cell) into FILE.
_JSON_PATH = {'path': None}

# tiny-parameter variants for --smoke: every engine/protocol-comparison
# script executes end to end in seconds, so CI catches bitrot in the
# benchmark layer without paying for a measurement
SMOKE_SECTIONS = {
    'round_length': lambda: (round_length.run(rounds=3),
                             round_length.summarize(rounds=3)),
    'round_engine': lambda: round_engine.run(rounds=6, reps=1),
    # comm_path asserts the 2-dispatch invariant of the compressed wire
    # path on every run, so the smoke pass is also a regression guard
    'comm_path': lambda: comm_path.run(rounds=4, reps=1),
    'eur': lambda: eur.run(rounds=3),
    # one fleet dispatch over the whole aggregation family; the JSON is
    # the BENCH_agg_schemes.json CI artifact
    'agg_schemes': lambda: agg_schemes.run(
        rounds=6, reps=1, json_path='BENCH_agg_schemes.json'),
    # the trace-scenario grid (scenario x protocol x wire); the JSON is
    # the BENCH_heterogeneity.json CI artifact
    'heterogeneity': lambda: heterogeneity.run(
        rounds=6, reps=1, json_path='BENCH_heterogeneity.json'),
    'fleet_sweep': lambda: __import__(
        'benchmarks.fleet_sweep', fromlist=['run']).run(rounds=6, s=4,
                                                        reps=1),
    'scale': lambda: __import__(
        'benchmarks.scale', fromlist=['run']).run(
            smoke=True, json_path=_JSON_PATH['path']),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--full', action='store_true',
                    help='paper-scale numeric runs (slow on 1 CPU core)')
    ap.add_argument('--smoke', action='store_true',
                    help='tiny-parameter CI pass over the engine sections')
    ap.add_argument('--only', choices=list(SECTIONS), default=None)
    ap.add_argument('--json', default=None, metavar='FILE',
                    help='write the scale section cells as JSON '
                         '(e.g. BENCH_scale.json)')
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error('--full and --smoke are mutually exclusive')
    _JSON_PATH['path'] = args.json
    if args.json and args.only not in (None, 'scale'):
        ap.error('--json applies to the scale section')
    sections = SMOKE_SECTIONS if args.smoke else SECTIONS
    print('name,us_per_call,derived')
    if args.only:
        if args.smoke and args.only not in sections:
            ap.error(f'--smoke has no section {args.only!r} '
                     f'(choose from {sorted(sections)})')
        todo = [args.only]
    else:
        todo = list(sections)
    for name in todo:
        t0 = time.time()
        print(f'# --- {name} ---', flush=True)
        sections[name]() if args.smoke else sections[name](args.full)
        print(f'# {name} done in {time.time() - t0:.0f}s', flush=True)


if __name__ == '__main__':
    main()
