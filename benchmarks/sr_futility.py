"""Paper Tables XI / XIII / XV — Synchronization Ratio and Futility
Percentage per protocol x C x cr."""
from __future__ import annotations

from benchmarks.common import CR_GRID, PROTOCOLS, emit, make_env, run_protocol

TASKS = ('task1_regression', 'task2_cnn', 'task3_svm')


def run(rounds: int = 30, seed: int = 0):
    for task_name in TASKS:
        for proto in PROTOCOLS:
            for cr in CR_GRID:
                for C in (0.1, 0.5, 1.0):
                    env = make_env(task_name, cr, seed=seed)
                    h = run_protocol(proto, env, C, rounds)
                    emit(f'sr_futility/{task_name}/{proto}/cr{cr}/C{C}',
                         f'{h.mean("sr"):.3f}',
                         f'futility={h.futility:.3f};vv={h.mean("vv"):.3f}')


if __name__ == '__main__':
    run()
