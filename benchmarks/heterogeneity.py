"""Trace-driven heterogeneity shoot-out: scenario grid x protocol x wire.

    PYTHONPATH=src python -m benchmarks.heterogeneity [--smoke] [--json F]

The trace simulator makes a deployment scenario *data* on the env spec:
day/night availability cycles, Markov on/off churn, and a device-class
grid are just ``EnvSpec.traces`` values, so all four scenarios ride ONE
``run_sweep(engine='fleet')`` dispatch per (protocol, wire) cell as
members of a single experiment, differing only in their
``SweepMember.overrides={'traces': ...}`` env override.  Every member
is built on a same-seed spec, so scenarios replay the same uniform
event draws — only the trace-modulated thresholds and timings differ.

The base spec uses ``comm='wire'``: comm times come from the experiment
model's measured wire bytes under the active ``ExecSpec.wire``, so the
f32 and int8 columns see genuinely different uplink times (at this toy
model size the packed-int8 lane padding dominates, so int8 ships MORE
bytes than the 56-byte f32 tree — the point is that the event simulator
feels the real wire, not that int8 wins at 13 weights), which shifts
round lengths, CFCFM picks and FedCS selections end-to-end.

Emits one CSV row per (protocol, wire, scenario) cell plus per-cell
fleet rounds/sec, and — with ``--json`` — writes the grid to
``BENCH_heterogeneity.json`` for the CI artifact, including the
int8-vs-f32 round-length delta per (protocol, scenario).
"""
from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import Timer, emit
from repro import api
from repro.data import make_regression, partition
from repro.data.tasks import regression_task
from repro.fedsim import DayNight, DeviceClass, DeviceClasses, EnvSpec, MarkovChurn

ROUNDS = 60
#: microscopic last-mile bandwidth so the measured wire bytes (tens of
#: bytes to a few KB for the quickstart model) land in the same ballpark
#: as the train times — otherwise both wires round to "instant upload".
BASE = EnvSpec(m=5, crash_prob=0.3, dataset_size=506, batch_size=5, epochs=3,
               t_lim=830.0, seed=3, client_bw_mbps=2e-4, comm='wire')

#: scenario name -> EnvSpec.traces value (None == the paper's static
#: availability/bandwidth/speed model).
SCENARIOS = {
    'stable': None,
    'daynight': DayNight(period=8, night_availability=0.3,
                         night_bandwidth=0.5, seed=0),
    'churn': MarkovChurn(p_off=0.2, p_on=0.6, seed=0),
    'classes': DeviceClasses((DeviceClass('hi', speed=2.0, bandwidth=4.0),
                              DeviceClass('lo', speed=0.5, bandwidth=0.25)),
                             mix=(0.4, 0.6)),
}
PROTOCOLS = ('safa', 'fedavg', 'fedcs')
WIRES = ('f32', 'int8')


def _quickstart_task():
    env = BASE.build()
    x, y = make_regression()
    data = partition(x, y, env.partition_sizes, batch_size=5, seed=1)
    return regression_task(data, lr=1e-3, epochs=3)


def _members():
    """One member per scenario — same-seed declarative specs, differing
    only in the ``traces`` env override (the sweep resolver splits env
    fields out of ``overrides`` and rebuilds each member's env)."""
    return [api.SweepMember(env=BASE, fraction=0.5, lag_tolerance=5,
                            overrides={'traces': tr})
            for tr in SCENARIOS.values()]


def _pdef(name: str) -> api.ProtocolDef:
    return next(p for p in api.PROTOCOLS.values() if p.name == name)


def _time(fn, reps: int) -> float:
    fn()                                   # warm the jit caches
    times = []
    for _ in range(reps):
        with Timer() as t:
            fn()
        times.append(t.dt)
    return min(times)


def run(rounds: int = ROUNDS, reps: int = 3,
        json_path: str | None = None) -> dict:
    task = _quickstart_task()
    out = {'rounds': rounds, 'm': BASE.m, 'engine': 'fleet',
           'scenarios': list(SCENARIOS), 'cells': []}
    round_len = {}
    for name in PROTOCOLS:
        pdef = _pdef(name)
        for wire in WIRES:
            ex = api.ExecSpec(engine='fleet', wire=wire,
                              eval_every=max(1, rounds // 4))
            exp = api.Experiment(task, BASE, pdef.spec_cls(), ex,
                                 rounds=rounds)

            def sweep(exp=exp):
                hists = exp.compile().run_sweep(_members())
                jax.block_until_ready(hists[-1].final_global)
                return hists

            sec = _time(sweep, reps)
            hists = sweep()
            total = len(SCENARIOS) * rounds
            emit(f'heterogeneity/{name}/{wire}/rounds_per_sec',
                 f'{total / sec:.1f}',
                 f'sec_per_sweep={sec:.3f};S={len(SCENARIOS)};rounds={rounds}')
            for scen, hist in zip(SCENARIOS, hists):
                rl = hist.mean('round_len')
                round_len[(name, wire, scen)] = rl
                emit(f'heterogeneity/{name}/{wire}/{scen}/round_len',
                     f'{rl:.2f}',
                     f'eur={hist.mean("eur"):.3f};'
                     f'final_loss={hist.best_eval["loss"]:.6f}')
                out['cells'].append({
                    'protocol': name, 'wire': wire, 'scenario': scen,
                    'round_len': rl, 'eur': hist.mean('eur'),
                    'sr': hist.mean('sr'),
                    'best_loss': hist.best_eval['loss'],
                    'evals': [(r, e['loss']) for r, e in hist.evals()],
                })
    # the headline: wire layout changes the event stream, per scenario
    out['wire_round_len_delta'] = [
        {'protocol': name, 'scenario': scen,
         'f32': round_len[(name, 'f32', scen)],
         'int8': round_len[(name, 'int8', scen)],
         'delta': round_len[(name, 'int8', scen)]
                  - round_len[(name, 'f32', scen)]}
        for name in PROTOCOLS for scen in SCENARIOS]
    if json_path:
        with open(json_path, 'w') as f:
            json.dump(out, f, indent=1)
        print(f'# wrote {json_path}', flush=True)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--smoke', action='store_true',
                    help='tiny-parameter CI pass (6 rounds, 1 rep)')
    ap.add_argument('--json', default=None, metavar='FILE',
                    help='write the scenario grid '
                         '(e.g. BENCH_heterogeneity.json)')
    args = ap.parse_args(argv)
    if args.smoke:
        run(rounds=6, reps=1, json_path=args.json or
            'BENCH_heterogeneity.json')
    else:
        run(json_path=args.json)


if __name__ == '__main__':
    main()
