"""Roofline report — renders EXPERIMENTS.md §Roofline from the dry-run
results (results/dryrun.jsonl).  One row per (arch x shape x mesh)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), '..', 'results',
                       'dryrun.jsonl')


def rows(path=RESULTS):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def run():
    rs = rows()
    if not rs:
        emit('roofline/missing', '0', 'run repro.launch.dryrun --all first')
        return
    for r in rs:
        dom = {'compute': r['t_compute_s'], 'memory': r['t_memory_s'],
               'collective': r['t_collective_s']}[r['bottleneck']]
        emit(f'roofline/{r["arch"]}/{r["shape"]}/{r["mesh"]}',
             f'{dom * 1e6:.0f}',
             f'bottleneck={r["bottleneck"]};tc={r["t_compute_s"]:.3e};'
             f'tm={r["t_memory_s"]:.3e};tcoll={r["t_collective_s"]:.3e};'
             f'useful={r["useful_ratio"] if r["useful_ratio"] else 0:.2f};'
             f'peak_GiB={r["peak_bytes"] / 2**30:.1f}')


def markdown_table(path=RESULTS):
    """Render the §Roofline markdown table."""
    rs = rows(path)
    out = ['| arch | shape | mesh | profile/step | t_compute (s) | '
           't_memory (s) | t_collective (s) | bottleneck | 6ND/HLO | '
           'peak GiB/dev |',
           '|---|---|---|---|---|---|---|---|---|---|']
    for r in sorted(rs, key=lambda r: (r['arch'], r['shape'], r['mesh'],
                                       r.get('profile', 'tp'))):
        ur = f"{r['useful_ratio']:.2f}" if r['useful_ratio'] else '-'
        tag = f"{r.get('profile', 'tp')}/{r.get('step', '?')}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {tag} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {ur} | {r['peak_bytes'] / 2**30:.1f} |")
    return '\n'.join(out)


if __name__ == '__main__':
    run()
