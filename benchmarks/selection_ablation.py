"""Beyond-paper ablation: what does CFCFM's *compensatory* rule buy?

Compares Algorithm 1 (priority to clients not picked last round) against
plain first-come-first-merge (same post-training selection, no
compensation) on participation fairness: per-client pick rates across a
heterogeneous population.  The compensation is the paper's §III-E bias
mechanism made operational.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import selection


def run(m=40, cr=0.3, C=0.4, rounds=2000, seed=0):
    rng = np.random.default_rng(seed)
    speed_rank = np.linspace(1.0, 10.0, m)   # client 0 fastest .. m-1 slowest
    for policy in ('cfcfm', 'fcfs'):
        picked_prev = np.zeros(m, bool)
        picks = np.zeros(m)
        for _ in range(rounds):
            crashed = rng.random(m) < cr
            arrival = speed_rank * rng.uniform(0.5, 1.5, m)
            arrival = np.where(~crashed, arrival, np.inf)
            prev = picked_prev if policy == 'cfcfm' else np.zeros(m, bool)
            sel = selection.cfcfm(arrival, ~crashed, prev, C, 1e9)
            picks += sel.picked
            picked_prev = sel.picked
        rates = picks / rounds
        fastest, slowest = rates[: m // 4].mean(), rates[-m // 4:].mean()
        # Gini coefficient of participation
        r = np.sort(rates)
        gini = (2 * np.arange(1, m + 1) - m - 1) @ r / (m * r.sum())
        emit(f'selection_ablation/{policy}',
             f'{fastest / max(slowest, 1e-9):.2f}',
             f'fast_q_rate={fastest:.3f};slow_q_rate={slowest:.3f};'
             f'gini={gini:.3f}')


if __name__ == '__main__':
    run()
