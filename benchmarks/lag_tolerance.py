"""Paper Figs. 3-4 — impact of lag tolerance tau on best loss, SR, EUR, VV.

Task 1 (regression) setup, tau in 1..10, C in {0.1, 0.5, 1.0},
cr in {0.3, 0.7} — as in §III-D.

The whole 36-cell grid runs as ONE fleet (``federation.run_sweep``): every
cell shares the task and client population (same env seed => same
partitions), differing only in crash rate / fraction / lag tolerance, so
all 36 simulations execute in a single vmapped-scan dispatch per eval
segment instead of paying a fresh dispatch per cell.
"""
from __future__ import annotations

import itertools

from benchmarks.common import emit, make_env
from repro.core import federation
from repro.data import make_regression, partition
from repro.data.tasks import regression_task

CRS = (0.3, 0.7)
CS = (0.1, 0.5, 1.0)
TAUS = (1, 2, 3, 5, 7, 10)


def run(rounds: int = 60, seed: int = 0):
    grid = list(itertools.product(CRS, CS, TAUS))
    members = [federation.SweepMember(
        env=make_env('task1_regression', cr, seed=seed),
        fraction=C, lag_tolerance=tau) for cr, C, tau in grid]

    # every member shares the partition layout (same env seed), so one task
    # serves the whole fleet
    env0 = members[0].env
    x, y = make_regression(seed=seed)
    data = partition(x, y, env0.partition_sizes, env0.batch_size, seed=seed)
    task = regression_task(data, lr=1e-3, epochs=env0.epochs)

    hists = federation.run_sweep(task, members, rounds=rounds,
                                 eval_every=rounds // 5)
    for (cr, C, tau), h in zip(grid, hists):
        emit(f'lag_tolerance/cr{cr}/C{C}/tau{tau}',
             f'{h.best_eval["loss"]:.4f}',
             f'sr={h.mean("sr"):.3f};eur={h.mean("eur"):.3f};'
             f'vv={h.mean("vv"):.3f}')


if __name__ == '__main__':
    run()
