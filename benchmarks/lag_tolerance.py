"""Paper Figs. 3-4 — impact of lag tolerance tau on best loss, SR, EUR, VV.

Task 1 (regression) setup, tau in 1..10, C in {0.1, 0.5, 1.0},
cr in {0.3, 0.7} — as in §III-D.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, make_env, run_protocol
from repro.data import make_regression, partition
from repro.data.tasks import regression_task


def run(rounds: int = 60, seed: int = 0):
    x, y = make_regression(seed=seed)
    for cr in (0.3, 0.7):
        for C in (0.1, 0.5, 1.0):
            for tau in (1, 2, 3, 5, 7, 10):
                env = make_env('task1_regression', cr, seed=seed)
                data = partition(x, y, env.partition_sizes, env.batch_size,
                                 seed=seed)
                task = regression_task(data, lr=1e-3, epochs=env.epochs)
                h = run_protocol('safa', env, C, rounds, lag_tolerance=tau,
                                 task=task, eval_every=rounds // 5)
                emit(f'lag_tolerance/cr{cr}/C{C}/tau{tau}',
                     f'{h.best_eval["loss"]:.4f}',
                     f'sr={h.mean("sr"):.3f};eur={h.mean("eur"):.3f};'
                     f'vv={h.mean("vv"):.3f}')


if __name__ == '__main__':
    run()
