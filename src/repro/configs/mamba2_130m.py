"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='mamba2-130m',
    family='ssm',
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_chunk=128,
)
