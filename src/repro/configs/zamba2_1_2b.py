"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].  38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  The shared transformer block (attention + MLP with *shared
weights*) is applied every 6 Mamba2 layers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='zamba2-1.2b',
    family='hybrid',
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    mlp_kind='swiglu',
    ssm_state=64,
    ssm_headdim=64,
    attn_every=6,
)
