"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

The InternViT-6B vision tower is STUBBED per the assignment carve-out:
``input_specs`` provides precomputed patch embeddings [B, n_patches, d_model]
which the language backbone consumes through a learned projector
(early fusion: patches prepended to the token sequence).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='internvl2-26b',
    family='vlm',
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,      # padded to 92672 internally (vocab_pad_multiple)
    mlp_kind='swiglu',
    n_patches=256,
)
