"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].  24L(enc) + 24L(dec) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865.

The mel-spectrogram + conv feature extractor is STUBBED per the assignment
carve-out: ``input_specs`` provides precomputed frame embeddings
[B, enc_seq=1500, d_model] consumed by the transformer encoder.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='whisper-medium',
    family='audio',
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_kind='gelu',
    enc_layers=24,
    enc_seq=1500,
)
