"""Architecture registry: ``get_config('<arch-id>')`` for the 10 assigned
architectures, plus input-shape definitions and paper-task FL settings."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    'h2o-danube-3-4b',
    'minitron-4b',
    'nemotron-4-340b',
    'zamba2-1.2b',
    'internvl2-26b',
    'llama4-maverick-400b-a17b',
    'llama4-scout-17b-a16e',
    'qwen3-1.7b',
    'mamba2-130m',
    'whisper-medium',
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace('-', '_').replace('.', '_')


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f'unknown arch {arch_id!r}; known: {ARCH_IDS}')
    mod = importlib.import_module(f'repro.configs.{_module_name(arch_id)}')
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    'train_4k': InputShape('train_4k', 4_096, 256, 'train'),
    'prefill_32k': InputShape('prefill_32k', 32_768, 32, 'prefill'),
    'decode_32k': InputShape('decode_32k', 32_768, 128, 'decode'),
    'long_500k': InputShape('long_500k', 524_288, 1, 'decode'),
}

# long_500k requires decode memory sub-linear in (or bounded against) context:
# SSM state (mamba2), hybrid SSM + bounded attn invocations (zamba2), or
# native sliding-window KV (h2o-danube).  Pure full-attention archs skip it
# (DESIGN.md §4).
LONG_CONTEXT_ARCHS = {'mamba2-130m', 'zamba2-1.2b', 'h2o-danube-3-4b'}


def shape_supported(arch_id: str, shape_name: str) -> bool:
    if shape_name == 'long_500k':
        return arch_id in LONG_CONTEXT_ARCHS
    return True


# ---------------------------------------------------------------------------
# Paper FL experiment settings (Table II)
# ---------------------------------------------------------------------------

PAPER_TASKS = {
    'task1_regression': dict(m=5, dataset_size=506, rounds=100, epochs=3,
                             batch_size=5, lr=1e-4, t_lim=830.0, features=13),
    'task2_cnn': dict(m=100, dataset_size=70_000, rounds=50, epochs=5,
                      batch_size=40, lr=1e-3, t_lim=5600.0, features=(28, 28)),
    'task3_svm': dict(m=500, dataset_size=186_480, rounds=100, epochs=5,
                      batch_size=100, lr=1e-2, t_lim=1620.0, features=35),
}
