"""llama4-maverick-400b-a17b [moe] — 128 experts, top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1 +
shared expert.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id='llama4-maverick-400b-a17b',
    family='moe',
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    mlp_kind='swiglu',
    n_experts=128,
    moe_shared_expert=True,
    moe_every=2,          # maverick interleaves dense and MoE layers
)
