"""Pytree checkpointing (npz) including federated protocol state, so a
federation can stop and resume mid-training.

Two layers:

* ``save`` / ``restore`` — generic pytree <-> npz with a JSON metadata
  sidecar entry, exact for every array dtype numpy can serialise (the
  float32 model state round-trips bit for bit).
* ``save_run`` / ``load_run`` — the run-state format used by
  ``repro.api.CompiledRunner``: the scan carry (global/local/cache model
  trees, single-run or fleet-stacked), the host schedule cursor (how many
  eval segments completed), the histories-so-far (``History.to_dict``)
  and a spec fingerprint that must match on resume.  A killed run resumed
  from the latest checkpoint replays only the remaining segments and ends
  bit-identical to an uninterrupted run.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _npz_path(path: str) -> str:
    """np.savez appends '.npz' when missing; normalise so save and load
    always agree on the on-disk name."""
    return path if path.endswith('.npz') else path + '.npz'


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    path = _npz_path(path)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(path, __meta__=json.dumps(metadata or {}), **arrays)


def restore(path: str, like: Any):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, metadata)."""
    data = np.load(_npz_path(path), allow_pickle=False)
    meta = json.loads(str(data['__meta__']))
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat[0]:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p))) for p in path_k)
        arr = data[key]
        dtype = getattr(leaf, 'dtype', None)
        leaves.append(jnp.asarray(arr, dtype=dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves), meta


# ---------------------------------------------------------------------------
# Run-state checkpoints (repro.api.CompiledRunner)
# ---------------------------------------------------------------------------

def exists(path: str) -> bool:
    return os.path.exists(_npz_path(path))


def save_run(path: str, state: Any, *, seg_done: int, histories: list,
             fingerprint: str) -> None:
    """Persist a (possibly partial) run: the model-state pytree, how many
    eval segments completed, the per-member history dicts, and the
    fingerprint of the producing spec.  Atomic enough for a kill between
    segments: the previous checkpoint is replaced only by a complete
    ``np.savez`` write to a temp file."""
    path = _npz_path(path)
    tmp = path + '.tmp.npz'
    save(tmp, state, metadata={
        'seg_done': int(seg_done),
        'histories': [h.to_dict() for h in histories],
        'fingerprint': fingerprint,
    })
    os.replace(tmp, path)


def load_run(path: str, like: Any, *, fingerprint: str):
    """Load a run checkpoint written by ``save_run`` into the structure of
    ``like``.  Raises ``ValueError`` when the stored fingerprint does not
    match — resuming under a different spec would silently produce a
    History that belongs to neither run.  Returns
    (state, seg_done, history_dicts)."""
    state, meta = restore(path, like)
    if meta.get('fingerprint') != fingerprint:
        raise ValueError(
            'checkpoint fingerprint mismatch: the checkpoint at '
            f'{path!r} was written by a different experiment spec '
            '(protocol/exec/rounds/seed/env all participate); refusing '
            'to resume')
    return state, int(meta['seg_done']), meta['histories']
