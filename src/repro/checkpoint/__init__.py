"""Pytree checkpointing (npz) including federated protocol state, so a
federation can stop and resume mid-training."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(path, __meta__=json.dumps(metadata or {}), **arrays)


def restore(path: str, like: Any):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, metadata)."""
    data = np.load(path, allow_pickle=False)
    meta = json.loads(str(data['__meta__']))
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat[0]:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p))) for p in path_k)
        arr = data[key]
        dtype = getattr(leaf, 'dtype', None)
        leaves.append(jnp.asarray(arr, dtype=dtype))
    return jax.tree_util.tree_unflatten(flat[1], leaves), meta
