"""Logical-axis sharding rules (MaxText-style).

Parameters and activations carry *logical* axis names; rules map them to
physical mesh axes.  Helpers gracefully drop axes that are absent from the
mesh or that don't divide the dimension, so one rule set serves the 1-device
CPU test mesh, the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> preferred mesh axes (first that fits wins, in order)
DEFAULT_RULES = {
    'clients': ('pod', 'data'),     # FL silo mode: client dim over pod+data
    'batch': ('pod', 'data'),       # serving / plain training
    'local_batch': (),              # per-client batch: unsharded
    'vocab': ('model',),
    'mlp': ('model',),
    'qkv': ('model',),
    'kv': ('model',),
    'experts': ('model',),
    'ssm_inner': ('model',),
    'heads': ('model',),
    'embed': (),
    'embed_out': (),
    'layers': (),
    'seq': (),
}

# Beyond-paper §Perf profile: FSDP-style weight sharding on the model axis.
# Weights shard on their d_model (row) dim and are all-gathered per layer;
# activations stay local to each client slice, eliminating the per-layer
# tensor-parallel activation all-reduces that dominate small-model FL
# training (EXPERIMENTS.md §Perf).  Experts keep expert-parallel sharding.
FSDP_RULES = {
    'clients': ('pod', 'data'),
    'batch': ('pod', 'data'),
    'vocab': ('model',),            # embed/unembed stay vocab-sharded
    'mlp': (),
    'qkv': (),
    'kv': (),
    'experts': ('model',),
    'ssm_inner': (),
    'heads': (),
    'embed': ('model',),            # shard the d_model row dim instead
    'embed_out': (),
    'layers': (),
    'seq': (),
    'local_batch': ('model',),      # ZeRO-3 style: per-client batch is
                                    # data-parallel across the client's
                                    # model-axis slice; weights gathered
}

# Multi-pod variant: clients on `data` only (C=16), so each client spans
# pod x model = 32 chips; per-client batch (16) stays divisible by the
# model axis and the seq dim shards over `pod` (sequence parallelism
# between pods inside a client).  With clients over (pod, data) the
# per-client batch (256/32 = 8) does not divide the 16-way model axis and
# ZeRO-3 degenerates (measured — EXPERIMENTS.md §Perf multi-pod note).
FSDP_MULTIPOD_RULES = dict(FSDP_RULES, clients=('data',), seq=('pod',))

PROFILES = {'tp': DEFAULT_RULES, 'fsdp': FSDP_RULES,
            'fsdp_mp': FSDP_MULTIPOD_RULES}


def _axes_in_mesh(mesh: Mesh, names):
    return tuple(n for n in names if n in mesh.axis_names)


def spec_for(logical_axes, shape, mesh: Mesh, rules=None) -> P:
    """Build a PartitionSpec for one array given its logical axes + shape."""
    rules = rules or DEFAULT_RULES
    used = set()
    entries = []
    for dim, name in zip(shape, logical_axes):
        if name is None or name not in rules:
            entries.append(None)
            continue
        cand = _axes_in_mesh(mesh, rules[name])
        cand = tuple(a for a in cand if a not in used)
        # shrink until the product of axis sizes divides the dim
        while cand and dim % int(np.prod([mesh.shape[a] for a in cand])):
            cand = cand[:-1]
        if not cand:
            entries.append(None)
        elif len(cand) == 1:
            entries.append(cand[0])
            used.add(cand[0])
        else:
            entries.append(cand)
            used.update(cand)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_specs(axes_tree, shapes_tree, mesh: Mesh, rules=None):
    """Map (logical-axes tree, ShapeDtypeStruct tree) -> PartitionSpec tree."""
    return jax.tree.map(
        lambda a, s: spec_for(a, s.shape, mesh, rules), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules=None):
    specs = tree_specs(axes_tree, shapes_tree, mesh, rules)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Optional[Mesh], *logical_axes, rules=None):
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(logical_axes, x.shape, mesh, rules)))


# ---------------------------------------------------------------------------
# Activation sharding context (§Perf): GSPMD's propagation freely re-shards
# scan/vmap interiors, overriding boundary in_shardings — the only reliable
# way to impose a parallelism layout (e.g. ZeRO-3 batch sharding instead of
# tensor parallelism) is to pin activations INSIDE the layer loop.  Model
# code calls ``constrain_act`` on the residual stream; by default it is a
# no-op, and step builders activate it with a (mesh, rules) context at
# trace time.
# ---------------------------------------------------------------------------

_ACT_CTX = None  # (mesh, rules) or None


class activation_sharding:
    def __init__(self, mesh, rules):
        self.ctx = (mesh, rules)

    def __enter__(self):
        global _ACT_CTX
        self._prev = _ACT_CTX
        _ACT_CTX = self.ctx

    def __exit__(self, *exc):
        global _ACT_CTX
        _ACT_CTX = self._prev


def constrain_act(x, *logical_axes):
    if _ACT_CTX is None:
        return x
    mesh, rules = _ACT_CTX
    spec = spec_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, spec)
