"""Optimizers as pure pytree transforms (no optax in this environment).

Clients in the paper use plain mini-batch SGD (Algorithm 2); AdamW is
provided for the LLM-scale silo-mode examples.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]  # (grads, state, params) -> (new_params, new_state)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params):
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new, ()
        vel = jax.tree.map(lambda v, g: momentum * v + g, state, grads)
        new = jax.tree.map(lambda p, v: p - lr * v.astype(p.dtype), params, vel)
        return new, vel

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            'mu': jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            'nu': jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            'count': jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state['count'] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state['mu'], grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state['nu'], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def step(p, m, n):
            upd = (m / c1) / (jnp.sqrt(n / c2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new = jax.tree.map(step, params, mu, nu)
        return new, {'mu': mu, 'nu': nu, 'count': count}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
