"""Model configuration shared by all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0                  # 0 for attention-free (ssm)
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    mlp_kind: str = 'swiglu'          # swiglu | relu2 | gelu
    qk_norm: bool = False
    window: Optional[int] = None      # sliding-window attention size
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    capacity_factor: float = 1.25
    moe_shared_expert: bool = True
    moe_every: int = 1                # llama4-maverick: MoE every 2nd layer
    # SSM / hybrid
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0               # hybrid: shared attn block every k ssm layers
    # encoder-decoder (audio)
    enc_layers: int = 0
    enc_seq: int = 1500               # stubbed frame-embedding length
    # vlm
    n_patches: int = 0                # stubbed patch-embedding count
    # numerics / structure
    dtype: Any = jnp.bfloat16
    remat: bool = True
    vocab_pad_multiple: int = 256
    # attention blocking for the flash path
    q_block: int = 512
    kv_block: int = 512
    attn_impl: str = 'flash_jnp'      # flash_jnp | pallas (TPU swa kernel)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(-(-self.vocab_size // m) * m)

    def reduced(self, **overrides) -> 'ModelConfig':
        """Smoke-test variant of the same family: 2 layers, tiny dims."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16 if self.ssm_state else 128,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=8 if self.enc_layers else self.enc_seq,
            n_patches=4 if self.n_patches else 0,
            attn_every=2 if self.attn_every else 0,
            window=min(self.window, 8) if self.window else None,
            dtype=jnp.float32,
            remat=False,
            vocab_pad_multiple=64,
            q_block=16,
            kv_block=16,
        )
        if self.n_heads:
            d_model = small['d_model']
            hd = 32
            small['n_heads'] = max(1, d_model // hd)
            small['n_kv_heads'] = max(1, min(self.n_kv_heads, small['n_heads']))
            # keep GQA ratio valid
            while small['n_heads'] % small['n_kv_heads']:
                small['n_kv_heads'] -= 1
        else:
            small['n_heads'] = 0
            small['n_kv_heads'] = 0
        small['ssm_headdim'] = 32 if self.ssm_state else self.ssm_headdim
        small.update(overrides)
        return dataclasses.replace(self, **small)
