"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked "dual form": quadratic attention-like computation inside chunks plus
a linear recurrence across chunk boundary states.  Decode is an O(1)
single-step state update, which is what makes the ssm/hybrid architectures
eligible for the 524k-token ``long_500k`` shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def segsum(x):
    """x: [..., T] -> cumulative segment sums [..., T, T]; entry (i, j) =
    sum_{k=j+1..i} x_k for i >= j, -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(T)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk=128, initial_state=None):
    """SSD scan in chunked dual form.

    x: [b, s, h, p]   inputs per head
    dt: [b, s, h]     softplus'd step sizes
    A: [h]            negative per-head decay rates (A = -exp(A_log))
    B, C: [b, s, n]   (single group, broadcast over heads)
    Returns (y [b, s, h, p], final_state [b, h, p, n]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = x.shape[1] // chunk

    xb = x.reshape(b, L, chunk, h, p).astype(jnp.float32)
    dtb = dt.reshape(b, L, chunk, h).astype(jnp.float32)
    Bb = B.reshape(b, L, chunk, n).astype(jnp.float32)
    Cb = C.reshape(b, L, chunk, n).astype(jnp.float32)

    dA = dtb * A.astype(jnp.float32)           # [b,L,c,h]
    dAc = jnp.cumsum(dA, axis=2)               # within-chunk cumsum
    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(segsum(dA.transpose(0, 1, 3, 2)))        # [b,L,h,c,c]
    scores = jnp.einsum('blin,bljn->blij', Cb, Bb)          # [b,L,c,c]
    y_diag = jnp.einsum('blij,blhij,bljh,bljhp->blihp', scores, Lmat, dtb, xb)
    # 2. chunk-final states
    decay_states = jnp.exp(dAc[:, :, -1:, :] - dAc)          # [b,L,c,h]
    states = jnp.einsum('blcn,blch,blch,blchp->blhpn', Bb, decay_states, dtb, xb)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(dAc[:, :, -1, :])                  # [b,L,h]

    if initial_state is None:
        s0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp  # st: [b,h,p,n], dec: [b,h]
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* this chunk

    final, prev_states = jax.lax.scan(
        scan_fn, s0, (states.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,L,h,p,n]
    # 4. inter-chunk outputs
    state_decay_out = jnp.exp(dAc)                           # [b,L,c,h]
    y_off = jnp.einsum('blcn,blhpn,blch->blchp', Cb, prev_states, state_decay_out)
    y = (y_diag + y_off).reshape(b, -1, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(state, x_t, dt_t, A, B_t, C_t):
    """O(1) decode step.  state: [b,h,p,n]; x_t: [b,h,p]; dt_t: [b,h];
    B_t, C_t: [b,n].  Returns (new_state, y_t [b,h,p])."""
    state = state.astype(jnp.float32)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))  # [b,h]
    dBx = jnp.einsum('bh,bhp,bn->bhpn', dt_t.astype(jnp.float32),
                     x_t.astype(jnp.float32), B_t.astype(jnp.float32))
    new = state * dA[:, :, None, None] + dBx
    y = jnp.einsum('bhpn,bn->bhp', new, C_t.astype(jnp.float32))
    return new, y.astype(x_t.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Sequential oracle (step-by-step recurrence) for tests."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    ys = []
    for t in range(s):
        st, y = ssd_step(st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), st


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

CONV_K = 4  # depthwise causal conv kernel width


def init_mamba_block(key, d_model, d_state, headdim, dtype, expand=2):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state  # conv over (x, B, C)
    ks = cm.split_keys(key, 8)
    return {
        'in_proj': cm.param(ks[0], (d_model, 2 * d_inner + 2 * d_state + n_heads),
                            ('embed', 'ssm_inner'), dtype),
        'conv_w': cm.param(ks[1], (CONV_K, conv_ch), (None, 'ssm_inner'), dtype,
                           init=lambda k, s, d: (jax.random.normal(k, s) * 0.1).astype(d)),
        'conv_b': cm.param(ks[2], (conv_ch,), ('ssm_inner',), dtype, init=cm.zeros_init),
        'A_log': cm.param(ks[3], (n_heads,), (None,), jnp.float32,
                          init=lambda k, s, d: jnp.log(jax.random.uniform(k, s, minval=1.0, maxval=16.0)).astype(d)),
        'D': cm.param(ks[4], (n_heads,), (None,), jnp.float32, init=cm.ones_init),
        'dt_bias': cm.param(ks[5], (n_heads,), (None,), jnp.float32,
                            init=lambda k, s, d: jnp.log(jnp.expm1(jax.random.uniform(k, s, minval=1e-3, maxval=0.1))).astype(d)),
        'norm_scale': cm.param(ks[6], (d_inner,), ('ssm_inner',), jnp.float32, init=cm.zeros_init),
        'out_proj': cm.param(ks[7], (d_inner, d_model), ('ssm_inner', 'embed'), dtype),
    }


def _split_in_proj(zxbcdt, d_inner, d_state):
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    return z, x, B, C, dt


def _causal_conv(xbc, w, b):
    """xbc: [batch, seq, ch]; w: [K, ch] depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def apply_mamba_block(p, x, *, d_state, headdim, chunk=128, expand=2):
    bsz, s, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    zxbcdt = jnp.einsum('bsd,de->bse', x, p['in_proj'])
    z, xc, B, C, dt = _split_in_proj(zxbcdt, d_inner, d_state)
    xbc = _causal_conv(jnp.concatenate([xc, B, C], axis=-1), p['conv_w'], p['conv_b'])
    xc, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p['dt_bias'])
    A = -jnp.exp(p['A_log'])
    xh = xc.reshape(bsz, s, n_heads, headdim)
    y, _ = ssd_chunked(xh, dt, A, B, C, chunk=chunk)
    y = y + xh * p['D'][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, d_inner)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p['norm_scale'])
    return jnp.einsum('bsi,id->bsd', y, p['out_proj'])


def init_mamba_cache(bsz, d_model, d_state, headdim, dtype, expand=2):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_ch = d_inner + 2 * d_state
    return {
        'conv': jnp.zeros((bsz, CONV_K - 1, conv_ch), dtype),
        'ssm': jnp.zeros((bsz, n_heads, headdim, d_state), jnp.float32),
    }


def step_mamba_block(p, cache, x_t, *, d_state, headdim, expand=2):
    """x_t: [b, 1, d_model] -> (new_cache, y_t [b, 1, d_model])."""
    bsz, _, d_model = x_t.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    zxbcdt = jnp.einsum('bsd,de->bse', x_t, p['in_proj'])[:, 0]
    z, xc, B, C, dt = _split_in_proj(zxbcdt, d_inner, d_state)
    conv_in = jnp.concatenate([xc, B, C], axis=-1)           # [b, ch]
    conv_win = jnp.concatenate([cache['conv'], conv_in[:, None]], axis=1)  # [b,K,ch]
    conv_out = jnp.einsum('bkc,kc->bc', conv_win, p['conv_w']) + p['conv_b']
    conv_out = jax.nn.silu(conv_out)
    xc, B, C = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p['dt_bias'])
    A = -jnp.exp(p['A_log'])
    xh = xc.reshape(bsz, n_heads, headdim)
    new_ssm, y = ssd_step(cache['ssm'], xh, dt, A, B, C)
    y = y + xh * p['D'][None, :, None].astype(y.dtype)
    y = y.reshape(bsz, d_inner)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p['norm_scale'])
    y = jnp.einsum('bi,id->bd', y, p['out_proj'])
    new_cache = {'conv': conv_win[:, 1:], 'ssm': new_ssm}
    return new_cache, y[:, None, :]
