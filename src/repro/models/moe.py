"""Top-1 routed Mixture-of-Experts (llama4-style early-fusion MoE layers).

Capacity-based dispatch in the Mesh-TensorFlow style: tokens are grouped,
each token routed to its top-1 expert, tokens beyond an expert's capacity are
dropped (residual passes through).  Experts are sharded over the ``model``
mesh axis (expert parallelism); under GSPMD the dispatch/combine einsums
lower to all-to-all-style collectives.

llama4 additionally has a *shared* expert applied to every token; we include
it (``shared_expert=True``) since it's part of the cited architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import mlp as mlp_mod


def init_moe(key, d_model, d_ff, n_experts, dtype, shared_expert=True):
    ks = cm.split_keys(key, 5)
    p = {
        'router': cm.param(ks[0], (d_model, n_experts), ('embed', 'experts'),
                           jnp.float32),
        'w_gate': cm.param(ks[1], (n_experts, d_model, d_ff),
                           ('experts', 'embed', 'mlp'), dtype),
        'w_up': cm.param(ks[2], (n_experts, d_model, d_ff),
                         ('experts', 'embed', 'mlp'), dtype),
        'w_down': cm.param(ks[3], (n_experts, d_ff, d_model),
                           ('experts', 'mlp', 'embed'), dtype),
    }
    if shared_expert:
        p['shared'] = mlp_mod.init_mlp(ks[4], d_model, d_ff, 'swiglu', dtype)
    return p


def apply_moe(p, x, *, capacity_factor=1.25, group_size=None):
    """x: [B, S, M] -> (y, aux) where aux carries router load-balance stats."""
    B, S, M = x.shape
    E = p['router'].shape[-1]
    tokens = x.reshape(B * S, M)
    N = B * S
    if group_size is None:
        group_size = min(N, 1024)
    # pad N to a multiple of group_size
    pad = (-N) % group_size
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    G = tokens.shape[0] // group_size
    tg = tokens.reshape(G, group_size, M)

    logits = jnp.einsum('gsm,me->gse', tg.astype(jnp.float32), p['router'])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jnp.max(probs, axis=-1), jnp.argmax(probs, axis=-1)  # [G,S]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)               # [G,S,E]

    C = max(1, int(capacity_factor * group_size / E))
    # position of each token within its expert queue
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1.0                  # [G,S,E]
    keep = (pos >= 0) & (pos < C)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)
    poh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=jnp.float32)  # drops -> all-zero row via index C
    dispatch = onehot[..., None] * poh                               # [G,S,E,C]
    # (dropped tokens already vanish: their ``poh`` row is all-zero)
    combine = dispatch * gate[..., None, None]

    xin = jnp.einsum('gsec,gsm->egcm', dispatch.astype(tg.dtype), tg)  # [E,G,C,M]
    h_gate = jnp.einsum('egcm,emf->egcf', xin, p['w_gate'])
    h_up = jnp.einsum('egcm,emf->egcf', xin, p['w_up'])
    h = jax.nn.silu(h_gate) * h_up
    xout = jnp.einsum('egcf,efm->egcm', h, p['w_down'])               # [E,G,C,M]
    y = jnp.einsum('gsec,egcm->gsm', combine.astype(xout.dtype), xout)

    y = y.reshape(-1, M)[:N].reshape(B, S, M)
    if 'shared' in p:
        y = y + mlp_mod.apply_mlp(p['shared'], x, 'swiglu')

    # load-balance aux loss (Shazeer-style): E * sum(frac_tokens * frac_probs)
    frac_tokens = jnp.mean(onehot, axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = {'load_balance_loss': E * jnp.sum(frac_tokens * frac_probs),
           'dropped_frac': 1.0 - jnp.sum(dispatch) / max(1, N)}
    return y, aux
