"""Feed-forward variants: SwiGLU (llama-style) and squared-ReLU (nemotron)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def init_mlp(key, d_model, d_ff, kind: str, dtype):
    ks = cm.split_keys(key, 3)
    p = {
        'w_up': cm.param(ks[0], (d_model, d_ff), ('embed', 'mlp'), dtype),
        'w_down': cm.param(ks[1], (d_ff, d_model), ('mlp', 'embed'), dtype),
    }
    if kind == 'swiglu':
        p['w_gate'] = cm.param(ks[2], (d_model, d_ff), ('embed', 'mlp'), dtype)
    return p


def apply_mlp(p, x, kind: str):
    up = jnp.einsum('...d,df->...f', x, p['w_up'])
    if kind == 'swiglu':
        gate = jnp.einsum('...d,df->...f', x, p['w_gate'])
        h = jax.nn.silu(gate) * up
    elif kind == 'relu2':
        h = jnp.square(jax.nn.relu(up))
    elif kind == 'gelu':
        h = jax.nn.gelu(up)
    else:
        raise ValueError(kind)
    return jnp.einsum('...f,fd->...d', h, p['w_down'])
