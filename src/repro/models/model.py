"""Public model facade: build once from a ModelConfig, use everywhere."""
from __future__ import annotations

import jax

from repro.models import common as cm
from repro.models import decode as dec
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters --------------------------------------------------------
    def init(self, key):
        """Concrete parameter values (CPU-feasible configs only)."""
        values, _ = cm.unbox(tfm.init_params(key, self.cfg))
        return values

    def param_axes(self):
        """Static logical-axes tree (no compute)."""
        with cm.abstract_init():
            _, axes = cm.unbox(tfm.init_params(jax.random.PRNGKey(0), self.cfg))
        return axes

    def param_shapes(self):
        """ShapeDtypeStruct tree (no compute)."""
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def n_params(self) -> int:
        import math
        return sum(math.prod(s.shape) for s in jax.tree.leaves(self.param_shapes()))

    # -- forward ------------------------------------------------------------
    def loss(self, params, batch):
        return tfm.loss_fn(params, batch, self.cfg)

    def logits(self, params, batch):
        return tfm.forward_logits(params, batch, self.cfg)

    # -- serving -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, length: int = 0):
        return dec.init_cache(self.cfg, batch, max_len, length)

    def decode_step(self, params, cache, tokens):
        return dec.decode_step(params, cache, tokens, self.cfg)

    def prefill(self, params, cache, tokens):
        return dec.prefill(params, cache, tokens, self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
