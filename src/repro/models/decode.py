"""Serving path: KV / SSM caches and single-token decode steps.

``decode_step`` consumes a cache representing ``length`` already-processed
tokens and produces logits for one new token — this is what the
``decode_32k`` / ``long_500k`` dry-run shapes lower.

Sliding-window architectures use a ring-buffer cache of ``window`` slots, so
their decode memory is O(window), independent of context length — that is
what qualifies them for ``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.config import ModelConfig


def kv_cache_slots(cfg: ModelConfig, max_len: int) -> int:
    if cfg.window is not None:
        return min(max_len, cfg.window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, length: int = 0):
    """Zero-initialised cache pytree.  ``length`` marks how many tokens the
    cache is considered to already hold (for dry-run decode shapes we set
    it to seq_len)."""
    hd = cfg.head_dim
    c = {'length': jnp.asarray(length, jnp.int32)}
    if cfg.family in ('dense', 'moe', 'vlm', 'audio'):
        S = kv_cache_slots(cfg, max_len)
        L = cfg.n_layers
        c['k'] = jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), cfg.dtype)
        c['v'] = jnp.zeros((L, batch, S, cfg.n_kv_heads, hd), cfg.dtype)
        c['positions'] = jnp.where(jnp.arange(S) < length,
                                   jnp.arange(S, dtype=jnp.int32), -1)
    if cfg.family == 'audio':
        c['xk'] = jnp.zeros((cfg.n_layers, batch, cfg.enc_seq,
                             cfg.n_kv_heads, hd), cfg.dtype)
        c['xv'] = jnp.zeros_like(c['xk'])
    if cfg.family in ('ssm', 'hybrid'):
        d_inner = 2 * cfg.d_model
        n_heads_ssm = d_inner // cfg.ssm_headdim
        conv_ch = d_inner + 2 * cfg.ssm_state
        L = cfg.n_layers
        c['conv'] = jnp.zeros((L, batch, ssm_mod.CONV_K - 1, conv_ch), cfg.dtype)
        c['ssm'] = jnp.zeros((L, batch, n_heads_ssm, cfg.ssm_headdim,
                              cfg.ssm_state), jnp.float32)
    if cfg.family == 'hybrid':
        n_attn = max(0, len(tfm.hybrid_groups(cfg)) - 1)
        S = kv_cache_slots(cfg, max_len)
        c['k'] = jnp.zeros((n_attn, batch, S, cfg.n_kv_heads, hd), cfg.dtype)
        c['v'] = jnp.zeros_like(c['k'])
        c['positions'] = jnp.where(jnp.arange(S) < length,
                                   jnp.arange(S, dtype=jnp.int32), -1)
    return c


def _attn_decode(layer_attn, h, kc, vc, positions, length, cfg: ModelConfig):
    """One attention decode step against (and updating) a cache slice.

    h: [B,1,D]; kc/vc: [B,S,KH,hd].  Returns (attn_out, new_kc, new_vc,
    new_positions)."""
    B = h.shape[0]
    S = kc.shape[1]
    pos = length  # position of the incoming token
    q, k, v = tfm._project_qkv(layer_attn, h, cfg, pos[None].astype(jnp.int32))
    slot = jax.lax.rem(pos, S)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
    positions = jax.lax.dynamic_update_slice(positions, pos[None].astype(jnp.int32), (slot,))
    cache_pos = jnp.broadcast_to(positions[None, :], (B, S))
    o = attn_mod.decode_attention(q, kc, vc, pos + 1, window=cfg.window,
                                  cache_positions=cache_pos)
    o = jnp.einsum('bse,ed->bsd', o.reshape(B, 1, -1), layer_attn['wo'])
    return o, kc, vc, positions


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """tokens: [B, 1] -> (new_cache, logits [B, V_padded])."""
    x = tfm.embed_tokens(params, tokens, cfg)
    length = cache['length']
    new_cache = dict(cache)

    if cfg.family in ('dense', 'moe', 'vlm'):
        positions0 = cache['positions']

        def one_layer(layer, h, kc, vc):
            xn = cm.rms_norm(h, layer['ln1'])
            o, kc, vc, new_pos = _attn_decode(layer['attn'], xn, kc, vc,
                                              positions0, length, cfg)
            h = h + o
            pre = cm.rms_norm(h, layer['ln2'])
            if 'moe' in layer:
                # no-drop capacity at decode time: a single-token routing
                # group would otherwise drop tokens that competed fine in
                # the full prefill group (train/serve capacity mismatch)
                y, _ = moe_mod.apply_moe(
                    layer['moe'], pre,
                    capacity_factor=float(max(cfg.capacity_factor,
                                              cfg.n_experts)))
            else:
                y = mlp_mod.apply_mlp(layer['mlp'], pre, cfg.mlp_kind)
            return h + y, kc, vc, new_pos

        layers = params['layers']
        if isinstance(layers, dict) and 'moe' in layers and 'dense' in layers:
            nb = cfg.n_layers // cfg.moe_every
            kcb = cache['k'].reshape((nb, cfg.moe_every) + cache['k'].shape[1:])
            vcb = cache['v'].reshape((nb, cfg.moe_every) + cache['v'].shape[1:])

            def block_body(carry, inputs):
                h, positions = carry
                block, kcs, vcs = inputs

                def sub(carry2, inp):
                    h2, pos2 = carry2
                    layer, kc1, vc1 = inp
                    h2, kc1, vc1, np_ = one_layer(layer, h2, kc1, vc1)
                    return (h2, np_), (kc1, vc1)

                (h, positions), (kd, vd) = jax.lax.scan(
                    sub, (h, positions), (block['dense'], kcs[:-1], vcs[:-1]))
                h, km, vm, positions = one_layer(block['moe'], h, kcs[-1], vcs[-1])
                nk = jnp.concatenate([kd, km[None]], axis=0)
                nv = jnp.concatenate([vd, vm[None]], axis=0)
                return (h, positions), (nk, nv)

            (x, new_pos), (nk, nv) = jax.lax.scan(
                block_body, (x, positions0), (layers, kcb, vcb))
            nk = nk.reshape(cache['k'].shape)
            nv = nv.reshape(cache['v'].shape)
        else:
            def body(carry, inputs):
                h, positions = carry
                layer, kc, vc = inputs
                h, kc, vc, new_pos = one_layer(layer, h, kc, vc)
                return (h, new_pos), (kc, vc)

            (x, new_pos), (nk, nv) = jax.lax.scan(
                body, (x, positions0), (layers, cache['k'], cache['v']))
        new_cache.update(k=nk, v=nv, positions=new_pos)

    elif cfg.family == 'ssm':
        def body(h, inputs):
            layer, conv_c, ssm_c = inputs
            xn = cm.rms_norm(h, layer['ln1'])
            nc, y = ssm_mod.step_mamba_block(
                layer['mamba'], {'conv': conv_c, 'ssm': ssm_c}, xn,
                d_state=cfg.ssm_state, headdim=cfg.ssm_headdim)
            return h + y, (nc['conv'], nc['ssm'])

        x, (nconv, nssm) = jax.lax.scan(
            body, x, (params['layers'], cache['conv'], cache['ssm']))
        new_cache.update(conv=nconv, ssm=nssm)

    elif cfg.family == 'hybrid':
        groups = tfm.hybrid_groups(cfg)
        nconv, nssm = [], []
        nk, nv = [], []
        new_pos = cache['positions']
        for gi, (s, e) in enumerate(groups):
            chunk = jax.tree.map(lambda a, lo=s, hi=e: a[lo:hi],
                                 params['layers'])

            def body(h, inputs):
                layer, conv_c, ssm_c = inputs
                xn = cm.rms_norm(h, layer['ln1'])
                nc, y = ssm_mod.step_mamba_block(
                    layer['mamba'], {'conv': conv_c, 'ssm': ssm_c}, xn,
                    d_state=cfg.ssm_state, headdim=cfg.ssm_headdim)
                return h + y, (nc['conv'], nc['ssm'])

            x, (cconv, cssm) = jax.lax.scan(
                body, x, (chunk, cache['conv'][s:e], cache['ssm'][s:e]))
            nconv.append(cconv)
            nssm.append(cssm)
            if gi < len(groups) - 1:
                layer = params['shared_attn']
                xn = cm.rms_norm(x, layer['ln1'])
                o, kc, vc, new_pos = _attn_decode(
                    layer['attn'], xn, cache['k'][gi], cache['v'][gi],
                    cache['positions'], length, cfg)
                x = x + o
                pre = cm.rms_norm(x, layer['ln2'])
                x = x + mlp_mod.apply_mlp(layer['mlp'], pre, cfg.mlp_kind)
                nk.append(kc)
                nv.append(vc)
        new_cache.update(conv=jnp.concatenate(nconv), ssm=jnp.concatenate(nssm))
        if nk:
            new_cache.update(k=jnp.stack(nk), v=jnp.stack(nv), positions=new_pos)

    elif cfg.family == 'audio':
        positions0 = cache['positions']

        def body(carry, inputs):
            h, positions = carry
            layer, kc, vc, xk, xv = inputs
            xn = cm.rms_norm(h, layer['ln1'])
            o, kc, vc, new_pos = _attn_decode(layer['attn'], xn, kc, vc,
                                              positions0, length, cfg)
            h = h + o
            h = h + tfm.cross_attn_block(layer['xattn'],
                                         cm.rms_norm(h, layer['ln_x']),
                                         (xk, xv), cfg)
            h = h + mlp_mod.apply_mlp(layer['mlp'],
                                      cm.rms_norm(h, layer['ln2']), 'gelu')
            return (h, new_pos), (kc, vc)

        (x, new_pos), (nk, nv) = jax.lax.scan(
            body, (x, positions0),
            (params['dec_layers'], cache['k'], cache['v'],
             cache['xk'], cache['xv']))
        new_cache.update(k=nk, v=nv, positions=new_pos)
    else:
        raise ValueError(cfg.family)

    new_cache['length'] = length + 1
    x = cm.rms_norm(x, params['ln_f'])
    logits = jnp.einsum('bsd,dv->bsv', x, params['unembed'])[:, 0]
    return new_cache, logits


def prefill(params, cache, tokens, cfg: ModelConfig):
    """Sequential prefill via decode steps (correct, not fast — used by tests
    and small-scale serving examples; bulk prefill benchmarking uses
    ``forward_logits``)."""
    def step(c, tok):
        c, logits = decode_step(params, c, tok[:, None], cfg)
        return c, logits
    cache, all_logits = jax.lax.scan(step, cache, tokens.T)
    return cache, jnp.transpose(all_logits, (1, 0, 2))
