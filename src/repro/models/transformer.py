"""Decoder stacks for all assigned families.

Uniform layers are stacked ([L, ...] leading dim) and driven by
``lax.scan`` so the lowered HLO stays small even for 96-layer configs —
essential for CPU-hosted multi-pod dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.models import attention as attn_mod
from repro.models import common as cm
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attn_layer(key, cfg: ModelConfig):
    hd = cfg.head_dim
    ks = cm.split_keys(key, 6)
    p = {
        'wq': cm.param(ks[0], (cfg.d_model, cfg.n_heads * hd), ('embed', 'qkv'), cfg.dtype),
        'wk': cm.param(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), ('embed', 'kv'), cfg.dtype),
        'wv': cm.param(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), ('embed', 'kv'), cfg.dtype),
        'wo': cm.param(ks[3], (cfg.n_heads * hd, cfg.d_model), ('qkv', 'embed'), cfg.dtype),
    }
    if cfg.qk_norm:
        p['q_norm'] = cm.param(ks[4], (hd,), (None,), jnp.float32, init=cm.zeros_init)
        p['k_norm'] = cm.param(ks[5], (hd,), (None,), jnp.float32, init=cm.zeros_init)
    return p


def init_dense_layer(key, cfg: ModelConfig):
    k1, k2, k3, k4 = cm.split_keys(key, 4)
    layer = {
        'ln1': cm.param(k1, (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
        'attn': init_attn_layer(k2, cfg),
        'ln2': cm.param(k3, (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
    }
    if cfg.n_experts:
        layer['moe'] = moe_mod.init_moe(k4, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                        cfg.dtype, cfg.moe_shared_expert)
    else:
        layer['mlp'] = mlp_mod.init_mlp(k4, cfg.d_model, cfg.d_ff, cfg.mlp_kind, cfg.dtype)
    return layer


def init_ssm_layer(key, cfg: ModelConfig):
    k1, k2 = cm.split_keys(key, 2)
    return {
        'ln1': cm.param(k1, (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
        'mamba': ssm_mod.init_mamba_block(k2, cfg.d_model, cfg.ssm_state,
                                          cfg.ssm_headdim, cfg.dtype),
    }


def _is_axes(x):
    return isinstance(x, tuple) and not isinstance(x, cm.Box) and all(
        isinstance(e, (str, type(None))) for e in x)


def _stack_layers(key, n_layers, init_one):
    """Stack per-layer inits along a leading 'layers' dim."""
    with cm.abstract_init():
        shapes, axes = cm.unbox(init_one(jax.random.PRNGKey(0)))
    axes = jax.tree.map(lambda a: ('layers',) + a, axes, is_leaf=_is_axes)
    if cm.is_abstract_init():
        values = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), shapes)
    else:
        keys = jax.random.split(key, n_layers)
        values = jax.vmap(lambda k: cm.unbox(init_one(k))[0])(keys)
    return jax.tree.map(lambda v, a: cm.Box(v, a), values, axes, is_leaf=None)


def init_params(key, cfg: ModelConfig):
    """Returns a boxed param tree for the whole model."""
    ks = cm.split_keys(key, 8)
    p = {
        'embed': cm.param(ks[0], (cfg.padded_vocab, cfg.d_model),
                          ('vocab', 'embed'), cfg.dtype, init=cm.embed_init),
        'ln_f': cm.param(ks[1], (cfg.d_model,), ('embed',), jnp.float32,
                         init=cm.zeros_init),
        'unembed': cm.param(ks[2], (cfg.d_model, cfg.padded_vocab),
                            ('embed', 'vocab'), cfg.dtype),
    }
    if cfg.family in ('dense', 'moe', 'vlm'):
        if cfg.n_experts and cfg.moe_every > 1:
            # interleaved dense/MoE blocks (llama4-maverick style): scan over
            # super-blocks of (moe_every - 1) dense layers + 1 MoE layer.
            import dataclasses as _dc
            dense_cfg = _dc.replace(cfg, n_experts=0)
            assert cfg.n_layers % cfg.moe_every == 0

            def init_block(k):
                kd, km = cm.split_keys(k, 2)
                return {
                    'dense': _stack_layers(kd, cfg.moe_every - 1,
                                           functools.partial(init_dense_layer,
                                                             cfg=dense_cfg)),
                    'moe': init_dense_layer(km, cfg),
                }
            p['layers'] = _stack_layers(ks[3], cfg.n_layers // cfg.moe_every,
                                        init_block)
        else:
            p['layers'] = _stack_layers(ks[3], cfg.n_layers,
                                        functools.partial(init_dense_layer, cfg=cfg))
    elif cfg.family == 'ssm':
        p['layers'] = _stack_layers(ks[3], cfg.n_layers,
                                    functools.partial(init_ssm_layer, cfg=cfg))
    elif cfg.family == 'hybrid':
        p['layers'] = _stack_layers(ks[3], cfg.n_layers,
                                    functools.partial(init_ssm_layer, cfg=cfg))
        p['shared_attn'] = init_dense_layer(ks[4], cfg)  # one shared block
    elif cfg.family == 'audio':
        p['enc_layers'] = _stack_layers(ks[3], cfg.enc_layers,
                                        functools.partial(init_dense_layer, cfg=cfg))
        p['dec_layers'] = _stack_layers(ks[4], cfg.n_layers,
                                        functools.partial(init_dec_layer, cfg=cfg))
        p['enc_ln_f'] = cm.param(ks[5], (cfg.d_model,), ('embed',), jnp.float32,
                                 init=cm.zeros_init)
        p['enc_pos'] = cm.param(ks[6], (cfg.enc_seq, cfg.d_model),
                                (None, 'embed'), cfg.dtype, init=cm.embed_init)
    else:
        raise ValueError(cfg.family)
    if cfg.family == 'vlm':
        # projector from the (stubbed) vision encoder into the LLM embedding
        p['patch_proj'] = cm.param(ks[7], (cfg.d_model, cfg.d_model),
                                   ('embed', 'embed_out'), cfg.dtype)
    return p


def init_dec_layer(key, cfg: ModelConfig):
    """Encoder-decoder (whisper) decoder layer: self-attn + cross-attn + mlp."""
    ks = cm.split_keys(key, 6)
    return {
        'ln1': cm.param(ks[0], (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
        'attn': init_attn_layer(ks[1], cfg),
        'ln_x': cm.param(ks[2], (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
        'xattn': init_attn_layer(ks[3], cfg),
        'ln2': cm.param(ks[4], (cfg.d_model,), ('embed',), jnp.float32, init=cm.zeros_init),
        'mlp': mlp_mod.init_mlp(ks[5], cfg.d_model, cfg.d_ff, 'gelu', cfg.dtype),
    }


# ---------------------------------------------------------------------------
# Forward primitives
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg: ModelConfig, positions, rope=True):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum('bsd,de->bse', x, p['wq']).reshape(B, S, cfg.n_heads, hd)
    k = jnp.einsum('bsd,de->bse', x, p['wk']).reshape(B, S, cfg.n_kv_heads, hd)
    v = jnp.einsum('bsd,de->bse', x, p['wv']).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rms_norm(q, p['q_norm'])
        k = cm.rms_norm(k, p['k_norm'])
    if rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(p, x, cfg: ModelConfig, *, causal=True, positions=None,
               window=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope=causal)
    if cfg.attn_impl == 'pallas' and causal:
        from repro.kernels.swa_attention import swa_attention
        o = swa_attention(q, k, v, window=window,
                          block_q=cfg.q_block, block_k=cfg.kv_block)
    else:
        o = attn_mod.flash_attention(q, k, v, causal=causal, window=window,
                                     q_positions=positions, k_positions=positions,
                                     q_block=cfg.q_block, kv_block=cfg.kv_block)
    return jnp.einsum('bse,ed->bsd', o.reshape(B, S, -1), p['wo'])


def cross_attn_block(p, x, enc_kv, cfg: ModelConfig):
    """x: [B,S,D]; enc_kv: (k, v) each [B,Senc,KH,hd] (already projected)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = jnp.einsum('bsd,de->bse', x, p['wq']).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = cm.rms_norm(q, p['q_norm'])
    k, v = enc_kv
    o = attn_mod.flash_attention(q, k, v, causal=False,
                                 q_block=cfg.q_block, kv_block=cfg.kv_block)
    return jnp.einsum('bse,ed->bsd', o.reshape(B, S, -1), p['wo'])


def project_enc_kv(p, enc_out, cfg: ModelConfig):
    B, Se, _ = enc_out.shape
    hd = cfg.head_dim
    k = jnp.einsum('bsd,de->bse', enc_out, p['wk']).reshape(B, Se, cfg.n_kv_heads, hd)
    v = jnp.einsum('bsd,de->bse', enc_out, p['wv']).reshape(B, Se, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = cm.rms_norm(k, p['k_norm'])
    return k, v


def dense_layer_fwd(layer, x, cfg: ModelConfig, *, causal=True, positions=None):
    x = shd.constrain_act(x, 'local_batch', 'seq', None)
    h = x + attn_block(layer['attn'], cm.rms_norm(x, layer['ln1']), cfg,
                       causal=causal, positions=positions, window=cfg.window)
    pre = cm.rms_norm(h, layer['ln2'])
    if 'moe' in layer:
        y, aux = moe_mod.apply_moe(layer['moe'], pre,
                                   capacity_factor=cfg.capacity_factor)
    else:
        y, aux = mlp_mod.apply_mlp(layer['mlp'], pre, cfg.mlp_kind), {}
    return h + y, aux


def ssm_layer_fwd(layer, x, cfg: ModelConfig):
    x = shd.constrain_act(x, 'local_batch', 'seq', None)
    return x + ssm_mod.apply_mamba_block(
        layer['mamba'], cm.rms_norm(x, layer['ln1']),
        d_state=cfg.ssm_state, headdim=cfg.ssm_headdim, chunk=cfg.ssm_chunk)


# ---------------------------------------------------------------------------
# Stacks (scan over layers)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def run_dense_stack(stacked, x, cfg: ModelConfig, *, causal=True, positions=None):
    if isinstance(stacked, dict) and 'moe' in stacked and 'dense' in stacked:
        # interleaved super-blocks (moe_every > 1)
        def block_body(h, block):
            def sub(h2, layer):
                out, _ = dense_layer_fwd(layer, h2, cfg, causal=causal,
                                         positions=positions)
                return out, None
            h, _ = jax.lax.scan(sub, h, block['dense'])
            h, aux = dense_layer_fwd(block['moe'], h, cfg, causal=causal,
                                     positions=positions)
            return h, aux.get('load_balance_loss', jnp.zeros((), jnp.float32))
        h, lbs = jax.lax.scan(_maybe_remat(block_body, cfg), x, stacked)
        return h, jnp.sum(lbs)

    def body(h, layer):
        out, aux = dense_layer_fwd(layer, h, cfg, causal=causal, positions=positions)
        lb = aux.get('load_balance_loss', jnp.zeros((), jnp.float32))
        return out, lb
    h, lbs = jax.lax.scan(_maybe_remat(body, cfg), x, stacked)
    return h, jnp.sum(lbs)


def run_ssm_stack(stacked, x, cfg: ModelConfig):
    def body(h, layer):
        return ssm_layer_fwd(layer, h, cfg), None
    h, _ = jax.lax.scan(_maybe_remat(body, cfg), x, stacked)
    return h


def hybrid_groups(cfg: ModelConfig):
    """Split cfg.n_layers ssm layers into groups; a shared attention block
    runs between consecutive groups (zamba2-style)."""
    k = cfg.attn_every
    bounds, start = [], 0
    while start < cfg.n_layers:
        end = min(start + k, cfg.n_layers)
        bounds.append((start, end))
        start = end
    return bounds  # attention after every group except the last


def run_hybrid_stack(params, x, cfg: ModelConfig, *, positions=None):
    groups = hybrid_groups(cfg)
    for gi, (s, e) in enumerate(groups):
        chunk = jax.tree.map(lambda a, lo=s, hi=e: a[lo:hi],
                             params['layers'])
        x = run_ssm_stack(chunk, x, cfg)
        if gi < len(groups) - 1:
            x, _ = dense_layer_fwd(params['shared_attn'], x, cfg,
                                   causal=True, positions=positions)
    return x


# ---------------------------------------------------------------------------
# Model-level forward (training / prefill logits)
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):  # noqa: ARG001
    return jnp.take(params['embed'], tokens, axis=0)


def forward_logits(params, batch, cfg: ModelConfig):
    """batch: dict with 'tokens' [B,S]; vlm adds 'patch_embeds'
    [B,n_patches,D]; audio adds 'frame_embeds' [B,enc_seq,D].
    Returns (logits [B,S,V_padded], aux)."""
    tokens = batch['tokens']
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    aux = {}
    positions = jnp.arange(S, dtype=jnp.int32)

    if cfg.family == 'vlm':
        patches = jnp.einsum('bpd,de->bpe', batch['patch_embeds'].astype(cfg.dtype),
                             params['patch_proj'])
        x = jnp.concatenate([patches, x], axis=1)  # early fusion: prepend
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    if cfg.family in ('dense', 'moe', 'vlm'):
        x, lb = run_dense_stack(params['layers'], x, cfg, positions=positions)
        aux['load_balance_loss'] = lb
    elif cfg.family == 'ssm':
        x = run_ssm_stack(params['layers'], x, cfg)
    elif cfg.family == 'hybrid':
        x = run_hybrid_stack(params, x, cfg, positions=positions)
    elif cfg.family == 'audio':
        frames = batch['frame_embeds'].astype(cfg.dtype) + params['enc_pos'][None]
        enc, _ = run_dense_stack(params['enc_layers'], frames, cfg, causal=False)
        enc = cm.rms_norm(enc, params['enc_ln_f'])

        def body(h, layer):
            h1 = h + attn_block(layer['attn'], cm.rms_norm(h, layer['ln1']),
                                cfg, causal=True, positions=positions)
            kv = project_enc_kv(layer['xattn'], enc, cfg)
            h2 = h1 + cross_attn_block(layer['xattn'],
                                       cm.rms_norm(h1, layer['ln_x']), kv, cfg)
            h3 = h2 + mlp_mod.apply_mlp(layer['mlp'],
                                        cm.rms_norm(h2, layer['ln2']), 'gelu')
            return h3, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params['dec_layers'])
    else:
        raise ValueError(cfg.family)

    if cfg.family == 'vlm':
        x = x[:, -S:]  # logits for the text positions only
    # gradient dtype barrier: keep f32 cotangents confined to the loss head
    x = cm.grad_cast(x, cfg.dtype)
    x = cm.rms_norm(x, params['ln_f'])
    logits = jnp.einsum('bsd,dv->bsv', x, params['unembed'])
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = forward_logits(params, batch, cfg)
    loss = cm.cross_entropy_loss(logits, batch['labels'], cfg.vocab_size,
                                 batch.get('loss_mask'))
    if 'load_balance_loss' in aux:
        loss = loss + 0.01 * aux['load_balance_loss']
    return loss
