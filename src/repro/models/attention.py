"""Attention: GQA + RoPE + optional qk-norm + optional sliding window.

The prefill/train path is a pure-jnp flash-style implementation (scan over
KV blocks with an online softmax) so peak activation memory stays bounded at
[*, q_block, kv_block] instead of [*, seq, seq].  It doubles as the oracle
for the Pallas ``swa_attention`` kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """q_pos: [qb], k_pos: [kb] -> bool [qb, kb] (True = attend)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def flash_attention(q, k, v, *, causal=True, window=None, q_positions=None,
                    k_positions=None, q_block=512, kv_block=512, kv_valid=None):
    """Online-softmax attention.

    q: [B, Sq, H, D];  k, v: [B, Sk, KH, D]  (GQA: H % KH == 0).
    window: sliding-window size (keys with q_pos - k_pos >= window masked).
    kv_valid: optional scalar/int count of valid kv entries (decode caches).
    Returns [B, Sq, H, D].
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0
    G = H // KH
    scale = D ** -0.5

    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if k_positions is None:
        k_positions = jnp.arange(Sk, dtype=jnp.int32)

    # Pad sequence dims to block multiples.
    pq = (-Sq) % q_block
    pk = (-Sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=2**30)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pk), constant_values=-(2**30))
    Sq_p, Sk_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // q_block, Sk_p // kv_block

    # [B, nq, qb, KH, G, D] — keep the input dtype here: the kv-block scan
    # body upcasts AFTER the (GSPMD-inserted) gathers, so in-loop collective
    # traffic stays in bf16 (§Perf iteration 2).
    qr = q.reshape(B, nq, q_block, KH, G, D)
    kr = k.reshape(B, nk, kv_block, KH, D)
    vr = v.reshape(B, nk, kv_block, KH, D)
    qpos = q_positions.reshape(nq, q_block)
    kpos = k_positions.reshape(nk, kv_block)

    kv_limit = None if kv_valid is None else jnp.asarray(kv_valid, jnp.int32)

    def per_qblock(qb, qp):
        # qb: [B, qblock, KH, G, D]; qp: [qblock]; scale applied to the
        # f32 scores (not to the bf16 operand) for precision
        def body(carry, inp):
            m_i, l_i, acc = carry
            kb, vb, kp = inp
            # bf16 operands, f32 accumulation (MXU-native); keeps the
            # GSPMD-inserted K/V gathers in bf16 (§Perf iteration 5)
            s = jnp.einsum('bqhgd,bkhd->bqhgk', qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(qp, kp, causal, window)
            mask &= (kp >= 0)[None, :]  # exclude block-padding keys
            if kv_limit is not None:
                mask &= kp[None, :] < kv_limit
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum('bqhgk,bkhd->bqhgd', p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, q_block, KH, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_block, KH, G), jnp.float32)
        a0 = jnp.zeros((B, q_block, KH, G, D), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4), kpos))
        return acc / jnp.maximum(l_f, 1e-30)[..., None]

    out = jax.lax.map(lambda args: per_qblock(*args),
                      (qr.transpose(1, 0, 2, 3, 4, 5), qpos))  # [nq,B,qb,KH,G,D]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, D)
    return out[:, :Sq].astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=None, q_positions=None,
                  k_positions=None, kv_valid=None):
    """Naive O(S^2)-memory oracle for tests."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if k_positions is None:
        k_positions = jnp.arange(Sk, dtype=jnp.int32)
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Sq, KH, G, D)
    s = jnp.einsum('bqhgd,bkhd->bqhgk', qf, k.astype(jnp.float32))
    mask = _block_mask(q_positions, k_positions, causal, window)
    if kv_valid is not None:
        mask &= k_positions[None, :] < jnp.asarray(kv_valid, jnp.int32)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum('bqhgk,bkhd->bqhgd', p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None,
                     cache_positions=None):
    """Single-step decode attention.

    q: [B, 1, H, D]; caches: [B, S, KH, D]; cache_len: int32 scalar — number
    of valid entries.  ``cache_positions`` supports ring-buffer (SWA) caches
    where slot index != token position; defaults to arange.
    """
    B, _, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    if cache_positions is None:
        cache_positions = jnp.arange(S, dtype=jnp.int32)[None, :] * jnp.ones((B, 1), jnp.int32)
    q_pos = jnp.asarray(cache_len, jnp.int32) - 1  # position of the new token
    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, KH, G, D)
    s = jnp.einsum('bhgd,bkhd->bhgk', qf, k_cache.astype(jnp.float32))
    valid = (cache_positions >= 0) & (cache_positions < cache_len)
    if window is not None:
        valid &= (q_pos - cache_positions) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum('bhgk,bkhd->bhgd', p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)
