"""Pure-JAX parameter/module substrate.

No flax/haiku in this environment, so we use a minimal convention:

* Parameters live in nested dicts of ``Box(value, axes)`` during init,
  where ``axes`` is a tuple of *logical* axis names (one per dim, ``None``
  for unsharded dims).  ``unbox`` splits a boxed tree into (values, axes).
* Model code is written against plain value pytrees; the logical-axes tree
  mirrors it and is consumed by ``repro.sharding`` to build PartitionSpecs.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Box(NamedTuple):
    """A parameter leaf paired with its logical axis names."""

    value: Any
    axes: tuple


def is_box(x) -> bool:
    return isinstance(x, Box)


def unbox(tree):
    """Split a boxed tree into (value_tree, axes_tree)."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)
    return values, axes


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _trunc_normal(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, shape, dtype, in_axis=0):
    """LeCun-normal style init: stddev = 1/sqrt(fan_in)."""
    fan_in = shape[in_axis]
    return _trunc_normal(key, shape, dtype, 1.0 / math.sqrt(max(1, fan_in)))


def embed_init(key, shape, dtype):
    return _trunc_normal(key, shape, dtype, 1.0)


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


_ABSTRACT_INIT = False


class abstract_init:
    """Context manager: ``param`` returns ShapeDtypeStructs (no compute).
    Used to extract static logical-axis metadata without materializing or
    tracing parameter tensors."""

    def __enter__(self):
        global _ABSTRACT_INIT
        self._prev = _ABSTRACT_INIT
        _ABSTRACT_INIT = True

    def __exit__(self, *exc):
        global _ABSTRACT_INIT
        _ABSTRACT_INIT = self._prev


def is_abstract_init() -> bool:
    return _ABSTRACT_INIT


def param(key, shape, axes, dtype=jnp.float32, init=dense_init, **kw) -> Box:
    assert len(shape) == len(axes), (shape, axes)
    shape = tuple(int(s) for s in shape)
    if _ABSTRACT_INIT:
        return Box(jax.ShapeDtypeStruct(shape, dtype), tuple(axes))
    return Box(init(key, shape, dtype, **kw), tuple(axes))


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps=1e-6):
    """RMSNorm with (1+scale) gain.  Internals run in f32; a custom VJP
    returns the input cotangent in x's dtype so downstream tensor-parallel
    all-reduces of activation gradients stay in bf16 (§Perf iteration 3 —
    without this, the f32 upcast inside the norm leaks f32 cotangents into
    the per-layer TP collectives, doubling their bytes)."""
    return _rms_norm_fwd(x, scale, eps)[0]


def _rms_norm_fwd(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    y = (xf * r * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
    return y, (x, scale)


def _rms_norm_bwd(eps, res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    gain = 1.0 + scale.astype(jnp.float32)
    d = x.shape[-1]
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    gg = gf * gain
    dot = jnp.sum(gg * xf, axis=-1, keepdims=True)
    dx = r * gg - (r ** 3) * xf * dot / d
    dscale = jnp.sum(gf * xf * r,
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dx.astype(x.dtype), dscale


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def layer_norm(x, scale, bias, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def grad_cast(x, dtype):  # noqa: ARG001
    """Identity forward; casts the cotangent to ``dtype`` on the way back.

    §Perf iteration 4: the cross-entropy upcast makes the logits cotangent
    f32, and without a barrier that f32-ness propagates down the entire
    residual backward chain — every per-layer tensor-parallel all-reduce of
    activation gradients then moves f32 instead of bf16 (2x collective
    bytes).  Placing this barrier before the unembed projection confines
    f32 gradients to the loss head."""
    return x


def _grad_cast_fwd(x, dtype):  # noqa: ARG001
    return x, None


def _grad_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim/2]


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    angles = angles[..., :, None, :]  # broadcast over heads: [..., s, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def pad_vocab(vocab_size: int, multiple: int = 256) -> int:
    return int(-(-vocab_size // multiple) * multiple)


def cross_entropy_loss(logits, labels, vocab_size: int, mask=None):
    """Mean next-token CE; ``vocab_size`` is the *unpadded* size (padded ids
    are excluded from the softmax).

    Written partition-friendly for a vocab-sharded logits tensor (§Perf
    iteration 1): the padded-id mask is an elementwise ``where`` against an
    iota (not a scatter), and the gold logit is extracted with a one-hot
    contraction over the vocab dim (not take_along_axis) — both keep the
    vocab dim sharded, so GSPMD emits small all-reduces of [B,S] instead of
    all-gathering fp32 [B,S,V] logits.
    """
    padded = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if padded != vocab_size:
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, (padded,), 0)
        logits = jnp.where(vocab_ids[None, None, :] < vocab_size, logits,
                           -1e9)
    # stable logsumexp with sharded-vocab reductions
    m = jnp.max(logits, axis=-1)                                  # [B,S]
    logz = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    onehot = jax.nn.one_hot(labels, padded, dtype=logits.dtype)   # [B,S,V]
    gold = jnp.sum(logits * onehot, axis=-1)                      # [B,S]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
