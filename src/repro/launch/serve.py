"""Batched serving driver: prefill + decode with KV/SSM caches.

Serves the (aggregated) global model — e.g. a checkpoint produced by
``repro.launch.train``.  On the production mesh the same ``serve_step``
lowers for the decode_32k / long_500k dry-run shapes.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.launch import mesh as mesh_lib
from repro.models.model import build_model


def run(arch: str, *, batch: int, prompt_len: int, gen: int,
        full_size: bool = False, ckpt: str = None, seed: int = 0):
    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    if ckpt:
        params, meta = checkpoint.restore(ckpt, model.param_shapes())
        print('restored checkpoint', meta)
    else:
        params = model.init(key)

    mesh = mesh_lib.make_local_mesh()
    max_len = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    with mesh:
        cache = model.init_cache(batch, max_len)
        t0 = time.time()
        # prefill token-by-token (reduced-size models; bulk prefill uses
        # forward_logits on real hardware)
        cache, logits = model.prefill(params, cache, prompts)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out = [tok]
        t0 = time.time()
        for _ in range(gen - 1):
            cache, logits = decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    toks = jnp.concatenate(out, axis=1)
    print(f'prefill: {batch}x{prompt_len} tokens in {t_prefill:.2f}s')
    print(f'decode:  {batch}x{gen} tokens in {t_decode:.2f}s '
          f'({batch * gen / max(t_decode, 1e-9):.1f} tok/s)')
    print('sample continuation ids:', np.asarray(toks[0, :12]))
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', choices=ARCH_IDS, default='mamba2-130m')
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--prompt-len', type=int, default=32)
    ap.add_argument('--gen', type=int, default=16)
    ap.add_argument('--ckpt', default=None)
    ap.add_argument('--full-size', action='store_true')
    args = ap.parse_args(argv)
    run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, ckpt=args.ckpt, full_size=args.full_size)


if __name__ == '__main__':
    main()
