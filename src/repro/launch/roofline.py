"""Roofline-term extraction from compiled dry-run artifacts.

The container has no TPU, so we derive the three roofline terms from the
compiled HLO (per the assignment):

    compute    = HLO_FLOPs       / (chips * peak_FLOPs)
    memory     = HLO_bytes       / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD optimized HLO text (sum of output-shape bytes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all ops).  Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    'pred': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'bf16': 2, 'f16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
}

_COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
                'collective-permute', 'ragged-all-to-all')

# e.g.  %all-reduce.5 = f32[16,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r'=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s(' +
    '|'.join(_COLLECTIVES) + r')\(')
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_TUPLE_RE = re.compile(
    r'=\s*\(([^)]*)\)\s*(' + '|'.join(_COLLECTIVES) + r')\(')
_SHAPE_RE = re.compile(r'([a-z0-9]+)\[([0-9,]*)\]')


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(','):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind over the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
    return {'bytes': out, 'counts': counts,
            'total_bytes': sum(out.values())}


@dataclasses.dataclass
class Roofline:
    flops: float                # total HLO FLOPs (whole program, all chips)
    hbm_bytes: float            # total bytes accessed
    coll_bytes: float           # total collective bytes (per-chip shapes)
    chips: int
    model_flops: float = 0.0    # 6*N*D useful-FLOPs estimate

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # HLO shapes are already per-chip after SPMD partitioning
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {'compute': self.t_compute, 'memory': self.t_memory,
                 'collective': self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if self.model_flops and self.flops:
            return self.model_flops / self.flops
        return None

    def as_dict(self) -> dict:
        return {
            'flops': self.flops, 'hbm_bytes': self.hbm_bytes,
            'coll_bytes': self.coll_bytes, 'chips': self.chips,
            't_compute_s': self.t_compute, 't_memory_s': self.t_memory,
            't_collective_s': self.t_collective,
            'bottleneck': self.bottleneck,
            'model_flops': self.model_flops,
            'useful_ratio': self.useful_ratio,
        }


def model_flops_estimate(n_active_params: int, tokens: int,
                         kind: str) -> float:
    """MODEL_FLOPS = 6 * N * D for training, 2 * N * D for inference
    (N = active params for MoE)."""
    mult = 6.0 if kind == 'train' else 2.0
    return mult * n_active_params * tokens


def from_compiled(compiled, lowered_text: str, chips: int,
                  model_flops: float = 0.0) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get('flops', 0.0))
    byt = float(cost.get('bytes accessed', 0.0))
    coll = collective_bytes(lowered_text)
    return Roofline(flops=flops, hbm_bytes=byt,
                    coll_bytes=float(coll['total_bytes']), chips=chips,
                    model_flops=model_flops)
