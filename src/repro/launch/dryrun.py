"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers, SPMD-partitions and compiles on the production meshes,
and extract roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, INPUT_SHAPES, get_config,
                           shape_supported)
from repro.launch import analytic, hlo_parse
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rf
from repro.launch.steps import ServeSetup, SiloSetup
from repro.models.model import build_model


def active_params(cfg, model) -> int:
    """Per-token active parameters (MoE: shared + top-1 expert)."""
    n = model.n_params()
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.d_ff
        n_moe_layers = cfg.n_layers // cfg.moe_every
        n -= (cfg.n_experts - 1) * expert * n_moe_layers
    return n


def lower_one(arch_id: str, shape_name: str, *, multi_pod: bool,
              fedavg_baseline: bool = False, extra_cfg=None,
              profile: str = 'tp'):
    """Returns a result dict with memory/cost/roofline info."""
    cfg = get_config(arch_id)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    model = build_model(cfg)
    t0 = time.time()

    from repro import sharding as shd
    from repro.launch.steps import SERVE_PROFILES
    if shape.kind != 'train' and profile in SERVE_PROFILES:
        serve_rules = SERVE_PROFILES[profile]
    else:
        serve_rules = None
    rules = shd.PROFILES.get(profile, shd.DEFAULT_RULES)
    if profile == 'fsdp' and multi_pod:
        rules = shd.FSDP_MULTIPOD_RULES
    if shape.kind == 'train':
        n_cl_axes = rules.get('clients', ('pod', 'data'))
        setup = SiloSetup(model,
                          n_clients=mesh_lib.n_clients(mesh, n_cl_axes),
                          rules=rules)
        state_sds = setup.state_sds()
        batch_sds = setup.client_batch(shape)
        state_sh, batch_sh = setup.shardings(mesh, shape)
        step = setup.fedavg_train_step if fedavg_baseline else setup.train_step
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state_sds, batch_sds)
        tokens = shape.global_batch * shape.seq_len
        mf = rf.model_flops_estimate(active_params(cfg, model), tokens,
                                     'train')
    elif shape.kind == 'prefill':
        setup = ServeSetup(model, serve_rules=serve_rules)
        p_sh = setup.param_shardings(mesh)
        b_sh = setup.prefill_shardings(mesh, shape)
        with mesh:
            lowered = jax.jit(setup.prefill_step,
                              in_shardings=(p_sh, b_sh)).lower(
                model.param_shapes(), setup.prefill_batch(shape))
        tokens = shape.global_batch * shape.seq_len
        mf = rf.model_flops_estimate(active_params(cfg, model), tokens,
                                     'prefill')
    else:  # decode
        setup = ServeSetup(model, serve_rules=serve_rules)
        p_sh = setup.param_shardings(mesh)
        cache_sds, tok_sds = setup.decode_batch(shape)
        cache_sh, tok_sh = setup.decode_shardings(mesh, shape)
        with mesh:
            lowered = jax.jit(setup.serve_step,
                              in_shardings=(p_sh, cache_sh, tok_sh),
                              donate_argnums=(1,)).lower(
                model.param_shapes(), cache_sds, tok_sds)
        tokens = shape.global_batch  # one token per sequence
        mf = rf.model_flops_estimate(active_params(cfg, model), tokens,
                                     'decode')

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_parse.analyze_collectives(hlo)

    # analytic compute/memory terms (XLA CPU cost analysis counts loop
    # bodies once — see EXPERIMENTS.md §Dry-run); collective term from
    # trip-count-corrected HLO parsing.
    n_cl = mesh_lib.n_clients(mesh) if shape.kind == 'train' else 1
    flops = analytic.flops_estimate(
        cfg, kind=shape.kind, batch=shape.global_batch, seq=shape.seq_len,
        n_active=active_params(cfg, model))
    byts = analytic.bytes_estimate(
        cfg, kind=shape.kind, batch=shape.global_batch, seq=shape.seq_len,
        n_params=model.n_params(), n_clients=n_cl)
    roof = rf.Roofline(flops=flops, hbm_bytes=byts,
                       coll_bytes=float(coll['adjusted_total_bytes']),
                       chips=chips, model_flops=mf)

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    result = {
        'arch': arch_id, 'shape': shape_name,
        'mesh': mesh_lib.describe(mesh), 'chips': chips,
        'kind': shape.kind, 'profile': profile,
        'step': 'fedavg' if fedavg_baseline else
                ('safa' if shape.kind == 'train' else 'serve'),
        'lower_s': round(t_lower, 1), 'compile_s': round(t_compile, 1),
        'arg_bytes': getattr(mem, 'argument_size_in_bytes', 0),
        'temp_bytes': getattr(mem, 'temp_size_in_bytes', 0),
        'peak_bytes': getattr(mem, 'peak_memory_in_bytes', 0),
        **roof.as_dict(),
        'collectives': coll['counts'],
        'collective_bytes_by_kind': coll['bytes'],
        'coll_bytes_raw': float(coll['total_bytes']),
        'xla_flops_body_once': float(cost.get('flops', 0.0)),
        'xla_bytes_body_once': float(cost.get('bytes accessed', 0.0)),
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', choices=ARCH_IDS)
    ap.add_argument('--shape', choices=list(INPUT_SHAPES))
    ap.add_argument('--all', action='store_true')
    ap.add_argument('--multi-pod', action='store_true')
    ap.add_argument('--fedavg-baseline', action='store_true')
    ap.add_argument('--profile', choices=('tp', 'fsdp', 'splitkv'),
                    default='tp')
    ap.add_argument('--out', default=None)
    ap.add_argument('--skip-existing', action='store_true')
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                if shape_supported(a, s):
                    combos.append((a, s))
    else:
        assert args.arch and args.shape, '--arch/--shape or --all'
        combos = [(args.arch, args.shape)]

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r['arch'], r['shape'], r['mesh'], r['step'], r.get('profile', 'tp')))
                except Exception:
                    pass

    mesh_desc = mesh_lib.describe(mesh_lib.make_production_mesh(
        multi_pod=args.multi_pod))
    failures = []
    for arch, shape in combos:
        kind = INPUT_SHAPES[shape].kind
        step_name = ('fedavg' if args.fedavg_baseline else
                     ('safa' if kind == 'train' else 'serve'))
        if (arch, shape, mesh_desc, step_name, args.profile) in done:
            continue
        try:
            res = lower_one(arch, shape, multi_pod=args.multi_pod,
                            fedavg_baseline=args.fedavg_baseline,
                            profile=args.profile)
            line = json.dumps(res)
            print(line, flush=True)
            if args.out:
                with open(args.out, 'a') as f:
                    f.write(line + '\n')
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f'FAIL {arch} {shape}: {e!r}', file=sys.stderr, flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
