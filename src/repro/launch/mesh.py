"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import; tests and benches see the real (1-device) platform.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names, for CPU tests."""
    n = len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))


def n_clients(mesh: Mesh, client_axes=("pod", "data")) -> int:
    """Silo-mode federated client count = product of client axes present."""
    c = 1
    for ax in client_axes:
        if ax in mesh.axis_names:
            c *= mesh.shape[ax]
    return c


def describe(mesh: Mesh) -> str:
    return 'x'.join(f'{k}={v}' for k, v in mesh.shape.items())
