"""End-to-end federated training driver (silo-mode SAFA).

Runs a real (reduced-size, CPU-feasible) federated LLM training: the SAFA
protocol drives per-round client states from the event simulator, while the
numeric round executes as one jit-ed ``SiloSetup.train_step`` on the local
mesh.  On real hardware the identical code runs on the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --rounds 50 --clients 4 --fraction 0.5 --lag-tolerance 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.core import protocol, selection
from repro.data import make_lm_tokens
from repro.fedsim import EnvSpec
from repro.launch import mesh as mesh_lib
from repro.launch.steps import SiloSetup
from repro.models.model import build_model


def run(arch: str, *, rounds: int, n_clients: int, fraction: float,
        lag_tolerance: int, crash_prob: float, batch: int, seq: int,
        local_steps: int, lr: float, seed: int = 0, ckpt: str = None,
        full_size: bool = False, log_every: int = 10):
    cfg = get_config(arch)
    if not full_size:
        cfg = cfg.reduced()
    model = build_model(cfg)
    setup = SiloSetup(model, n_clients=n_clients, local_steps=local_steps,
                      learning_rate=lr)
    mesh = mesh_lib.make_local_mesh()

    key = jax.random.PRNGKey(seed)
    global_w = model.init(key)
    state = {
        'global': global_w,
        'local': protocol.broadcast_global(global_w, n_clients),
        'cache': protocol.broadcast_global(global_w, n_clients),
    }

    # synthetic federated token streams, one shard per client
    toks = make_lm_tokens(n_docs=n_clients * batch * 4, seq_len=seq,
                          vocab=cfg.vocab_size, seed=seed)
    env = EnvSpec(m=n_clients, crash_prob=crash_prob,
                  dataset_size=toks.shape[0], batch_size=batch, epochs=1,
                  t_lim=3600.0, seed=seed).build()
    weights = jnp.asarray(env.weights, jnp.float32)

    step = jax.jit(setup.train_step, donate_argnums=(0,))
    versions = np.zeros(n_clients, int)
    committed_prev = np.ones(n_clients, bool)
    picked_prev = np.zeros(n_clients, bool)
    rng = np.random.default_rng(seed)
    history = []

    with mesh:
        for t in range(1, rounds + 1):
            up, dep, _ = protocol.classify_versions(
                jnp.asarray(versions), t - 1, lag_tolerance,
                jnp.asarray(committed_prev))
            up, dep = np.asarray(up), np.asarray(dep)
            sync = up | dep
            crashed, _ = env.draw_round()
            arrival = env.t_dist(int(sync.sum())) + 2 * env.t_updown + \
                env.full_train_time()
            arrival = np.where(~crashed, arrival, np.inf)
            sel = selection.cfcfm(arrival, ~crashed, picked_prev, fraction,
                                  env.t_lim)
            versions[sync] = t - 1
            versions[sel.committed] = t

            doc_idx = rng.integers(0, toks.shape[0],
                                   size=(n_clients, batch))
            tb = toks[doc_idx]
            round_batch = {
                'tokens': jnp.asarray(tb[..., :seq]),
                'labels': jnp.asarray(tb[..., 1:seq + 1]),
                'meta': {
                    'sync': jnp.asarray(sync),
                    'picked': jnp.asarray(sel.picked),
                    'undrafted': jnp.asarray(sel.undrafted),
                    'deprecated': jnp.asarray(dep),
                    'completed': jnp.asarray(sel.committed),
                    'weights': weights,
                },
            }
            if cfg.family == 'vlm':
                round_batch['patch_embeds'] = jnp.zeros(
                    (n_clients, batch, cfg.n_patches, cfg.d_model), jnp.float32)
            if cfg.family == 'audio':
                round_batch['frame_embeds'] = jnp.zeros(
                    (n_clients, batch, cfg.enc_seq, cfg.d_model), jnp.float32)
            state, metrics = step(state, round_batch)
            committed_prev = sel.committed.copy()
            picked_prev = sel.picked.copy()
            history.append(float(metrics['loss']))
            if t % log_every == 0 or t == rounds:
                print(f'round {t:4d} loss {history[-1]:.4f} '
                      f'picked {int(sel.picked.sum())}/{n_clients} '
                      f'crashed {int(crashed.sum())}', flush=True)

    if ckpt:
        checkpoint.save(ckpt, state['global'],
                        {'arch': arch, 'rounds': rounds})
        print('checkpoint saved to', ckpt)
    return history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', choices=ARCH_IDS, default='qwen3-1.7b')
    ap.add_argument('--rounds', type=int, default=30)
    ap.add_argument('--clients', type=int, default=4)
    ap.add_argument('--fraction', type=float, default=0.5)
    ap.add_argument('--lag-tolerance', type=int, default=5)
    ap.add_argument('--crash-prob', type=float, default=0.2)
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--seq', type=int, default=64)
    ap.add_argument('--local-steps', type=int, default=2)
    ap.add_argument('--lr', type=float, default=0.05)
    ap.add_argument('--ckpt', default=None)
    ap.add_argument('--full-size', action='store_true')
    args = ap.parse_args(argv)
    t0 = time.time()
    hist = run(args.arch, rounds=args.rounds, n_clients=args.clients,
               fraction=args.fraction, lag_tolerance=args.lag_tolerance,
               crash_prob=args.crash_prob, batch=args.batch, seq=args.seq,
               local_steps=args.local_steps, lr=args.lr, ckpt=args.ckpt,
               full_size=args.full_size)
    print(f'done: loss {hist[0]:.3f} -> {hist[-1]:.3f} in {time.time()-t0:.0f}s')


if __name__ == '__main__':
    main()
