"""Structural HLO parsing: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` (and any flat text scan) counts a while-loop
body ONCE, but our stacks are lax.scan-over-layers, so collective traffic
inside the loop must be multiplied by the trip count.  We split the HLO
module into computations, find ``while`` ops with their condition/body
computations, read the trip count from the loop-bound constant in the
condition, and accumulate collective output bytes with the correct
multipliers (nested scans compose).
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {
    'pred': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'bf16': 2, 'f16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
}

COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
               'collective-permute', 'ragged-all-to-all')

# computation headers may contain nested parens in tuple-typed params
_COMP_START = re.compile(r'^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$')
_SHAPE_RE = re.compile(r'([a-z0-9]+)\[([0-9,]*)\]')
_WHILE_RE = re.compile(r'\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)')
_CONST_RE = re.compile(r'constant\((\d+)\)')
_COLL_RE = re.compile(
    r'=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*(' +
    '|'.join(COLLECTIVES) + r')\(')


def _shape_bytes_from(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_START.match(line.strip()) if not line.startswith(' ') else None
        if m and (line.startswith('%') or line.startswith('ENTRY')):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith('}'):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    comps['__entry__'] = [entry]  # type: ignore
    return comps


def analyze_collectives(hlo: str) -> dict:
    """Returns {'bytes': {kind: B}, 'counts': {kind: n}, 'total_bytes': B}
    with while-loop trip multipliers applied (dynamic executions counted)."""
    comps = split_computations(hlo)
    entry = comps.pop('__entry__')[0]

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for c in _CONST_RE.findall(
            '\n'.join(comps.get(cond_name, [])))]
        big = [c for c in consts if c > 0]
        return max(big) if big else 1

    byt = {k: 0.0 for k in COLLECTIVES}
    cnt = {k: 0.0 for k in COLLECTIVES}
    adj = {k: 0.0 for k in COLLECTIVES}
    visited_stack = []

    def walk(comp_name: str, mult: float):
        if comp_name in visited_stack:   # defensive: no recursion
            return
        visited_stack.append(comp_name)
        for line in comps.get(comp_name, []):
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.groups()
                walk(body, mult * trip_count(cond))
                continue
            mc = _COLL_RE.search(line)
            if mc:
                shape_txt, kind = mc.groups()
                b = _shape_bytes_from(shape_txt)
                byt[kind] += mult * b
                cnt[kind] += mult
                # CPU XLA lowers bf16 dot partial-sums as f32 collectives
                # (convert -> f32 AR -> convert); the TPU target keeps them
                # in bf16.  The adjusted figure halves f32 collective bytes
                # to reflect the TPU lowering (EXPERIMENTS.md §Roofline).
                f32b = _shape_bytes_from(' '.join(
                    re.findall(r'f32\[[0-9,]*\]', shape_txt)))
                adj[kind] += mult * (b - f32b / 2)
                continue
            # conditionals: visit both branches at same multiplier
            mcond = re.search(r'conditional\(.*branch_computations=\{([^}]*)\}',
                              line)
            if mcond:
                for b in mcond.group(1).split(','):
                    walk(b.strip().lstrip('%'), mult)
        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    return {'bytes': byt, 'counts': cnt, 'total_bytes': sum(byt.values()),
            'adjusted_bytes': adj,
            'adjusted_total_bytes': sum(adj.values())}


def top_collectives(hlo: str, k: int = 20):
    """List individual collective ops sorted by (trip-mult x bytes):
    [(kind, bytes, mult, computation, line_snippet)] — the §Perf profile."""
    comps = split_computations(hlo)
    entry = comps.pop('__entry__')[0]

    def trip_count(cond_name: str) -> int:
        consts = [int(c) for c in _CONST_RE.findall(
            '\n'.join(comps.get(cond_name, [])))]
        big = [c for c in consts if c > 0]
        return max(big) if big else 1

    found = []
    stack = []

    def walk(comp_name: str, mult: float):
        if comp_name in stack:
            return
        stack.append(comp_name)
        for line in comps.get(comp_name, []):
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.groups()
                walk(body, mult * trip_count(cond))
                continue
            mc = _COLL_RE.search(line)
            if mc:
                shape_txt, kind = mc.groups()
                b = _shape_bytes_from(shape_txt)
                found.append((kind, b, mult, comp_name, line[:140]))
        stack.pop()

    if entry:
        walk(entry, 1.0)
    found.sort(key=lambda t: -t[1] * t[2])
    return found[:k]
