"""Analytic (napkin-math) FLOPs / HBM-bytes estimators per arch x shape.

XLA's CPU cost analysis counts while-loop bodies once (verified empirically;
see EXPERIMENTS.md §Dry-run), so the compute/memory roofline terms are
derived analytically from the architecture config — the standard 6ND-style
accounting — while the collective term uses trip-count-corrected HLO parsing
(``hlo_parse``).  Every formula is documented here and in EXPERIMENTS.md.

Conventions:
  N   total params;  Na  active params (MoE top-1: shared + 1 expert)
  T   tokens processed;  S  seq;  B  batch;  W  attention window
  train flops  = 8 Na T   (fwd 2NaT + bwd 4NaT + remat re-fwd 2NaT)
  prefill flops= 2 Na T
  decode flops = 2 Na B   (one token per sequence)
  attention adds 2*2*B*H*hd*S*S_eff per layer (QK^T + PV), x4 for training
  (bwd+remat), with S_eff = min(S, W)/2-ish causal average.
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def _attn_tokens_eff(S: int, window) -> float:
    """Average causal KV footprint per query."""
    if window is not None and window < S:
        return window  # steady-state: each query sees ~W keys
    return S / 2.0


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family in ('dense', 'moe', 'vlm'):
        return cfg.n_layers
    if cfg.family == 'hybrid':
        # shared attention block between groups of attn_every ssm layers
        return max(0, -(-cfg.n_layers // cfg.attn_every) - 1)
    if cfg.family == 'audio':
        return cfg.n_layers + cfg.enc_layers  # + cross-attn handled below
    return 0


def flops_estimate(cfg: ModelConfig, *, kind: str, batch: int, seq: int,
                   n_active: int, local_steps: int = 1) -> float:
    """Total FLOPs for one step across the whole mesh."""
    T = batch * seq if kind != 'decode' else batch
    H, hd = cfg.n_heads, cfg.head_dim
    L_attn = _attn_layers(cfg)

    if kind == 'train':
        # fwd 2NaT + bwd 4NaT (+ remat re-forward 2NaT)
        factor = 8.0 if cfg.remat else 6.0
        mat = factor * n_active * T * local_steps
        s_eff = _attn_tokens_eff(seq, cfg.window)
        attn = (factor / 2) * (2 * 2 * batch * H * hd * seq * s_eff) \
            * L_attn * local_steps
    elif kind == 'prefill':
        mat = 2.0 * n_active * T
        s_eff = _attn_tokens_eff(seq, cfg.window)
        attn = 2 * 2 * batch * H * hd * seq * s_eff * L_attn
    else:  # decode: one token attends to the full (or windowed) cache
        mat = 2.0 * n_active * batch
        kv_seen = min(seq, cfg.window) if cfg.window else seq
        attn = 2 * 2 * batch * H * hd * kv_seen * L_attn
    if cfg.family == 'audio' and kind != 'train':
        # cross-attention reads enc_seq keys per decoder layer
        attn += 2 * 2 * batch * H * hd * cfg.enc_seq * cfg.n_layers
    return mat + attn


def bytes_estimate(cfg: ModelConfig, *, kind: str, batch: int, seq: int,
                   n_params: int, n_clients: int = 1, dtype_bytes: int = 2,
                   local_steps: int = 1) -> float:
    """Total HBM bytes moved for one step across the whole mesh.

    train (silo SAFA round): per client — read global + local + cache,
    write local + cache (+ grads transient), plus activations ~2 passes
    (remat) of L*B*S*D; aggregation reads cache once more + writes global.
    """
    D, L = cfg.d_model, cfg.n_layers
    P = n_params * dtype_bytes
    if kind == 'train':
        act = 2 * L * batch * seq * D * dtype_bytes * 2  # fwd+refwd residual streams
        params_traffic = n_clients * (3 + 2) * P + 2 * P  # clients*(r3+w2) + agg r/w
        grads = n_clients * 2 * P * local_steps
        return params_traffic + grads + act * local_steps
    if kind == 'prefill':
        act = 2 * L * batch * seq * D * dtype_bytes
        kv_write = 2 * L * batch * seq * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
        return P + act + kv_write
    # decode: read all params + read cache + write cache slot
    cache_bytes = 0
    if cfg.n_heads:
        S_c = min(seq, cfg.window) if cfg.window else seq
        cache_bytes = 2 * _attn_layers(cfg) * batch * S_c * \
            max(1, cfg.n_kv_heads) * cfg.head_dim * dtype_bytes
    if cfg.family in ('ssm', 'hybrid'):
        d_inner = 2 * D
        cache_bytes += L * batch * (d_inner // cfg.ssm_headdim) * \
            cfg.ssm_headdim * cfg.ssm_state * 4 * 2  # f32 state r+w
    return P + cache_bytes + batch * D * L * dtype_bytes
