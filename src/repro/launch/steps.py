"""Jit-able step functions + sharding/spec builders for the production mesh.

Silo-mode SAFA (DESIGN.md §3.2): federated clients = (pod, data) mesh
slices.  Every state pytree carries a leading ``clients`` dim; the paper's
server cache/bypass live distributed across the clients; Eq. 7 is a single
weighted all-reduce over the client axis.

``serve_step`` / ``prefill_step`` lower the *global* (aggregated) model for
the inference shapes.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro import sharding as shd
from repro.core import protocol
from repro.models.config import ModelConfig
from repro.models.model import Model


# ---------------------------------------------------------------------------
# Logical-axis trees for caches and batches
# ---------------------------------------------------------------------------

def cache_axes(cfg: ModelConfig):
    ax = {'length': ()}
    kv = ('layers', 'batch', 'kv_seq', 'kv_heads', 'head_dim')
    if cfg.family in ('dense', 'moe', 'vlm', 'audio'):
        ax['k'] = kv
        ax['v'] = kv
        ax['positions'] = ('kv_seq',)
    if cfg.family == 'audio':
        ax['xk'] = kv
        ax['xv'] = kv
    if cfg.family in ('ssm', 'hybrid'):
        ax['conv'] = ('layers', 'batch', None, 'ssm_inner')
        ax['ssm'] = ('layers', 'batch', 'ssm_heads', 'ssm_headdim', 'ssm_state')
    if cfg.family == 'hybrid':
        ax['k'] = kv
        ax['v'] = kv
        ax['positions'] = ('kv_seq',)
    return ax


SERVE_RULES = dict(shd.DEFAULT_RULES,
                   kv_seq=(), kv_heads=('model',), head_dim=('model',),
                   ssm_heads=('model',), ssm_headdim=('model',), ssm_state=())

# §Perf serve profile — "split-KV" decode: shard the cache SEQUENCE dim over
# the model axis instead of kv_heads/head_dim.  The per-token attention then
# partial-sums tiny [B,H] softmax stats across shards instead of
# all-gathering the KV cache per layer (nemotron decode_32k baseline moves
# 154 GiB/step of cache all-gathers; split-KV moves 0.07 GiB — measured,
# EXPERIMENTS.md §Perf serve iteration).
SERVE_SPLITKV_RULES = dict(SERVE_RULES, kv_seq=('model',), kv_heads=(),
                           head_dim=())

SERVE_PROFILES = {'gqa': SERVE_RULES, 'splitkv': SERVE_SPLITKV_RULES}


def batch_axes_train(cfg: ModelConfig):
    ax = {'tokens': ('clients', 'local_batch', 'seq'),
          'labels': ('clients', 'local_batch', 'seq')}
    if cfg.family == 'vlm':
        ax['patch_embeds'] = ('clients', None, None, None)
    if cfg.family == 'audio':
        ax['frame_embeds'] = ('clients', None, None, None)
    ax['meta'] = {k: ('clients',) for k in
                  ('sync', 'picked', 'undrafted', 'deprecated', 'completed',
                   'weights')}
    return ax


def _shardings_for(axes_tree, sds_tree, mesh: Mesh, rules=None):
    rules = rules or shd.DEFAULT_RULES
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, shd.spec_for(a, s.shape, mesh, rules)),
        axes_tree, sds_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# Silo-mode federated train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SiloSetup:
    model: Model
    n_clients: int
    local_steps: int = 1
    learning_rate: float = 1e-2
    rules: dict = None   # sharding profile (repro.sharding.PROFILES); None=tp

    def client_batch(self, shape):
        """ShapeDtypeStructs for one round's input batch."""
        cfg = self.model.cfg
        C = self.n_clients
        b = max(1, shape.global_batch // C)
        S = shape.seq_len
        sds = {
            'tokens': jax.ShapeDtypeStruct((C, b, S), jnp.int32),
            'labels': jax.ShapeDtypeStruct((C, b, S), jnp.int32),
            'meta': {
                **{k: jax.ShapeDtypeStruct((C,), jnp.bool_) for k in
                   ('sync', 'picked', 'undrafted', 'deprecated', 'completed')},
                'weights': jax.ShapeDtypeStruct((C,), jnp.float32),
            },
        }
        if cfg.family == 'vlm':
            sds['patch_embeds'] = jax.ShapeDtypeStruct(
                (C, b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == 'audio':
            sds['frame_embeds'] = jax.ShapeDtypeStruct(
                (C, b, cfg.enc_seq, cfg.d_model), jnp.float32)
        return sds

    def state_sds(self):
        C = self.n_clients
        shapes = self.model.param_shapes()
        stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((C,) + s.shape, s.dtype), shapes)
        return {'global': shapes, 'local': stack, 'cache': stack}

    def state_axes(self):
        axes = self.model.param_axes()
        stacked = jax.tree.map(lambda a: ('clients',) + a, axes,
                               is_leaf=_is_axes)
        return {'global': axes, 'local': stacked, 'cache': stacked}

    def shardings(self, mesh: Mesh, shape):
        self._mesh = mesh
        state_sh = _shardings_for(self.state_axes(), self.state_sds(), mesh,
                                  self.rules)
        batch_sh = _shardings_for(batch_axes_train(self.model.cfg),
                                  self.client_batch(shape), mesh,
                                  self.rules)
        return state_sh, batch_sh

    def _maybe_gather_weights(self, stacked):
        """FSDP profile: explicitly all-gather each client's weights before
        local compute (weights-stay-sharded-at-rest, gathered-for-use).
        Without this GSPMD resolves row-sharded weights by all-reducing
        activations instead — measured 2.5x WORSE than TP (§Perf).

        MoE expert tables are NOT gathered: they keep expert-parallel
        sharding (gathering 400B-class expert weights would move TiBs per
        step — measured; §Perf maverick iteration)."""
        if self.rules is not shd.FSDP_RULES or getattr(self, '_mesh', None) is None:
            return stacked
        mesh = self._mesh
        axes = self.state_axes()['local']

        def gather(x, ax):
            keep = tuple(a if a == 'experts' else None for a in ax[1:])
            spec = shd.spec_for(('clients',) + keep, x.shape, mesh,
                                self.rules)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))

        flat_x, treedef = jax.tree_util.tree_flatten(stacked)
        flat_a = treedef.flatten_up_to(axes)
        return jax.tree_util.tree_unflatten(
            treedef, [gather(x, a) for x, a in zip(flat_x, flat_a)])

    # -- the step itself -----------------------------------------------------
    def train_step(self, state, batch):
        """One SAFA round in silo mode: Eq.3 -> local SGD -> Eq.6/7/8."""
        model = self.model
        meta = batch['meta']
        client_batch = {k: v for k, v in batch.items() if k != 'meta'}

        base = protocol.distribute(state['global'], state['local'], meta['sync'])
        base = self._maybe_gather_weights(base)

        def train_one(params, cb):
            def sgd_step(p, _):
                loss, g = jax.value_and_grad(model.loss)(p, cb)
                p = jax.tree.map(lambda w, gw: (w - self.learning_rate
                                                * gw.astype(jnp.float32)).astype(w.dtype),
                                 p, g)
                return p, loss
            p, losses = jax.lax.scan(sgd_step, params, None,
                                     length=self.local_steps)
            return p, jnp.mean(losses)

        mesh = getattr(self, '_mesh', None)
        if self.rules is shd.FSDP_RULES and mesh is not None:
            # pin the interior layout (GSPMD propagation otherwise reverts
            # scan/vmap interiors to its own TP solution — see §Perf)
            ctx = shd.activation_sharding(mesh, self.rules)
            client_axes = tuple(a for a in ('pod', 'data')
                                if a in mesh.axis_names)
            vmapped = jax.vmap(train_one, spmd_axis_name=client_axes)
        else:
            ctx = contextlib.nullcontext()
            vmapped = jax.vmap(train_one)
        with ctx:
            trained, losses = vmapped(base, client_batch)
        trained = protocol.masked_select(meta['completed'], trained, base)

        agg = protocol.discriminative_aggregation(
            state['cache'], trained, state['global'],
            picked=meta['picked'], undrafted=meta['undrafted'],
            deprecated=meta['deprecated'], weights=meta['weights'])
        new_local = protocol.masked_select(meta['completed'], trained, base)
        new_state = {'global': agg.new_global, 'local': new_local,
                     'cache': agg.new_cache}
        metrics = {'loss': jnp.mean(losses),
                   'picked_frac': jnp.mean(meta['picked'].astype(jnp.float32))}
        return new_state, metrics

    def fedavg_train_step(self, state, batch):
        """Baseline: synchronous FedAvg round on the same mesh (no cache)."""
        model = self.model
        meta = batch['meta']
        client_batch = {k: v for k, v in batch.items() if k != 'meta'}

        def train_one(params, cb):
            def sgd_step(p, _):
                loss, g = jax.value_and_grad(model.loss)(p, cb)
                p = jax.tree.map(lambda w, gw: (w - self.learning_rate
                                                * gw.astype(jnp.float32)).astype(w.dtype),
                                 p, g)
                return p, loss
            p, losses = jax.lax.scan(sgd_step, params, None,
                                     length=self.local_steps)
            return p, jnp.mean(losses)

        new_global, new_local = protocol.fedavg_round(
            state['global'], state['local'], selected=meta['picked'],
            completed=meta['completed'], weights=meta['weights'],
            local_train_fn=lambda b: jax.vmap(train_one)(b, client_batch)[0])
        return {'global': new_global, 'local': new_local,
                'cache': state['cache']}, {}


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


# ---------------------------------------------------------------------------
# Serving steps (global model)
# ---------------------------------------------------------------------------

def make_serve_setup(model: Model):
    return ServeSetup(model)


@dataclasses.dataclass
class ServeSetup:
    model: Model
    serve_rules: dict = None   # SERVE_PROFILES entry; None = SERVE_RULES

    @property
    def _rules(self):
        return self.serve_rules or SERVE_RULES

    def param_shardings(self, mesh: Mesh):
        return _shardings_for(self.model.param_axes(),
                              self.model.param_shapes(), mesh)

    def prefill_batch(self, shape):
        cfg = self.model.cfg
        B, S = shape.global_batch, shape.seq_len
        sds = {'tokens': jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == 'vlm':
            sds['patch_embeds'] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == 'audio':
            sds['frame_embeds'] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        return sds

    def prefill_axes(self):
        cfg = self.model.cfg
        ax = {'tokens': ('batch', None)}
        if cfg.family == 'vlm':
            ax['patch_embeds'] = ('batch', None, None)
        if cfg.family == 'audio':
            ax['frame_embeds'] = ('batch', None, None)
        return ax

    def prefill_step(self, params, batch):
        logits, _ = self.model.logits(params, batch)
        return logits[:, -1].argmax(-1)

    def decode_batch(self, shape):
        """(cache, tokens) ShapeDtypeStructs for one decode step with a full
        seq_len KV/SSM cache."""
        B, S = shape.global_batch, shape.seq_len
        cache = jax.eval_shape(
            lambda: self.model.init_cache(B, S, length=S - 1))
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return cache, tokens

    def decode_shardings(self, mesh: Mesh, shape):
        cache_sds, tok_sds = self.decode_batch(shape)
        cache_sh = _shardings_for(cache_axes(self.model.cfg), cache_sds, mesh,
                                  self._rules)
        tok_sh = NamedSharding(mesh, shd.spec_for(('batch', None),
                                                  tok_sds.shape, mesh,
                                                  self._rules))
        return cache_sh, tok_sh

    def prefill_shardings(self, mesh: Mesh, shape):
        return _shardings_for(self.prefill_axes(), self.prefill_batch(shape),
                              mesh, self._rules)

    def serve_step(self, params, cache, tokens):
        new_cache, logits = self.model.decode_step(params, cache, tokens)
        return new_cache, logits.argmax(-1)
