"""Staleness-adaptive aggregation family: discount functions, weighted
precomputes, and the SEAFL/CSAFL protocol registrations.

The family generalises FedAsync's merge-per-arrival mixing into *data*:
every scheme here reduces, on the host, to per-round effective merge
weights — either the [rounds, m] alpha tensors of the sequential-merge
engine (``precompute_async_schedule``) or the one-shot weight rows of the
weighted-merge engine (``precompute_weighted_schedule``) — which the
existing compiled scan/fleet engines replay unchanged.  ``federation.py``
is never touched: the new protocols plug in through ``api.register``.

Schemes
-------

* **FedAsync discounts** (Xie et al., via ``FedAsyncSpec.staleness_fn``):
  s(dt) in ``api.STALENESS_FNS`` scales the base alpha per commit;
  ``'poly'`` reproduces the legacy schedule bit-for-bit.
* **SEAFL-style adaptive weights** (``SeaflSpec``): one merge per round,
  each committed client weighted by its data share x staleness discount
  (optionally x a loss-term proxy), normalised over the committed set and
  scaled by alpha.
* **CSAFL-style clustered semi-async** (``CsaflSpec``): clients are
  clustered host-side by timing profile (``selection.cluster_by_profile``
  on ``FLEnv.full_train_time()``); each cluster sub-aggregates its commits
  by data share x per-client discount, and the cluster blends into the
  global model under its own rounds-since-last-merge discount.  The
  cluster masks lower to ordinary weight rows, so the packed merge kernel
  executes the per-cluster sub-aggregates as masked sub-sums of one
  launch.
* **Folded FedAsync** (``scheme='fedasync'`` via ``SweepMember.overrides``):
  the sequential arrival-ordered merge chain folded into closed-form
  effective weights (suffix products in float64), so a FedAsync member can
  ride in the same weighted fleet as SEAFL/CSAFL members.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import federation, protocol, schedules, selection
from repro.core.api import (STALENESS_FNS, ProtocolDef, ProtocolSpec,
                            register)
from repro.core.schedules import RoundRecord

__all__ = [
    'CsaflSpec', 'SeaflSpec', 'WEIGHTED_SCHEMES', 'async_kwargs',
    'precompute_async_schedule', 'precompute_weighted_schedule',
    'staleness_discount', 'weighted_kwargs',
]

#: weight-row builders of ``precompute_weighted_schedule``.  The scheme is
#: data, not trace: members of one fleet sweep may mix schemes via
#: ``SweepMember.overrides={'scheme': ...}``.
WEIGHTED_SCHEMES = ('seafl', 'csafl', 'fedasync')


# ---------------------------------------------------------------------------
# Discount functions
# ---------------------------------------------------------------------------

def staleness_discount(staleness, fn: str = 'poly', *,
                       staleness_exp: float = 0.5, hinge_a: float = 10.0,
                       hinge_b: int = 4) -> np.ndarray:
    """Elementwise staleness discount s(dt) in (0, 1] (host numpy).

    ``'constant'`` -> 1; ``'poly'`` -> (1+dt)^(-staleness_exp);
    ``'hinge'`` -> 1 while dt <= hinge_b, then 1/(hinge_a*(dt-hinge_b)),
    clamped to 1 so the discount never *amplifies* an update (the raw
    hinge exceeds 1 for dt just past the knee when hinge_a < 1/(dt-b))."""
    s = np.asarray(staleness, dtype=float)
    if fn == 'constant':
        return np.ones_like(s)
    if fn == 'poly':
        return (1.0 + s) ** (-staleness_exp)
    if fn == 'hinge':
        with np.errstate(divide='ignore'):
            tail = 1.0 / (hinge_a * (s - hinge_b))
        return np.where(s <= hinge_b, 1.0, np.minimum(1.0, tail))
    raise ValueError(
        f'unknown staleness_fn {fn!r} (want one of {STALENESS_FNS})')


def _apply_member(kw: dict, mem) -> dict:
    """Member hyper columns, then ``mem.overrides``, on top of the spec
    defaults.  Unknown override keys are rejected here — at precompute
    time — so a typo'd sweep fails before any device work."""
    kw['alpha'] = mem.alpha
    kw['staleness_exp'] = mem.staleness_exp
    if mem.overrides:
        unknown = sorted(set(mem.overrides) - set(kw))
        if unknown:
            raise ValueError(
                f'unknown member override keys {unknown}; this precompute '
                f'takes {sorted(kw)}')
        kw.update(mem.overrides)
    return kw


def async_kwargs(sp, mem=None) -> dict:
    """``precompute_async_schedule`` kwargs from a ``FedAsyncSpec`` (and
    optionally a ``SweepMember`` whose hyper columns/overrides win)."""
    kw = dict(alpha=sp.alpha, staleness_exp=sp.staleness_exp,
              staleness_fn=sp.staleness_fn, hinge_a=sp.hinge_a,
              hinge_b=sp.hinge_b)
    return kw if mem is None else _apply_member(kw, mem)


def weighted_kwargs(sp, mem=None) -> dict:
    """``precompute_weighted_schedule`` kwargs from a ``SeaflSpec`` /
    ``CsaflSpec`` (and optionally a ``SweepMember``).  ``overrides`` may
    switch ``scheme`` per member — including to ``'fedasync'``, whose
    sequential merge folds into weight rows — so one fleet dispatch can
    shoot out the whole family."""
    kw = dict(scheme='csafl' if isinstance(sp, CsaflSpec) else 'seafl',
              alpha=sp.alpha, staleness_fn=sp.staleness_fn,
              staleness_exp=sp.staleness_exp, hinge_a=sp.hinge_a,
              hinge_b=sp.hinge_b,
              use_loss=getattr(sp, 'use_loss', False),
              loss_coef=getattr(sp, 'loss_coef', 0.5),
              clusters=getattr(sp, 'clusters', 1))
    return kw if mem is None else _apply_member(kw, mem)


# ---------------------------------------------------------------------------
# Host precomputes
# ---------------------------------------------------------------------------

def precompute_async_schedule(env, *, rounds: int, alpha: float = 0.6,
                              staleness_fn: str = 'poly',
                              staleness_exp: float = 0.5,
                              hinge_a: float = 10.0, hinge_b: int = 4
                              ) -> schedules.FedasyncSchedule:
    """FedAsync event pass with a pluggable staleness discount.

    Same bookkeeping as ``federation.precompute_fedasync_schedule``
    (global-version counter, per-client staleness, bulk crash draws from
    the same rng stream); only the per-commit mixing weight generalises to
    ``alpha * s(staleness)``.  With ``staleness_fn='poly'`` the emitted
    schedule is bit-identical to the legacy one — the discount is the
    same float expression (1+dt)^(-exp) — which is how the upgraded
    ``FedAsyncSpec`` keeps its historical results (regression-tested)."""
    m = env.m
    tim = env.round_timing(rounds)        # [rounds, m] trace/wire-aware
    crashed_all, _ = env.draw_rounds(rounds)
    t_dist_m = env.t_dist(m)
    versions = np.zeros(m, dtype=float)   # global version at last pull
    global_version = 0
    committed_s = np.zeros((rounds, m), bool)
    order_s = np.zeros((rounds, m), np.int64)
    alphas_s = np.zeros((rounds, m))
    records = []

    for t in range(1, rounds + 1):
        crashed = crashed_all[t - 1]
        arrival_base = t_dist_m \
            + (tim.t_down[t - 1] + tim.t_up[t - 1]) + tim.full_tt[t - 1]
        arrival = np.where(~crashed, arrival_base, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        staleness = np.maximum(0.0, global_version - versions)
        i = t - 1
        committed_s[i] = committed
        order_s[i] = np.argsort(arrival, kind='stable')
        disc = staleness_discount(staleness, staleness_fn,
                                  staleness_exp=staleness_exp,
                                  hinge_a=hinge_a, hinge_b=hinge_b)
        alphas_s[i] = np.where(committed, alpha * disc, 0.0)
        global_version += int(committed.sum())
        versions[committed] = global_version
        records.append(_async_record(t, arrival, committed, crashed,
                                     staleness, env))

    return schedules.FedasyncSchedule(committed=committed_s, order=order_s,
                                      alphas=alphas_s, records=records,
                                      futility=0.0)


def _async_record(t, arrival, committed, crashed, staleness,
                  env) -> RoundRecord:
    """The per-round timing record every merge-per-arrival scheme shares
    (identical to the legacy FedAsync precompute's)."""
    return RoundRecord(
        round=t,
        round_len=federation._capped_round_len(arrival, committed, env.t_lim),
        t_dist=env.t_dist(int(committed.sum())),
        eur=float(committed.sum()) / arrival.shape[0],
        sr=1.0,  # every client syncs every round: max downlink pressure
        vv=float(np.var(staleness[committed])) if committed.any() else 0.0,
        n_picked=int(committed.sum()),
        n_committed=int(committed.sum()),
        n_crashed=int(crashed.sum()))


def _fold_sequential(a: np.ndarray, order: np.ndarray) -> np.ndarray:
    """Closed-form weights of the arrival-ordered sequential merge chain
    G := (1-a_k) G + a_k T_k: eff[k] = a_k * prod over later merges of
    (1 - a_l), computed as float64 suffix products.  The residual global
    weight 1 - sum(eff) equals prod(1 - a) by telescoping, so the fold is
    exactly the chain up to float rounding (allclose-, not bit-,
    equivalent to the sequential engine)."""
    m = a.shape[0]
    a_ord = a[order].astype(np.float64)
    suffix = np.ones(m, dtype=np.float64)
    if m > 1:
        suffix[:-1] = np.cumprod((1.0 - a_ord)[::-1])[::-1][1:]
    eff = np.zeros(m, dtype=np.float64)
    eff[order] = a_ord * suffix
    return eff


def precompute_weighted_schedule(env, *, rounds: int, scheme: str = 'seafl',
                                 alpha: float = 0.6,
                                 staleness_fn: str = 'poly',
                                 staleness_exp: float = 0.5,
                                 hinge_a: float = 10.0, hinge_b: int = 4,
                                 use_loss: bool = False,
                                 loss_coef: float = 0.5,
                                 clusters: int = 1
                                 ) -> schedules.WeightedSchedule:
    """One host pass emitting [rounds, m] one-shot merge weight rows.

    The event process (crash draws, arrivals, commits, version/staleness
    bookkeeping) is exactly FedAsync's — so staleness means the same thing
    across the family — and the scheme only decides how a round's commits
    turn into ``wrow``:

    * ``'seafl'``: wrow = alpha * normalise(data_w * s(staleness)
      [* (1 + loss_coef/(1 + commits))]) over the committed set.  The
      optional loss term uses the commit-count deficit as a
      model-independent proxy for the under-trained-client loss signal
      (clients that merged rarely get boosted), keeping the precompute
      free of model weights.
    * ``'csafl'``: clients are bucketed by ``cluster_by_profile``; within
      cluster g the commits sub-aggregate by data_w * s(staleness), and
      the cluster merges at weight alpha * s(rounds since g last merged)
      * W_g (its total data share).  Rows sum to <= alpha by construction
      (sum_g W_g = 1, discounts <= 1).
    * ``'fedasync'``: the per-arrival chain folded via
      ``_fold_sequential`` — FedAsync as a member of the weighted fleet.

    Every row is zero off the committed set and sums to at most alpha
    <= 1, so the merge's residual global weight stays non-negative
    (property-tested)."""
    if scheme not in WEIGHTED_SCHEMES:
        raise ValueError(
            f'unknown scheme {scheme!r} (want one of {WEIGHTED_SCHEMES})')
    m = env.m
    # CSAFL clusters on the *base* training profile (round-invariant by
    # design, so cluster membership is stable even under traces); arrivals
    # use the per-round trace/wire-aware timing
    full_tt = env.full_train_time()
    tim = env.round_timing(rounds)
    crashed_all, _ = env.draw_rounds(rounds)
    t_dist_m = env.t_dist(m)
    data_w = np.asarray(env.weights, dtype=float)
    versions = np.zeros(m, dtype=float)
    global_version = 0
    commits = np.zeros(m, dtype=float)        # seafl loss-proxy counter
    labels = selection.cluster_by_profile(full_tt, clusters)
    k = int(labels.max()) + 1
    cluster_w = np.bincount(labels, weights=data_w, minlength=k)
    last_merge = np.zeros(k, dtype=float)     # csafl per-cluster bookkeeping
    committed_s = np.zeros((rounds, m), bool)
    wrow_s = np.zeros((rounds, m))
    records = []

    def disc_of(x):
        return staleness_discount(x, staleness_fn,
                                  staleness_exp=staleness_exp,
                                  hinge_a=hinge_a, hinge_b=hinge_b)

    for t in range(1, rounds + 1):
        crashed = crashed_all[t - 1]
        arrival_base = t_dist_m \
            + (tim.t_down[t - 1] + tim.t_up[t - 1]) + tim.full_tt[t - 1]
        arrival = np.where(~crashed, arrival_base, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        staleness = np.maximum(0.0, global_version - versions)
        disc = disc_of(staleness)
        i = t - 1
        committed_s[i] = committed

        if scheme == 'fedasync':
            a = np.where(committed, alpha * disc, 0.0)
            wrow_s[i] = _fold_sequential(a, np.argsort(arrival, kind='stable'))
        elif scheme == 'seafl':
            base = data_w * disc
            if use_loss:
                base = base * (1.0 + loss_coef / (1.0 + commits))
            base = np.where(committed, base, 0.0)
            tot = base.sum()
            if tot > 0:
                wrow_s[i] = alpha * base / tot
        else:  # csafl
            base = np.where(committed, data_w * disc, 0.0)
            intra_tot = np.bincount(labels, weights=base, minlength=k)
            cdisc = disc_of(np.maximum(0.0, (t - 1) - last_merge))
            scale = np.where(intra_tot > 0,
                             alpha * cdisc * cluster_w
                             / np.where(intra_tot > 0, intra_tot, 1.0), 0.0)
            wrow_s[i] = base * scale[labels]
            merged = np.unique(labels[committed])
            last_merge[merged] = t

        commits += committed
        global_version += int(committed.sum())
        versions[committed] = global_version
        records.append(_async_record(t, arrival, committed, crashed,
                                     staleness, env))

    return schedules.WeightedSchedule(committed=committed_s, wrow=wrow_s,
                                      records=records, futility=0.0)


# ---------------------------------------------------------------------------
# Protocol specs + registration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SeaflSpec(ProtocolSpec):
    """SEAFL-style adaptive weighted aggregation: one merge per round,
    committed clients weighted by data share x staleness discount,
    normalised over the committed set and scaled by ``alpha`` (the
    residual 1 - alpha stays on the previous global model).

    ``use_loss=True`` adds the loss-term boost 1 + loss_coef/(1 +
    commits), a model-independent proxy that favours clients whose
    updates rarely landed (see ``precompute_weighted_schedule``)."""
    alpha: float = 0.6
    staleness_fn: str = 'poly'
    staleness_exp: float = 0.5
    hinge_a: float = 10.0
    hinge_b: int = 4
    use_loss: bool = False
    loss_coef: float = 0.5


@dataclasses.dataclass(frozen=True)
class CsaflSpec(ProtocolSpec):
    """CSAFL-style clustered semi-async aggregation: clients are grouped
    host-side by timing profile (quantile buckets of
    ``FLEnv.full_train_time()``), each cluster sub-aggregates its own
    commits, and clusters blend into the global model under their own
    rounds-since-last-merge discount.  ``clusters=1`` degenerates to
    plain adaptive weighting."""
    clusters: int = 2
    alpha: float = 0.6
    staleness_fn: str = 'poly'
    staleness_exp: float = 0.5
    hinge_a: float = 10.0
    hinge_b: int = 4


def _weighted_precompute(env, sp, *, rounds, seed):
    del seed  # the family's event process draws only from the env rng
    return precompute_weighted_schedule(env, rounds=rounds,
                                        **weighted_kwargs(sp))


def _weighted_fleet_precompute(members, sp, *, rounds):
    return schedules.WeightedFleetSchedule.stack([
        precompute_weighted_schedule(mem.env, rounds=rounds,
                                     **weighted_kwargs(sp, mem))
        for mem in members])


def _weighted_scan_segment(st, seg, weights, train_fn, ex):
    del weights  # merge weights live in the schedule
    st.global_w, st.local_w = protocol.weighted_run_scan(
        st.global_w, st.local_w, seg, local_train_fn=train_fn,
        use_kernel=ex.use_kernel, wire=ex.wire)


def _weighted_loop_round(st, sched, i, weights, train_fn, ex):
    del weights
    st.global_w, st.local_w = protocol.weighted_round(
        st.global_w, st.local_w,
        committed=jnp.asarray(sched.committed[i]),
        wrow=jnp.asarray(sched.wrow[i], jnp.float32),
        local_train_fn=train_fn, train_args=(i + 1,),
        use_kernel=ex.use_kernel, wire=ex.wire)


def _weighted_fleet_segment(st, seg, weights, train_fn, ex, ctx):
    del weights
    st.global_w, st.local_w = protocol.weighted_run_fleet(
        st.global_w, st.local_w, seg, local_train_fn=train_fn,
        use_kernel=ex.use_kernel, wire=ex.wire, train_ctx=ctx)


def _weighted_dispatch_budget(ex) -> int:
    """Pallas dispatches per compiled weighted-merge round (analysis
    JAX001): one fused merge on the packed path, plus the int8 wire
    round-trip (quantize + dequantize) when compressed."""
    merge = 1 if ex.use_kernel == 'packed' else 0
    return merge + (2 if ex.wire == 'int8' else 0)


register(ProtocolDef(
    name='seafl', spec_cls=SeaflSpec,
    precompute=_weighted_precompute,
    fleet_precompute=_weighted_fleet_precompute,
    scan_segment=_weighted_scan_segment, loop_round=_weighted_loop_round,
    fleet_segment=_weighted_fleet_segment,
    supports_wire=True, supports_kernel='packed', spec_overrides=True,
    dispatch_budget=_weighted_dispatch_budget))

register(ProtocolDef(
    name='csafl', spec_cls=CsaflSpec,
    precompute=_weighted_precompute,
    fleet_precompute=_weighted_fleet_precompute,
    scan_segment=_weighted_scan_segment, loop_round=_weighted_loop_round,
    fleet_segment=_weighted_fleet_segment,
    supports_wire=True, supports_kernel='packed', spec_overrides=True,
    dispatch_budget=_weighted_dispatch_budget))
