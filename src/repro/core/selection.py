"""Client selection policies (server-side orchestration; numpy).

CFCFM (Algorithm 1) — Compensatory First-Come-First-Merge: the server picks
arriving updates until the quota C*m is met, giving priority to clients that
were NOT picked in the previous round; leftover quota is filled from the
remaining arrivals in arrival order.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


def quota_of(fraction: float, m: int) -> int:
    """The C*m selection quota shared by every policy: at least one
    client, round-half-to-even (Python ``round`` == ``np.rint``, which the
    batched selectors rely on for row identity)."""
    return max(1, int(round(fraction * m)))


@dataclasses.dataclass
class SelectionResult:
    picked: np.ndarray       # [m] bool — P(t)
    undrafted: np.ndarray    # [m] bool — Q(t): committed but not picked
    committed: np.ndarray    # [m] bool — W(t): finished & arrived by deadline
    quota_met_time: float    # arrival time of the quota-filling update (or deadline)


def cfcfm(arrival: np.ndarray, completed: np.ndarray, picked_prev: np.ndarray,
          fraction: float, deadline: float) -> SelectionResult:
    """arrival: [m] float arrival times (inf for crashed); completed: [m]
    bool (finished training); picked_prev: [m] bool = P(t-1)."""
    m = arrival.shape[0]
    quota = quota_of(fraction, m)
    committed = completed & (arrival <= deadline)
    picked = np.zeros(m, bool)

    # Phase 1: priority clients (not picked last round), in arrival order.
    prio = committed & ~picked_prev
    order = np.argsort(np.where(prio, arrival, np.inf), kind='stable')
    take = order[:quota][prio[order[:quota]]]
    picked[take] = True

    # Phase 2: fill remaining quota from the rest (picked last round).
    short = quota - picked.sum()
    if short > 0:
        rest = committed & ~picked
        order2 = np.argsort(np.where(rest, arrival, np.inf), kind='stable')
        take2 = order2[:short][rest[order2[:short]]]
        picked[take2] = True

    undrafted = committed & ~picked
    if short <= 0 and picked.any():
        # quota filled by priority arrivals: round closes at the quota-th one
        quota_met = float(np.max(arrival[picked]))
    elif committed.any():
        # the server waits for all live clients (crashes are detectable),
        # then tops the quota up from the remaining arrivals
        quota_met = float(np.max(arrival[committed]))
    else:
        quota_met = deadline
    return SelectionResult(picked, undrafted, committed, min(quota_met, deadline))


@dataclasses.dataclass
class BatchSelectionResult:
    """Fleet-batched ``SelectionResult``: [S, m] masks, [S] times."""
    picked: np.ndarray
    undrafted: np.ndarray
    committed: np.ndarray
    quota_met_time: np.ndarray


def cfcfm_batch(arrival: np.ndarray, completed: np.ndarray,
                picked_prev: np.ndarray, fraction: np.ndarray,
                deadline: np.ndarray, *,
                quota: Optional[np.ndarray] = None) -> BatchSelectionResult:
    """CFCFM for a whole fleet in one vectorised pass.

    arrival/completed/picked_prev: [S, m]; fraction/deadline: [S] (or
    scalars).  Row s is bit-identical to ``cfcfm(arrival[s], ...)`` — the
    fleet schedule precompute relies on this (regression-tested).  The
    per-member "take arrivals in order up to quota" scan becomes a rank
    comparison: a client is picked in phase 1 iff it is eligible and its
    stable arrival rank among eligible clients beats the quota.

    ``quota`` (the [S] int result of ``max(1, round(fraction * m))``) may
    be precomputed by per-round callers; it only depends on the fractions.
    """
    s, m = arrival.shape
    deadline = np.broadcast_to(np.asarray(deadline, float), (s,))
    if quota is None:
        fraction = np.broadcast_to(np.asarray(fraction, float), (s,))
        # np.rint rounds half-to-even exactly like the scalar path's round()
        quota = np.maximum(1, np.rint(fraction * m).astype(int))
    committed = completed & (arrival <= deadline[:, None])

    def rank(eligible):
        """Stable arrival rank (ineligible clients rank last)."""
        order = np.argsort(np.where(eligible, arrival, np.inf), axis=-1,
                           kind='stable')
        return np.argsort(order, axis=-1, kind='stable')  # inverse perm

    # Phase 1: priority clients (not picked last round), in arrival order.
    prio = committed & ~picked_prev
    picked = prio & (rank(prio) < quota[:, None])
    # Phase 2: fill remaining quota from the rest (picked last round).
    short = quota - picked.sum(axis=-1)
    rest = committed & ~picked
    picked = picked | (rest & (rank(rest) < short[:, None]))

    undrafted = committed & ~picked
    picked_max = np.max(np.where(picked, arrival, -np.inf), axis=-1)
    committed_max = np.max(np.where(committed, arrival, -np.inf), axis=-1)
    quota_met = np.where(
        (short <= 0) & picked.any(axis=-1), picked_max,
        np.where(committed.any(axis=-1), committed_max, deadline))
    return BatchSelectionResult(picked, undrafted, committed,
                                np.minimum(quota_met, deadline))


def fedavg_select(rng: np.random.Generator, m: int, fraction: float) -> np.ndarray:
    """Random pre-training selection (FedAvg)."""
    quota = quota_of(fraction, m)
    sel = np.zeros(m, bool)
    sel[rng.choice(m, size=quota, replace=False)] = True
    return sel


def fedavg_select_topk(rng: np.random.Generator, m: int, fraction: float,
                       rounds: int = 1) -> np.ndarray:
    """Vectorised without-replacement uniform selection: [rounds, quota]
    sorted client indices.

    One bulk ``rng.random((rounds, m))`` draw; per round the quota clients
    with the smallest uniforms win — distributionally a uniform
    without-replacement sample, with no per-round ``Generator.choice``
    loop.  This is the sparse stream contract (``sampler='topk'``): it
    emits index lists directly, so sparse schedules never materialise a
    [rounds, m] mask.  The draw order is row-major, so chunking over
    rounds consumes the stream identically — which is how this is
    implemented: rounds are drawn in bounded chunks so peak host memory
    is O(chunk * m), not O(rounds * m), at million-client populations."""
    quota = quota_of(fraction, m)
    chunk = max(1, min(rounds, int(4e6) // max(m, 1) + 1))
    out = np.empty((rounds, quota), np.int32)
    for lo in range(0, rounds, chunk):
        u = rng.random((min(chunk, rounds - lo), m))
        idx = np.argpartition(u, quota - 1, axis=-1)[:, :quota]
        out[lo:lo + len(u)] = np.sort(idx, axis=-1)
    return out


def fedavg_select_batch(rngs, m: int, fraction, rounds: int = 1,
                        sampler: str = 'choice') -> np.ndarray:
    """FedAvg selections for a whole fleet: [S, rounds, m] bool.

    ``rngs`` is one ``np.random.Generator`` per member; ``fraction`` is [S]
    (or a scalar).

    ``sampler='choice'`` (default, legacy stream): row (s, t) is
    bit-identical to the t-th sequential ``fedavg_select(rngs[s], m,
    fraction[s])`` call — the without-replacement draw has no batched
    Generator form that consumes the stream the same way, so the per-round
    ``choice()`` calls stay the generator's own; only the quota computation
    and the mask scatter are batched.

    ``sampler='topk'`` scatters ``fedavg_select_topk`` rows instead: one
    bulk uniform draw per member, no per-round loop — the fast path for
    large populations (its stream differs from 'choice' by design).
    """
    if sampler not in ('choice', 'topk'):
        raise ValueError(
            f"unknown sampler {sampler!r} (want 'choice' or 'topk')")
    s = len(rngs)
    fraction = np.broadcast_to(np.asarray(fraction, float), (s,))
    # np.rint rounds half-to-even exactly like the scalar path's round()
    quota = np.maximum(1, np.rint(fraction * m).astype(int))
    sel = np.zeros((s, rounds, m), bool)
    rows = np.arange(rounds)
    for i, rng in enumerate(rngs):
        if sampler == 'topk':
            idx = fedavg_select_topk(rng, m, float(fraction[i]), rounds)
        else:
            idx = np.stack([rng.choice(m, size=quota[i], replace=False)
                            for _ in range(rounds)])
        sel[i, rows[:, None], idx] = True
    return sel


def fedcs_select(est_round_time: np.ndarray, fraction: float,
                 deadline: float) -> np.ndarray:
    """FedCS (Nishio & Yonetani): the server estimates each client's round
    time and greedily admits the fastest clients that fit the deadline, up
    to the C*m quota."""
    m = est_round_time.shape[0]
    quota = quota_of(fraction, m)
    order = np.argsort(est_round_time, kind='stable')
    sel = np.zeros(m, bool)
    n = 0
    for k in order:
        if n >= quota:
            break
        if est_round_time[k] <= deadline:
            sel[k] = True
            n += 1
    if n == 0:  # degenerate: admit the single fastest client
        sel[order[0]] = True
    return sel


def cluster_by_profile(profile: np.ndarray, clusters: int) -> np.ndarray:
    """CSAFL-style host-side client clustering: [m] int labels in
    [0, clusters) from a per-client timing/crash profile (e.g.
    ``FLEnv.full_train_time()`` — slow clients land together, so each
    cluster's semi-async sub-aggregation mixes updates of similar
    staleness).

    Quantile bucketing on the stable profile rank: label k holds the
    clients between the k/clusters and (k+1)/clusters rank quantiles, so
    clusters are balanced to within one client and the labels are a
    partition by construction (deterministic, no iterative k-means
    state).  ``clusters`` is capped at m; with ``clusters=1`` every
    client shares one group and the scheme degenerates to plain adaptive
    weighting."""
    m = profile.shape[0]
    if clusters < 1:
        raise ValueError(f'clusters must be >= 1, got {clusters}')
    k = min(int(clusters), m)
    order = np.argsort(profile, kind='stable')
    rank = np.argsort(order, kind='stable')     # inverse perm
    return (rank * k) // m


def fedcs_select_batch(est_round_time: np.ndarray, fraction,
                       deadline) -> np.ndarray:
    """FedCS for a whole fleet in one vectorised pass: [S, m] bool.

    est_round_time: [S, m]; fraction/deadline: [S] (or scalars).  Row s is
    bit-identical to ``fedcs_select(est_round_time[s], ...)`` — the scalar
    greedy "admit fastest fitting clients until quota" loop becomes a rank
    comparison: a client is admitted iff it fits the deadline and its
    stable speed rank among fitting clients beats the quota.
    """
    s, m = est_round_time.shape
    fraction = np.broadcast_to(np.asarray(fraction, float), (s,))
    deadline = np.broadcast_to(np.asarray(deadline, float), (s,))
    quota = np.maximum(1, np.rint(fraction * m).astype(int))
    fits = est_round_time <= deadline[:, None]
    order = np.argsort(np.where(fits, est_round_time, np.inf), axis=-1,
                       kind='stable')
    rank = np.argsort(order, axis=-1, kind='stable')  # inverse perm
    sel = fits & (rank < quota[:, None])
    # degenerate: nothing fits the deadline -> admit the single fastest
    none = ~fits.any(axis=-1)
    fastest = np.argsort(est_round_time, axis=-1, kind='stable')[:, 0]
    sel[none, fastest[none]] = True
    return sel
