"""Unified experiment API: declarative specs -> protocol registry ->
compiled, resumable runners.

One spec, one compile step, many protocols::

    from repro import api

    exp = api.Experiment(task, env,
                         api.SafaSpec(fraction=0.5, lag_tolerance=5),
                         api.ExecSpec(eval_every=15),
                         rounds=60)
    hist = exp.compile().run()

The pieces:

* **Protocol specs** (``SafaSpec``/``FedAvgSpec``/``FedCSSpec``/
  ``LocalSpec``/``FedAsyncSpec``) are frozen dataclasses carrying only
  protocol-semantic fields; **``ExecSpec``** carries execution knobs
  (``engine``, ``wire``, ``use_kernel``, ``shard``, ``eval_every``,
  ``numeric``).  All cross-field validation lives in ``check_compat``.
* **``PROTOCOLS``** maps each spec type to a ``ProtocolDef`` — the
  protocol's precompute / scan / fleet triple plus its loop-engine round
  — so a new variant (say, a SEAFL-style staleness-discounted
  aggregation) registers with ``api.register`` and immediately gains
  every engine, sweep batching, and checkpointing, without touching
  ``federation.py``.
* **``Experiment``** binds (task, env, protocol spec, exec spec, rounds,
  seed); ``.precompute()`` runs the host event state machine once (the
  env rng is consumed exactly once, the schedule is cached) and
  ``.compile()`` returns a ``CompiledRunner``.
* **``CompiledRunner.run()``** executes the single run;
  ``.run_sweep(members)`` executes S member configurations as a batched
  fleet (``SweepSpec(members, tasks=...)`` for per-member Tasks via
  padded stacking).  Both accept ``checkpoint=`` for kill/resume: the
  scan carry and the host schedule cursor persist at every eval-segment
  boundary (``repro.checkpoint``), and a resumed run finishes
  bit-identical to an uninterrupted one.

The legacy free functions (``federation.run_safa`` & co.) are thin shims
over this module and emit ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro import fedsim
from repro.core import federation, protocol, schedules
from repro.core.federation import Task
from repro.core.schedules import History, RoundRecord, SweepMember

__all__ = [
    'CompiledRunner', 'ExecSpec', 'Experiment', 'FedAsyncSpec', 'FedAvgSpec',
    'FedCSSpec', 'History', 'LocalSpec', 'PROTOCOLS', 'ProtocolDef',
    'ProtocolSpec', 'RoundRecord', 'STALENESS_FNS', 'SafaSpec', 'SweepMember',
    'SweepSpec', 'Task', 'check_compat', 'init_fleet_global', 'register',
    'spec',
]


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProtocolSpec:
    """Base class for protocol specs: protocol-semantic fields only —
    execution knobs live in ``ExecSpec``."""


@dataclasses.dataclass(frozen=True)
class SafaSpec(ProtocolSpec):
    """SAFA (the paper's protocol): post-training CFCFM selection at
    quota C*m, Eq. 3 lag-tolerant distribution, Eq. 6-8 three-bypass
    aggregation.  ``quantize_uploads`` is the per-leaf int8 reference
    form of the packed ``wire='int8'`` path (mutually exclusive)."""
    fraction: float = 0.5
    lag_tolerance: int = 5
    quantize_uploads: bool = False


@dataclasses.dataclass(frozen=True)
class FedAvgSpec(ProtocolSpec):
    """FedAvg baseline: random pre-training selection, synchronous.

    ``sampler`` picks the without-replacement draw: ``'choice'`` (default)
    is the legacy per-round ``Generator.choice`` stream; ``'topk'`` is the
    vectorised bulk-uniform draw (one ``rng.random((rounds, m))``) that
    scales to large populations — distributionally identical, different
    stream by design."""
    fraction: float = 0.5
    sampler: str = 'choice'


@dataclasses.dataclass(frozen=True)
class FedCSSpec(ProtocolSpec):
    """FedCS baseline: fastest-first selection under the deadline."""
    fraction: float = 0.5


@dataclasses.dataclass(frozen=True)
class LocalSpec(ProtocolSpec):
    """Fully-local baseline: no aggregation except at eval points."""
    fraction: float = 0.5


#: staleness-discount functions s(dt) of the FedAsync family (Xie et al.):
#: ``'constant'`` -> 1; ``'hinge'`` -> 1 if dt <= b else 1/(a*(dt-b)),
#: clamped to (0, 1]; ``'poly'`` -> (1+dt)^(-a).  The discount scales the
#: base mixing weight alpha, so every variant replays through the same
#: precomputed per-round alpha tensors.
STALENESS_FNS = ('constant', 'hinge', 'poly')


@dataclasses.dataclass(frozen=True)
class FedAsyncSpec(ProtocolSpec):
    """FedAsync baseline: every client, every round; merge-per-arrival
    with staleness-discounted mixing alpha * s(staleness).

    ``staleness_fn`` picks s(dt) from ``STALENESS_FNS``; the default
    ``'poly'`` is the legacy alpha*(1+staleness)^(-staleness_exp) form,
    bit-identical to the pre-``staleness_fn`` schedules.  ``hinge_a`` /
    ``hinge_b`` parameterise the hinge discount (ignored otherwise)."""
    alpha: float = 0.6
    staleness_exp: float = 0.5
    staleness_fn: str = 'poly'
    hinge_a: float = 10.0
    hinge_b: int = 4


@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """Execution knobs, orthogonal to protocol semantics.

    ``engine=None`` resolves to the compiled default: ``'scan'`` for
    ``run()``, ``'fleet'`` for ``run_sweep()``; the reference engines
    (``'loop'`` / ``'sequential'``) stay available and bit-identical.

    ``schedule`` picks the schedule representation and round math:

    * ``'dense'`` — [rounds, m] masks, every client's row flows through
      every round (the paper-scale reference).
    * ``'sparse'`` — [rounds, quota] (idx, roles) tensors; only the
      active rows are trained, then the identical dense server trace
      runs.  Bit-identical to ``'dense'``, training FLOPs O(quota).
    * ``'sparse_delta'`` — additionally keeps the aggregation O(quota·N)
      per round by carrying the running weighted sum as a delta target.
      Allclose- (not bit-) equivalent; with ``use_kernel='packed'``
      (SAFA) the whole round fuses into one rows-indexed dispatch on
      resident pack buffers.
    * ``'sparse_tier'`` — (SAFA) replaces the remaining [m, N] cache
      stack with a lag-tier value buffer of capacity + 1 rows
      (capacity = peak live version snapshots + commit rows,
      O(tau+quota)) plus host-precomputed slot maps.  Resident state is
      O((tau+quota)·N) — independent of m.  Same slot math as
      ``'sparse_delta'`` (allclose to it and to ``'dense'``); scan==loop
      and fleet==sequential stay bit-identical within the form."""
    engine: Optional[str] = None
    wire: str = 'f32'
    use_kernel: Any = False
    schedule: str = 'dense'
    shard: bool = True
    eval_every: int = 10
    numeric: bool = True


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A sweep: S member configurations, optionally with per-member
    ``tasks`` (one per member, padded-stacked so members may hold
    different client partitions — multi-``seed`` env sweeps batch too)."""
    members: tuple
    tasks: Optional[tuple] = None

    def __post_init__(self):
        object.__setattr__(self, 'members', tuple(self.members))
        if self.tasks is not None:
            object.__setattr__(self, 'tasks', tuple(self.tasks))
            if len(self.tasks) != len(self.members):
                raise ValueError(
                    f'got {len(self.tasks)} tasks for {len(self.members)} '
                    f'members (want one task per member, or tasks=None '
                    f'for a shared task)')


# ---------------------------------------------------------------------------
# Protocol registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProtocolDef:
    """Everything the runners need to execute one protocol.

    ``precompute(env, spec, *, rounds, seed)`` runs the host event state
    machine; ``fleet_precompute(members, *, rounds)`` the fleet-major
    form.  ``scan_segment`` / ``fleet_segment`` advance the model state
    through one compiled eval segment; ``loop_round`` is the per-round
    reference; ``finish_segment`` (optional) runs at eval stops (the
    fully-local aggregation).  Registering a new def via ``register``
    makes the protocol available to ``Experiment`` and sweeps without
    touching ``federation.py``."""
    name: str
    spec_cls: type
    precompute: Callable
    fleet_precompute: Callable
    scan_segment: Callable
    loop_round: Callable
    fleet_segment: Callable
    finish_segment: Optional[Callable] = None
    uses_cache: bool = False
    supports_wire: bool = False
    #: fused-aggregation kernel support: ``False`` (no kernel), ``True``
    #: (both the per-leaf kernel and the packed one), or ``'packed'`` —
    #: the protocol's merge only exists on pack buffers, so ``use_kernel``
    #: takes ``False`` or ``'packed'`` but never ``True`` (the weighted
    #: aggregation family has no leaf-wise kernel form).
    supports_kernel: Any = False
    #: sparse-schedule support (``ExecSpec.schedule != 'dense'``):
    #: ``sparse_precompute(env, spec, *, rounds, seed)`` emits the native
    #: [rounds, quota] schedule (None -> protocol rejects sparse);
    #: ``prepare_state(st, weights, ex, fleet)`` converts the initial
    #: model state for the schedule mode (running aggregate, pack
    #: buffers, dropping stateless carries) before any round runs.
    sparse_precompute: Optional[Callable] = None
    prepare_state: Optional[Callable] = None
    #: lag-tier schedule support (``ExecSpec.schedule == 'sparse_tier'``):
    #: ``tier_precompute(env, spec, *, rounds, seed)`` emits the
    #: [rounds, quota] (idx, roles) tensors plus the slot maps over the
    #: O(tau+quota) value buffer (None -> protocol rejects sparse_tier).
    tier_precompute: Optional[Callable] = None
    #: the protocol's sparse_delta carry is the global model alone (no
    #: [m, ...] local/cache stacks): the runners then never materialise
    #: the O(m) state — resident memory stays quota-bounded at any m.
    delta_stateless: bool = False
    #: the protocol's precompute consumes leftover ``SweepMember.overrides``
    #: keys as protocol-spec fields (the staleness-adaptive family).  When
    #: False, override keys that are not ``EnvSpec`` fields are rejected
    #: at sweep-resolution time with a golden message.
    spec_overrides: bool = False
    #: static dispatch budget for ``repro.analysis`` (JAX001):
    #: ``dispatch_budget(ex)`` returns the pallas dispatches one compiled
    #: round of the admitted exec cell issues, or None for cells with no
    #: declared budget (e.g. the leaf-wise kernel path, whose count
    #: scales with the model's pytree).  This is where "a fully
    #: compressed SAFA round is exactly 2 dispatches" lives as data.
    dispatch_budget: Optional[Callable] = None
    #: static alias claims for ``repro.analysis`` (JAX003):
    #: ``alias_claims(ex)`` returns {kernel body name: alias pairs} that
    #: must appear, exactly, among the cell's lowered pallas_call sites;
    #: names/pairs key into the kernel modules' ``ALIAS_CONTRACTS``.
    alias_claims: Optional[Callable] = None


#: spec type -> ProtocolDef.  The single source of protocol dispatch.
PROTOCOLS: dict = {}
_BY_NAME: dict = {}


def register(pdef: ProtocolDef) -> ProtocolDef:
    """Add a protocol to the registry (spec type and name must be new)."""
    if pdef.spec_cls in PROTOCOLS:
        raise ValueError(f'spec type {pdef.spec_cls.__name__} already '
                         f'registered (as {PROTOCOLS[pdef.spec_cls].name!r})')
    if pdef.name in _BY_NAME:
        raise ValueError(f'protocol name {pdef.name!r} already registered')
    PROTOCOLS[pdef.spec_cls] = pdef
    _BY_NAME[pdef.name] = pdef
    return pdef


def spec(name: str, **fields) -> ProtocolSpec:
    """Build a protocol spec by registry name ('safa', 'fedavg', ...)."""
    if name not in _BY_NAME:
        raise ValueError(
            f'unknown proto {name!r} (want one of {sorted(_BY_NAME)})')
    return _BY_NAME[name].spec_cls(**fields)


def check_compat(protocol_spec: ProtocolSpec,
                 exec_spec: Optional[ExecSpec] = None,
                 env=None) -> ProtocolDef:
    """Validate a (protocol, exec[, env]) spec triple; returns the
    ProtocolDef.

    This is the single home for every cross-field rule the legacy
    runners enforced ad hoc: wire values, engine names, kernel modes,
    wire x protocol compatibility, and the quantize_uploads-vs-wire
    exclusivity.  ``env`` (optional) is an ``fedsim.EnvSpec`` — or a
    built ``Env``, validated through its spec — checked with the same
    golden messages ``EnvSpec.build()`` raises."""
    pdef = PROTOCOLS.get(type(protocol_spec))
    if pdef is None:
        raise TypeError(
            f'unregistered protocol spec {type(protocol_spec).__name__!r}; '
            f'known specs: {sorted(c.__name__ for c in PROTOCOLS)} '
            f'(register new ones via api.register)')
    ex = exec_spec if exec_spec is not None else ExecSpec()
    if env is not None:
        env_spec = getattr(env, 'spec', env)
        if isinstance(env_spec, fedsim.EnvSpec):
            fedsim.validate_env_spec(env_spec)
    protocol.check_wire(ex.wire)
    if ex.engine not in (None, 'scan', 'loop', 'fleet', 'sequential'):
        raise ValueError(
            f'unknown engine {ex.engine!r} (want "scan"/"loop" for runs, '
            f'"fleet"/"sequential" for sweeps, or None for the default)')
    if ex.use_kernel not in (False, True, 'packed'):
        raise ValueError(
            f'unknown use_kernel {ex.use_kernel!r} (want False, True, or '
            f'"packed")')
    if ex.wire != 'f32' and not pdef.supports_wire:
        wired = '/'.join(sorted(p.name for p in PROTOCOLS.values()
                                if p.supports_wire))
        raise ValueError(
            f"protocol {pdef.name!r} has no upload-aggregate wire; "
            f"wire='int8' applies to {wired} only")
    if ex.use_kernel and not pdef.supports_kernel:
        kerneled = '/'.join(sorted(p.name for p in PROTOCOLS.values()
                                   if p.supports_kernel))
        raise ValueError(
            f'protocol {pdef.name!r} has no fused aggregation kernel; '
            f'use_kernel applies to {kerneled} only')
    if ex.use_kernel is True and pdef.supports_kernel == 'packed':
        raise ValueError(
            f'protocol {pdef.name!r} aggregates on pack buffers only (no '
            f"leaf-wise kernel form); use_kernel takes False or 'packed'")
    fn = getattr(protocol_spec, 'staleness_fn', None)
    if fn is not None and fn not in STALENESS_FNS:
        raise ValueError(
            f'unknown staleness_fn {fn!r} (want one of {STALENESS_FNS})')
    alpha = getattr(protocol_spec, 'alpha', None)
    if alpha is not None and not 0.0 < alpha <= 1.0:
        raise ValueError(
            f'alpha must be in (0, 1] (the residual global weight '
            f'1 - sum(wrow) must stay non-negative), got {alpha}')
    if getattr(protocol_spec, 'hinge_a', 1.0) <= 0:
        raise ValueError(
            f'hinge_a must be > 0, got {protocol_spec.hinge_a}')
    if getattr(protocol_spec, 'clusters', 1) < 1:
        raise ValueError(
            f'clusters must be >= 1, got {protocol_spec.clusters}')
    if getattr(protocol_spec, 'quantize_uploads', False) and ex.wire != 'f32':
        raise ValueError(
            "quantize_uploads=True is the per-leaf reference for the packed "
            "wire='int8' path; pass one or the other, not both")
    if getattr(protocol_spec, 'sampler', 'choice') not in ('choice', 'topk'):
        raise ValueError(
            f'unknown sampler {protocol_spec.sampler!r} '
            f"(want 'choice' or 'topk')")
    if ex.schedule not in ('dense', 'sparse', 'sparse_delta', 'sparse_tier'):
        raise ValueError(
            f'unknown schedule {ex.schedule!r} (want "dense", "sparse", '
            f'"sparse_delta", or "sparse_tier")')
    if ex.schedule != 'dense':
        if pdef.sparse_precompute is None:
            raise ValueError(
                f'protocol {pdef.name!r} has no sparse schedule form; '
                f'sparse schedules apply to safa/fedavg/fedcs only')
        if ex.schedule == 'sparse_tier' and pdef.tier_precompute is None:
            raise ValueError(
                f'protocol {pdef.name!r} has no lag-tier schedule form; '
                f"schedule='sparse_tier' applies to safa only (the "
                f'version-ring compression needs SAFA lag-bounded bases)')
        if getattr(protocol_spec, 'quantize_uploads', False):
            raise ValueError(
                'quantize_uploads is the dense per-leaf reference knob; '
                "sparse schedules take the packed wire instead "
                "(wire='int8')")
        if ex.schedule in ('sparse_delta', 'sparse_tier') \
                and ex.use_kernel is True:
            raise ValueError(
                f'the leaf-wise kernel (use_kernel=True) has no rows form; '
                f"schedule={ex.schedule!r} takes use_kernel=False or "
                f"'packed'")
    return pdef


# ---------------------------------------------------------------------------
# Engine plumbing (shared by every protocol def)
# ---------------------------------------------------------------------------

class _RunState:
    """The model-state carry between segments: global/local(/cache).

    Sparse-delta modes add ``agg`` (the running Eq. 7 aggregate) and,
    under ``use_kernel='packed'``, ``packed`` — the (global, local,
    cache, agg) pack-buffer carry with layout ``spec`` (static, rebuilt
    on resume) that replaces the local/cache/agg trees entirely."""
    __slots__ = ('global_w', 'local_w', 'cache', 'agg', 'packed', 'spec')

    def __init__(self, global_w=None, local_w=None, cache=None):
        self.global_w, self.local_w, self.cache = global_w, local_w, cache
        self.agg, self.packed, self.spec = None, None, None

    def tree(self):
        t = {'global': self.global_w, 'local': self.local_w}
        if self.cache is not None:
            t['cache'] = self.cache
        if self.agg is not None:
            t['agg'] = self.agg
        if self.packed is not None:
            t['packed'] = self.packed
        return t

    def set_tree(self, t):
        self.global_w, self.local_w = t['global'], t['local']
        self.cache = t.get('cache')
        self.agg = t.get('agg')
        self.packed = t.get('packed')


def _to_j(mask: np.ndarray):
    return jnp.asarray(mask)


def _eval_rounds(rounds: int, eval_every: int):
    """Rounds at which the runners evaluate the global model.

    These are also the scan-engine segment boundaries — and therefore the
    checkpoint/resume boundaries: at most two distinct segment lengths
    exist per run (eval_every and a ragged final remainder), so the
    scanned program traces at most twice."""
    stops = sorted(set(range(eval_every, rounds + 1, eval_every)) | {rounds})
    return [t for t in stops if t >= 1]


def _record_eval(hist: History, rec: RoundRecord, task, global_w):
    rec.eval = task.evaluate(global_w)
    if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
        hist.best_eval = rec.eval


def _stack_trees(trees):
    return jax.tree.map(lambda *a: jnp.stack(a), *trees)


def _tree_member(tree, s: int):
    return jax.tree.map(lambda a: a[s], tree)


def _init_state(task, m: int, seed: int, uses_cache: bool,
                stateless: bool = False) -> _RunState:
    key = jax.random.PRNGKey(seed)
    g = task.init_global(key)
    if stateless:           # sparse_delta with a global-only carry: never
        return _RunState(g, None, None)   # materialise the [m, ...] stacks
    return _RunState(g, protocol.broadcast_global(g, m),
                     protocol.broadcast_global(g, m) if uses_cache else None)


def _apply_saved_history(hist: History, d: dict) -> None:
    """Replay a checkpoint's eval entries into a freshly-precomputed
    History (records/futility are recomputed bit-identically; only the
    evals and best_eval need restoring)."""
    hist.best_eval = d['best_eval']
    for rec, rd in zip(hist.records, d['records']):
        if rd.get('eval') is not None:
            rec.eval = rd['eval']


def _fp_val(v):
    """Checkpoint-fingerprint form of one spec field value: recurse into
    nested dataclasses (trace specs), hash ndarrays (``Replay`` traces)
    so a fingerprint never embeds megabytes of trace data."""
    if isinstance(v, np.ndarray):
        digest = hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest()
        return f'ndarray{v.shape}:{digest}'
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return (type(v).__name__,
                [(f.name, _fp_val(getattr(v, f.name)))
                 for f in dataclasses.fields(v)])
    return v


def _env_fp(env) -> str:
    """Environment identity for checkpoint fingerprints: the declarative
    spec's fields (a built ``Env`` fingerprints as its spec — same
    spelling, same fingerprint)."""
    spec = getattr(env, 'spec', env)
    return repr(_fp_val(spec))


#: declarative env fields a ``SweepMember.overrides`` dict may set
_ENV_FIELDS = frozenset(f.name for f in dataclasses.fields(fedsim.EnvSpec))


def _wire_mb_of(task, wire: str):
    """Measured (uplink, downlink) megabytes of the task's model under the
    active wire (``EnvSpec(comm='wire')``): the uplink ships client
    updates — packed int8 buffers under ``wire='int8'``, plain f32 leaves
    otherwise — while the server always distributes the uncompressed
    global.  Memoised on the task (one throwaway ``init_global`` per
    distinct wire) so sweeps measure once."""
    from repro.kernels import ops as kops
    cache = task.__dict__.setdefault('_wire_mb_cache', {})
    if wire not in cache:
        g = task.init_global(jax.random.PRNGKey(0))
        up = kops.comm_bytes(g, wire == 'int8',
                             layout='packed' if wire == 'int8' else 'tree')
        down = kops.comm_bytes(g, False, layout='tree')
        cache[wire] = (up / 1e6, down / 1e6)
    return cache[wire]


def _realize_env(env, *, task, ex):
    """``EnvSpec`` -> built ``Env``; built envs pass through.  When the
    spec asks for wire-derived comm (``comm='wire'``), measure the task
    model's actual bytes under ``ex.wire`` and inject them before any
    schedule precompute runs."""
    if env is None:
        return None
    if isinstance(env, fedsim.EnvSpec):
        env = env.build()
    if getattr(env, 'comm', 'static') == 'wire':
        if task is None:
            raise ValueError(
                "EnvSpec(comm='wire') derives comm times from the "
                'experiment model; this run has no Task to measure '
                "(pass a Task, or use comm='static')")
        env.set_wire_mb(*_wire_mb_of(task, ex.wire))
    return env


def _resolve_member(mem: SweepMember, *, pdef: ProtocolDef, task,
                    ex: ExecSpec) -> SweepMember:
    """Split a member's overrides into env fields vs protocol fields,
    apply the env part declaratively, and realize the env.

    Env-field overrides (``crash_prob``, ``traces``, ...) need a
    declarative member env — an ``fedsim.EnvSpec`` — so the override is a
    pure ``dataclasses.replace`` before the population is drawn; leftover
    keys must be protocol-spec fields of a ``spec_overrides`` protocol
    (the staleness-adaptive family), rejected here otherwise."""
    env = mem.env
    ov = dict(mem.overrides or {})
    env_ov = {k: ov.pop(k) for k in list(ov) if k in _ENV_FIELDS}
    if env_ov:
        if not isinstance(env, fedsim.EnvSpec):
            raise ValueError(
                f'member override keys {sorted(env_ov)} are EnvSpec fields; '
                f'env overrides need a declarative member env '
                f'(fedsim.EnvSpec), got {type(env).__name__}')
        env = env.replace(**env_ov)
    if ov and not pdef.spec_overrides:
        raise ValueError(
            f'unknown member override keys {sorted(ov)}; protocol '
            f'{pdef.name!r} takes env-field overrides only '
            f'(EnvSpec fields, e.g. crash_prob/traces/draw_seed)')
    return dataclasses.replace(mem, env=_realize_env(env, task=task, ex=ex),
                               overrides=(ov or None))


def _task_fp(task) -> str:
    """Task identity for checkpoint fingerprints.  Tasks that implement
    ``fingerprint()`` (e.g. ``SupervisedTask``: a hash of the client
    data + hypers) pin the training problem; others fall back to the
    class name, which at least catches swapping task types."""
    if task is None:
        return 'None'
    fp = getattr(task, 'fingerprint', None)
    return fp() if callable(fp) else type(task).__name__


def _fresh_records(records: list) -> list:
    """Per-run copies of a schedule's RoundRecords.  The schedule is
    cached on the Experiment, so Histories from repeated run() calls
    must not alias (and thereby leak evals into) each other's records."""
    return [dataclasses.replace(r, eval=None) for r in records]


def init_fleet_global(task, seeds):
    """Per-member initial globals for a shared-task fleet, stacked [S, ...].

    This codifies the fleet-init contract: ``task.init_global`` is called
    host-side once per *distinct* seed and the results are stacked — it is
    deliberately NOT vmapped over a key batch, because vmapping a
    PRNG-keyed init lowers ``jax.random`` differently than the scalar call
    and is not bit-stable against the single-run path.  Members sharing a
    seed therefore share one init computation, and every member's row is
    bit-identical to its own ``task.init_global(PRNGKey(seed))`` — which is
    what keeps ``engine='fleet'`` == ``engine='sequential'`` == single
    ``run()`` exact.  The relaxed part of the contract is only *where* the
    init runs (host loop, outside the compiled fleet program), never its
    values."""
    init = {}
    for seed in seeds:
        if seed not in init:
            init[seed] = task.init_global(jax.random.PRNGKey(seed))
    return _stack_trees([init[seed] for seed in seeds])


def _stacked_task(tasks):
    """Memoised ``stack_tasks``: repeated ``run_sweep`` calls over the
    same task tuple (e.g. the checkpoint resume flow) reuse one stacked
    task, so the padded data is built once and the bound ``fleet_train``
    stays a stable static jit argument (a fresh one would force a full
    recompile).  Cached on the first task; entries hold the member tasks
    alive, so the id-tuple key cannot be reused while it is live."""
    from repro.data.tasks import stack_tasks
    cache = tasks[0].__dict__.setdefault('_fleet_task_stacks', {})
    key = tuple(map(id, tasks))
    if key not in cache:
        cache[key] = stack_tasks(tasks)
    return cache[key]


# ---------------------------------------------------------------------------
# Built-in protocol defs
# ---------------------------------------------------------------------------

def _safa_precompute(env, sp, *, rounds, seed):
    del seed  # SAFA's event process draws only from the env rng
    return federation.precompute_safa_schedule(
        env, fraction=sp.fraction, lag_tolerance=sp.lag_tolerance,
        rounds=rounds)


def _safa_sparse_precompute(env, sp, *, rounds, seed):
    del seed
    return federation.precompute_safa_schedule(
        env, fraction=sp.fraction, lag_tolerance=sp.lag_tolerance,
        rounds=rounds, form='sparse')


def _safa_tier_precompute(env, sp, *, rounds, seed):
    del seed
    return federation.precompute_safa_schedule(
        env, fraction=sp.fraction, lag_tolerance=sp.lag_tolerance,
        rounds=rounds, form='sparse_tier')


def _pack_layout(global_w, wire):
    from repro.kernels import ops as kops
    return kops.wire_spec(global_w) if wire == 'int8' \
        else kops.pack_spec(global_w)


def _safa_prepare_state(st, weights, ex, fleet: bool, sched=None):
    """Sparse-delta carries: the running aggregate tree, or — under
    ``use_kernel='packed'`` — the whole state as resident pack buffers
    ([m+1, N] with a trailing scratch row for sentinel slots).

    Lag-tier carries (``schedule='sparse_tier'``): the [m, ...] stacks are
    never materialised — the cache slot becomes the O(tau+quota) value
    buffer of ``sched.capacity + 1`` rows (every row starts as the init
    global, matching the dense cache init bit-for-bit), and the running
    aggregate starts at ``global * sum(weights)``."""
    if ex.schedule == 'sparse_tier':
        _safa_prepare_tier_state(st, weights, ex, fleet, sched)
        return
    if ex.schedule != 'sparse_delta':
        return
    from repro.kernels import ops as kops
    if ex.use_kernel != 'packed':
        init = jax.vmap(protocol.init_aggregate) if fleet \
            else protocol.init_aggregate
        st.agg = init(st.cache, weights)
        return
    spec = _pack_layout(
        _tree_member(st.global_w, 0) if fleet else st.global_w, ex.wire)
    agg = (jax.vmap(protocol.init_aggregate) if fleet
           else protocol.init_aggregate)(st.cache, weights)
    pack_g = kops.pack_stacked if fleet else kops.pack_global
    pack_m = kops.pack_fleet if fleet else kops.pack_stacked

    def scratch(b):
        pad = [(0, 0)] * (b.ndim - 2) + [(0, 1), (0, 0)]
        return jnp.pad(b, pad)

    st.packed = (pack_g(st.global_w, spec),
                 scratch(pack_m(st.local_w, spec)),
                 scratch(pack_m(st.cache, spec)),
                 pack_g(agg, spec))
    st.spec = spec
    st.local_w = st.cache = None


def _safa_prepare_tier_state(st, weights, ex, fleet: bool, sched):
    """Build the lag-tier carry from the global alone: value buffer
    (capacity + 1 rows of the init global; trailing row is scratch) and
    the running aggregate ``global * sum(weights)``."""
    from repro.kernels import ops as kops
    cap = int(sched.capacity)
    wsum = jnp.sum(weights, axis=-1) if fleet else jnp.sum(weights)

    def scale(g):
        w = wsum.reshape((-1,) + (1,) * (g.ndim - 1)) if fleet else wsum
        return g.astype(jnp.float32) * w

    def rows(g):
        if fleet:
            return jnp.broadcast_to(g[:, None],
                                    (g.shape[0], cap + 1) + g.shape[1:])
        return jnp.broadcast_to(g[None], (cap + 1,) + g.shape)

    if ex.use_kernel != 'packed':
        st.cache = jax.tree.map(rows, st.global_w)
        st.agg = jax.tree.map(scale, st.global_w)
        return
    spec = _pack_layout(
        _tree_member(st.global_w, 0) if fleet else st.global_w, ex.wire)
    pack_g = kops.pack_stacked if fleet else kops.pack_global
    gbuf = pack_g(st.global_w, spec)
    st.packed = (gbuf, rows(gbuf),
                 pack_g(jax.tree.map(scale, st.global_w), spec))
    st.spec = spec


def _safa_scan_segment(st, seg, weights, train_fn, ex):
    if ex.schedule == 'dense':
        st.global_w, st.local_w, st.cache = protocol.safa_run_scan(
            st.global_w, st.local_w, st.cache, seg, weights,
            local_train_fn=train_fn, use_kernel=ex.use_kernel, wire=ex.wire)
    elif ex.schedule == 'sparse':
        st.global_w, st.local_w, st.cache = protocol.safa_run_scan_sparse(
            st.global_w, st.local_w, st.cache, seg, weights,
            local_train_fn=train_fn, use_kernel=ex.use_kernel, wire=ex.wire)
    elif ex.schedule == 'sparse_tier':
        if st.packed is not None:
            from repro.kernels import ops as kops
            st.packed = protocol.safa_run_scan_sparse_tier_packed(
                *st.packed, seg, weights, local_train_fn=train_fn,
                spec=st.spec, wire=ex.wire)
            st.global_w = kops.unpack_global(st.packed[0], st.spec)
        else:
            st.global_w, st.cache, st.agg = \
                protocol.safa_run_scan_sparse_tier(
                    st.global_w, st.cache, st.agg, seg, weights,
                    local_train_fn=train_fn, wire=ex.wire)
    elif st.packed is not None:
        from repro.kernels import ops as kops
        st.packed = protocol.safa_run_scan_sparse_delta_packed(
            *st.packed, seg, weights, local_train_fn=train_fn,
            spec=st.spec, wire=ex.wire)
        st.global_w = kops.unpack_global(st.packed[0], st.spec)
    else:
        st.global_w, st.local_w, st.cache, st.agg = \
            protocol.safa_run_scan_sparse_delta(
                st.global_w, st.local_w, st.cache, st.agg, seg, weights,
                local_train_fn=train_fn, wire=ex.wire)


def _safa_loop_round(st, sched, i, weights, train_fn, ex):
    if ex.schedule == 'dense':
        st.global_w, st.local_w, st.cache = protocol.safa_round(
            st.global_w, st.local_w, st.cache,
            sync_mask=_to_j(sched.sync[i]),
            completed=_to_j(sched.committed[i]),
            picked=_to_j(sched.picked[i]),
            undrafted=_to_j(sched.undrafted[i]),
            deprecated=_to_j(sched.deprecated[i]), weights=weights,
            local_train_fn=train_fn, train_args=(i + 1,),
            use_kernel=ex.use_kernel, wire=ex.wire)
        return
    idx, roles = _to_j(sched.idx[i]), _to_j(sched.roles[i])
    if ex.schedule == 'sparse':
        st.global_w, st.local_w, st.cache = protocol.safa_round_sparse(
            st.global_w, st.local_w, st.cache, idx=idx, roles=roles,
            weights=weights, local_train_fn=train_fn, train_args=(i + 1,),
            use_kernel=ex.use_kernel, wire=ex.wire)
    elif ex.schedule == 'sparse_tier':
        maps = dict(
            idx=idx, roles=roles, base_src=_to_j(sched.base_src[i]),
            cache_src=_to_j(sched.cache_src[i]),
            cache_dst=_to_j(sched.cache_dst[i]),
            global_dst=jnp.asarray(sched.global_dst[i]))
        if st.packed is not None:
            from repro.kernels import ops as kops
            st.packed = protocol.safa_round_sparse_tier_packed(
                *st.packed, **maps, weights=weights, local_train_fn=train_fn,
                train_args=(i + 1,), spec=st.spec, wire=ex.wire)
            st.global_w = kops.unpack_global(st.packed[0], st.spec)
        else:
            st.global_w, st.cache, st.agg = protocol.safa_round_sparse_tier(
                st.global_w, st.cache, st.agg, **maps, weights=weights,
                local_train_fn=train_fn, train_args=(i + 1,), wire=ex.wire)
    elif st.packed is not None:
        from repro.kernels import ops as kops
        st.packed = protocol.safa_round_sparse_delta_packed(
            *st.packed, idx=idx, roles=roles, weights=weights,
            local_train_fn=train_fn, train_args=(i + 1,), spec=st.spec,
            wire=ex.wire)
        st.global_w = kops.unpack_global(st.packed[0], st.spec)
    else:
        st.global_w, st.local_w, st.cache, st.agg = \
            protocol.safa_round_sparse_delta(
                st.global_w, st.local_w, st.cache, st.agg, idx=idx,
                roles=roles, weights=weights, local_train_fn=train_fn,
                train_args=(i + 1,), wire=ex.wire)


def _safa_fleet_segment(st, seg, weights, train_fn, ex, ctx):
    if ex.schedule == 'dense':
        st.global_w, st.local_w, st.cache = protocol.safa_run_fleet(
            st.global_w, st.local_w, st.cache, seg, weights,
            local_train_fn=train_fn, use_kernel=ex.use_kernel, wire=ex.wire,
            train_ctx=ctx)
    elif ex.schedule == 'sparse':
        st.global_w, st.local_w, st.cache = protocol.safa_run_fleet_sparse(
            st.global_w, st.local_w, st.cache, seg, weights,
            local_train_fn=train_fn, use_kernel=ex.use_kernel, wire=ex.wire)
    elif ex.schedule == 'sparse_tier':
        if st.packed is not None:
            from repro.kernels import ops as kops
            st.packed = protocol.safa_run_fleet_sparse_tier_packed(
                *st.packed, seg, weights, local_train_fn=train_fn,
                spec=st.spec, wire=ex.wire)
            st.global_w = kops.unpack_stacked(st.packed[0], st.spec)
        else:
            st.global_w, st.cache, st.agg = \
                protocol.safa_run_fleet_sparse_tier(
                    st.global_w, st.cache, st.agg, seg, weights,
                    local_train_fn=train_fn, wire=ex.wire)
    elif st.packed is not None:
        from repro.kernels import ops as kops
        st.packed = protocol.safa_run_fleet_sparse_delta_packed(
            *st.packed, seg, weights, local_train_fn=train_fn,
            spec=st.spec, wire=ex.wire)
        st.global_w = kops.unpack_stacked(st.packed[0], st.spec)
    else:
        st.global_w, st.local_w, st.cache, st.agg = \
            protocol.safa_run_fleet_sparse_delta(
                st.global_w, st.local_w, st.cache, st.agg, seg, weights,
                local_train_fn=train_fn, wire=ex.wire)


def _sync_precompute(fedcs, form='dense'):
    def precompute(env, sp, *, rounds, seed):
        return federation.precompute_sync_schedule(
            env, fraction=sp.fraction, rounds=rounds, seed=seed, fedcs=fedcs,
            form=form, sampler=getattr(sp, 'sampler', 'choice'))
    return precompute


def _sync_fleet_precompute(fedcs):
    def precompute(members, sp, *, rounds):
        return federation.precompute_sync_fleet_schedule(
            members, rounds=rounds, fedcs=fedcs,
            sampler=getattr(sp, 'sampler', 'choice'))
    return precompute


def _fedavg_prepare_state(st, weights, ex, fleet: bool, sched=None):
    """The stateless sparse-delta FedAvg/FedCS carry is the global model
    alone — drop the [m, ...] local stack before it is ever committed."""
    del weights, fleet, sched
    if ex.schedule == 'sparse_delta':
        st.local_w = None


def _fedavg_scan_segment(st, seg, weights, train_fn, ex):
    if ex.schedule == 'dense':
        st.global_w, st.local_w = protocol.fedavg_run_scan(
            st.global_w, st.local_w, seg, weights, local_train_fn=train_fn,
            wire=ex.wire)
    elif ex.schedule == 'sparse':
        st.global_w, st.local_w = protocol.fedavg_run_scan_sparse(
            st.global_w, st.local_w, seg, weights, local_train_fn=train_fn,
            wire=ex.wire)
    else:
        st.global_w = protocol.fedavg_run_scan_sparse_delta(
            st.global_w, seg, weights, local_train_fn=train_fn, wire=ex.wire)


def _fedavg_loop_round(st, sched, i, weights, train_fn, ex):
    if ex.schedule == 'dense':
        st.global_w, st.local_w = protocol.fedavg_round(
            st.global_w, st.local_w, selected=_to_j(sched.selected[i]),
            completed=_to_j(sched.completed[i]), weights=weights,
            local_train_fn=train_fn, train_args=(i + 1,), wire=ex.wire)
        return
    idx, roles = _to_j(sched.idx[i]), _to_j(sched.roles[i])
    if ex.schedule == 'sparse':
        st.global_w, st.local_w = protocol.fedavg_round_sparse(
            st.global_w, st.local_w, idx=idx, roles=roles, weights=weights,
            local_train_fn=train_fn, train_args=(i + 1,), wire=ex.wire)
    else:
        st.global_w = protocol.fedavg_round_sparse_delta(
            st.global_w, idx=idx, roles=roles, weights=weights,
            local_train_fn=train_fn, train_args=(i + 1,), wire=ex.wire)


def _fedavg_fleet_segment(st, seg, weights, train_fn, ex, ctx):
    if ex.schedule == 'dense':
        st.global_w, st.local_w = protocol.fedavg_run_fleet(
            st.global_w, st.local_w, seg, weights, local_train_fn=train_fn,
            wire=ex.wire, train_ctx=ctx)
    elif ex.schedule == 'sparse':
        st.global_w, st.local_w = protocol.fedavg_run_fleet_sparse(
            st.global_w, st.local_w, seg, weights, local_train_fn=train_fn,
            wire=ex.wire)
    else:
        st.global_w = protocol.fedavg_run_fleet_sparse_delta(
            st.global_w, seg, weights, local_train_fn=train_fn, wire=ex.wire)


def _local_precompute(env, sp, *, rounds, seed):
    return federation.precompute_local_schedule(
        env, fraction=sp.fraction, rounds=rounds, seed=seed)


def _local_fleet_precompute(members, sp, *, rounds):
    del sp
    return schedules.LocalFleetSchedule.stack([
        federation.precompute_local_schedule(
            mem.env, fraction=mem.fraction, rounds=rounds, seed=mem.seed)
        for mem in members])


def _local_scan_segment(st, seg, weights, train_fn, ex):
    del weights, ex
    st.local_w = protocol.local_run_scan(st.local_w, seg,
                                         local_train_fn=train_fn)


def _local_loop_round(st, sched, i, weights, train_fn, ex):
    del weights, ex
    st.local_w = protocol.local_only_round(
        st.local_w, completed=_to_j(sched.completed[i]),
        local_train_fn=train_fn, train_args=(i + 1,))


def _local_fleet_segment(st, seg, weights, train_fn, ex, ctx):
    del weights, ex
    st.local_w = protocol.local_run_fleet(st.local_w, seg,
                                          local_train_fn=train_fn,
                                          train_ctx=ctx)


def _local_finish_segment(st, weights, fleet: bool):
    """There is no global model between rounds — aggregate at eval stops
    (and leave the result in the state so final_global is uniform)."""
    if fleet:
        st.global_w = jax.vmap(protocol.aggregate)(st.local_w, weights)
    else:
        st.global_w = protocol.aggregate(st.local_w, weights)


def _fedasync_precompute(env, sp, *, rounds, seed):
    del seed  # FedAsync's event process draws only from the env rng
    from repro.core import agg_schemes
    return agg_schemes.precompute_async_schedule(
        env, rounds=rounds, **agg_schemes.async_kwargs(sp))


def _fedasync_fleet_precompute(members, sp, *, rounds):
    from repro.core import agg_schemes
    return schedules.AsyncFleetSchedule.stack([
        agg_schemes.precompute_async_schedule(
            mem.env, rounds=rounds, **agg_schemes.async_kwargs(sp, mem))
        for mem in members])


def _fedasync_scan_segment(st, seg, weights, train_fn, ex):
    del weights, ex
    st.global_w, st.local_w = protocol.fedasync_run_scan(
        st.global_w, st.local_w, seg, local_train_fn=train_fn)


def _fedasync_loop_round(st, sched, i, weights, train_fn, ex):
    del weights, ex
    st.global_w, st.local_w = protocol.fedasync_round(
        st.global_w, st.local_w, committed=_to_j(sched.committed[i]),
        order=jnp.asarray(sched.order[i]),
        alphas=jnp.asarray(sched.alphas[i], jnp.float32),
        local_train_fn=train_fn, train_args=(i + 1,))


def _fedasync_fleet_segment(st, seg, weights, train_fn, ex, ctx):
    del weights, ex
    st.global_w, st.local_w = protocol.fedasync_run_fleet(
        st.global_w, st.local_w, seg, local_train_fn=train_fn,
        train_ctx=ctx)


def _safa_dispatch_budget(ex) -> Optional[int]:
    """Pallas dispatches per compiled SAFA round (verified statically by
    ``repro.analysis`` JAX001 against the lowered scan body).  The dense/
    sparse int8 cells are the PR 4 invariant: a fully compressed round is
    exactly 2 dispatches (quantize + fused q8 aggregate) however many
    leaves the model has."""
    if ex.schedule == 'sparse_tier':
        if not ex.use_kernel:
            return 2 if ex.wire == 'int8' else 0
        # gather bases + fused tier aggregate (+ quantize on the wire)
        return 3 if ex.wire == 'int8' else 2
    if ex.schedule == 'sparse_delta':
        if not ex.use_kernel:
            return 2 if ex.wire == 'int8' else 0
        # gather + rows aggregate + scatter x2 (local rows, cache rows)
        return 5 if ex.wire == 'int8' else 4
    if ex.wire == 'int8':
        return 2
    if ex.use_kernel == 'packed':
        return 1
    if ex.use_kernel:
        return None     # leaf-wise: one dispatch per pytree leaf
    return 0


def _safa_alias_claims(ex) -> dict:
    """In-place aliases the cell's lowered program must carry (JAX003):
    dropping any of these silently doubles the server's resident cache/
    buffer footprint."""
    if ex.schedule == 'sparse_tier':
        if not ex.use_kernel:
            return {}
        return ({'_q8_tier_rows_kernel': ((5, 2),)} if ex.wire == 'int8'
                else {'_tier_rows_kernel': ((2, 2),)})
    if ex.schedule == 'sparse_delta':
        if not ex.use_kernel:
            return {}
        return {'_scatter_kernel': ((2, 0),)}
    if ex.wire == 'int8':
        return {'_q8_kernel': ((3, 1),)}
    if ex.use_kernel == 'packed':
        return {'_kernel': ((0, 1),)}
    return {}


def _wire_only_dispatch_budget(ex) -> int:
    """Kernel-less protocols touch pallas only through the int8 wire
    round-trip (quantize + dequantize)."""
    return 2 if ex.wire == 'int8' else 0


register(ProtocolDef(
    name='safa', spec_cls=SafaSpec,
    precompute=_safa_precompute,
    fleet_precompute=lambda members, sp, *, rounds:
        federation.precompute_fleet_schedule(members, rounds=rounds),
    scan_segment=_safa_scan_segment, loop_round=_safa_loop_round,
    fleet_segment=_safa_fleet_segment,
    uses_cache=True, supports_wire=True, supports_kernel=True,
    sparse_precompute=_safa_sparse_precompute,
    prepare_state=_safa_prepare_state,
    tier_precompute=_safa_tier_precompute,
    dispatch_budget=_safa_dispatch_budget,
    alias_claims=_safa_alias_claims))

register(ProtocolDef(
    name='fedavg', spec_cls=FedAvgSpec,
    precompute=_sync_precompute(fedcs=False),
    fleet_precompute=_sync_fleet_precompute(fedcs=False),
    scan_segment=_fedavg_scan_segment, loop_round=_fedavg_loop_round,
    fleet_segment=_fedavg_fleet_segment, supports_wire=True,
    sparse_precompute=_sync_precompute(fedcs=False, form='sparse'),
    prepare_state=_fedavg_prepare_state, delta_stateless=True,
    dispatch_budget=_wire_only_dispatch_budget))

register(ProtocolDef(
    name='fedcs', spec_cls=FedCSSpec,
    precompute=_sync_precompute(fedcs=True),
    fleet_precompute=_sync_fleet_precompute(fedcs=True),
    scan_segment=_fedavg_scan_segment, loop_round=_fedavg_loop_round,
    fleet_segment=_fedavg_fleet_segment, supports_wire=True,
    sparse_precompute=_sync_precompute(fedcs=True, form='sparse'),
    prepare_state=_fedavg_prepare_state, delta_stateless=True,
    dispatch_budget=_wire_only_dispatch_budget))

register(ProtocolDef(
    name='local', spec_cls=LocalSpec,
    precompute=_local_precompute,
    fleet_precompute=_local_fleet_precompute,
    scan_segment=_local_scan_segment, loop_round=_local_loop_round,
    fleet_segment=_local_fleet_segment,
    finish_segment=_local_finish_segment,
    dispatch_budget=lambda ex: 0))

register(ProtocolDef(
    name='fedasync', spec_cls=FedAsyncSpec,
    precompute=_fedasync_precompute,
    fleet_precompute=_fedasync_fleet_precompute,
    scan_segment=_fedasync_scan_segment, loop_round=_fedasync_loop_round,
    fleet_segment=_fedasync_fleet_segment, spec_overrides=True,
    dispatch_budget=lambda ex: 0))


# ---------------------------------------------------------------------------
# Experiment + CompiledRunner
# ---------------------------------------------------------------------------

class Experiment:
    """One declarative experiment: (task, env, protocol spec, exec spec,
    rounds, seed).  ``task`` may be None for timing-only runs
    (``ExecSpec(numeric=False)``).

    ``env`` is declarative too: pass an ``fedsim.EnvSpec`` and the
    experiment builds it (validated in ``check_compat``; wire-derived
    comm sizes injected under ``comm='wire'``).  A pre-built ``Env`` (or
    the deprecated ``FLEnv``) is accepted unchanged."""

    def __init__(self, task, env, protocol: ProtocolSpec,
                 exec: Optional[ExecSpec] = None, *,  # noqa: A002
                 rounds: int, seed: int = 0):
        self.task = task
        self.protocol = protocol
        self.exec = exec if exec is not None else ExecSpec()
        self.rounds = int(rounds)
        self.seed = int(seed)
        self._pdef = check_compat(self.protocol, self.exec, env=env)
        self.env = _realize_env(env, task=task, ex=self.exec)
        self._sched = None

    def precompute(self):
        """Run the host event state machine (versions, crash draws,
        selection) once and cache the schedule — [rounds, m] masks for
        ``schedule='dense'``, native [rounds, quota] (idx, roles) tensors
        otherwise (same event stream, O(m + rounds*quota) host memory).
        The env rng is consumed exactly once per Experiment — repeated
        calls (and repeated ``run()``s) replay the same schedule."""
        if self._sched is None:
            if self.exec.schedule == 'dense':
                pre = self._pdef.precompute
            elif self.exec.schedule == 'sparse_tier':
                pre = self._pdef.tier_precompute
            else:
                pre = self._pdef.sparse_precompute
            self._sched = pre(
                self.env, self.protocol, rounds=self.rounds, seed=self.seed)
        return self._sched

    def compile(self) -> 'CompiledRunner':
        """Resolve the engine and pin the static pieces of the compiled
        program (train fn, kernel/wire modes).  The XLA trace itself is
        built at the first ``run()`` dispatch and cached by jit."""
        return CompiledRunner(self)

    def fingerprint(self, members=None, tasks=None, task=None) -> str:
        """Identity of the run a checkpoint belongs to: protocol/exec
        specs, rounds, seed, env(s) — and the task(s), so a carry is
        never resumed against different training data."""
        parts = [
            f'proto={self._pdef.name}',
            f'spec={dataclasses.asdict(self.protocol)!r}',
            f'exec={dataclasses.asdict(self.exec)!r}',
            f'rounds={self.rounds}', f'seed={self.seed}',
        ]
        if members is None:
            parts.append('env=' + _env_fp(self.env))
            parts.append('task=' + _task_fp(self.task))
        else:
            parts += ['member=' + _env_fp(mem.env) + repr(
                (mem.fraction, mem.lag_tolerance, mem.seed, mem.alpha,
                 mem.staleness_exp, mem.overrides)) for mem in members]
            if tasks is not None:
                parts += ['task=' + _task_fp(t) for t in tasks]
            else:
                parts.append('task=' + _task_fp(task))
        return '|'.join(parts)


class CompiledRunner:
    """Executes an ``Experiment``.  ``run()`` drives the single
    simulation; ``run_sweep(members)`` drives S member configurations as
    one batched fleet.  Both checkpoint at eval-segment boundaries when
    ``checkpoint=`` names a path, and resume from it when it exists."""

    def __init__(self, exp: Experiment):
        self.exp = exp
        self._pdef = exp._pdef
        self._dev = None            # cached device-resident schedule

    # -- single run ---------------------------------------------------------

    def _engine(self, *, sweep: bool) -> str:
        e = self.exp.exec.engine
        if sweep:
            e = e if e is not None else 'fleet'
            if e not in ('fleet', 'sequential'):
                raise ValueError(
                    f'unknown engine {e!r} (want "fleet" or "sequential")')
        else:
            e = e if e is not None else 'scan'
            if e not in ('scan', 'loop'):
                raise ValueError(
                    f'unknown engine {e!r} (want "scan" or "loop")')
        return e

    def _stateless(self, ex) -> bool:
        """Global-only carry: skip the [m, ...] local/cache stacks.

        Lag-tier runs are always stateless here — ``prepare_state`` then
        builds the O(tau+quota) value buffer in the cache slot."""
        return (ex.schedule == 'sparse_delta' and self._pdef.delta_stateless) \
            or ex.schedule == 'sparse_tier'

    def _train_fn(self, task):
        if self.exp.exec.schedule != 'dense':
            # rows-train contract: (params_rows, rows, round_idx)
            return task.local_train_rows
        if getattr(self.exp.protocol, 'quantize_uploads', False):
            return federation._quantized_train_fn(task.local_train)
        return task.local_train

    def run(self, *, checkpoint: Optional[str] = None,
            max_segments: Optional[int] = None) -> History:
        """Execute the experiment.  ``checkpoint`` (a path) enables
        save/resume at eval-segment boundaries; ``max_segments`` stops
        after that many segments *this call* (the partial History carries
        the state reached so far — resume via ``checkpoint``)."""
        exp = self.exp
        ex = exp.exec
        engine = self._engine(sweep=False)
        sched = exp.precompute()
        hist = History(self._pdef.name, records=_fresh_records(sched.records),
                       futility=sched.futility)
        if not ex.numeric:
            return hist
        if exp.task is None:
            raise ValueError('numeric run needs a Task '
                             '(or ExecSpec(numeric=False))')

        st = _init_state(exp.task, exp.env.m, exp.seed, self._pdef.uses_cache,
                         self._stateless(ex))
        weights_j = jnp.asarray(exp.env.weights)
        if self._pdef.prepare_state is not None:
            self._pdef.prepare_state(st, weights_j, ex, False, sched)
        start_seg = 0
        fingerprint = exp.fingerprint()
        if checkpoint is not None and ckpt.exists(checkpoint):
            tree, start_seg, saved = ckpt.load_run(
                checkpoint, st.tree(), fingerprint=fingerprint)
            st.set_tree(tree)
            _apply_saved_history(hist, saved[0])

        weights = weights_j
        train_fn = self._train_fn(exp.task)
        evals = _eval_rounds(exp.rounds, ex.eval_every)
        if engine == 'scan' and self._dev is None:
            self._dev = sched.to_device()
        start = evals[start_seg - 1] if start_seg else 0
        done = 0
        for k in range(start_seg, len(evals)):
            stop = evals[k]
            if engine == 'scan':
                seg = jax.tree.map(
                    lambda a, s=start, e=stop: a[s:e], self._dev)
                self._pdef.scan_segment(st, seg, weights, train_fn, ex)
            else:
                for t in range(start + 1, stop + 1):
                    self._pdef.loop_round(st, sched, t - 1, weights,
                                          train_fn, ex)
            if self._pdef.finish_segment is not None:
                self._pdef.finish_segment(st, weights, False)
            _record_eval(hist, hist.records[stop - 1], exp.task, st.global_w)
            start = stop
            done += 1
            if checkpoint is not None:
                ckpt.save_run(checkpoint, st.tree(), seg_done=k + 1,
                              histories=[hist], fingerprint=fingerprint)
            if max_segments is not None and done >= max_segments \
                    and k + 1 < len(evals):
                break
        hist.final_global = st.global_w
        return hist

    # -- sweeps -------------------------------------------------------------

    def run_sweep(self, members, *, checkpoint: Optional[str] = None,
                  max_segments: Optional[int] = None) -> list:
        """Run S = len(members) simulations of this protocol as a batched
        fleet; returns one ``History`` per member, in order.

        ``members`` is a list of ``SweepMember`` or a ``SweepSpec``; a
        ``SweepSpec`` may carry per-member ``tasks`` (padded stacking —
        members may then hold different client partitions).  The
        experiment's own env/seed are not used here; each member carries
        its own.  ``engine='fleet'`` (default) executes all members in a
        single vmapped-scan dispatch per eval segment (sharded over JAX
        devices when several are visible and S divides evenly);
        ``engine='sequential'`` drives the same precomputed schedules
        through S per-member scan runs — bit-identical per member."""
        exp = self.exp
        ex = exp.exec
        engine = self._engine(sweep=True)
        if isinstance(members, SweepSpec):
            sweep, members = members, list(members.members)
            tasks = list(sweep.tasks) if sweep.tasks is not None else None
        else:
            members, tasks = list(members), None
        if not members:
            raise ValueError('empty sweep')
        # resolve declarative member envs up front: split env-field
        # overrides from protocol overrides, apply them to the EnvSpec,
        # and build each member its own Env (one fleet dispatch may then
        # mix crash rates, traces, device-class grids, ...)
        members = [
            _resolve_member(mem, pdef=self._pdef, ex=ex,
                            task=tasks[s] if tasks is not None else exp.task)
            for s, mem in enumerate(members)]
        m = members[0].env.m
        if any(mem.env.m != m for mem in members):
            raise ValueError('fleet members must share the client count m')
        if tasks is not None and all(t is tasks[0] for t in tasks):
            # one shared task object: take the cheaper no-padding path
            shared_task, tasks = tasks[0], None
        else:
            shared_task = exp.task
        if getattr(exp.protocol, 'quantize_uploads', False):
            raise ValueError(
                'quantize_uploads is the single-run per-leaf reference '
                "knob; sweeps take the packed wire instead (wire='int8')")
        if ex.schedule != 'dense' and tasks is not None:
            raise ValueError(
                'sparse schedules need the rows-train contract, which the '
                'padded per-member task stack does not implement; use a '
                'shared task (or schedule="dense")')

        fleet = self._pdef.fleet_precompute(members, exp.protocol,
                                            rounds=exp.rounds)
        if ex.schedule == 'sparse_tier':
            # fleet-major lag-tier form of the SAME event stream: member
            # slot maps are remapped into the shared fleet-max capacity
            fleet = fleet.to_tier()
        elif ex.schedule != 'dense':
            # fleet-major sparse form of the SAME event stream (members
            # re-padded to the fleet-max active-set capacity)
            fleet = fleet.to_sparse()
        hists = [History(self._pdef.name,
                         records=_fresh_records(fleet.records[s]),
                         futility=float(fleet.futility[s]))
                 for s in range(fleet.size)]
        if not ex.numeric:
            return hists
        if shared_task is None and tasks is None:
            raise ValueError('numeric sweep needs a Task (shared or '
                             'per-member) or ExecSpec(numeric=False)')
        if checkpoint is not None and engine != 'fleet':
            raise ValueError("sweep checkpointing requires engine='fleet'")

        weights = jnp.asarray(np.stack([mem.env.weights for mem in members]))
        evals = _eval_rounds(exp.rounds, ex.eval_every)

        if engine == 'sequential':
            for s, (mem, hist) in enumerate(zip(members, hists)):
                task_s = tasks[s] if tasks is not None else shared_task
                st = _init_state(task_s, m, mem.seed, self._pdef.uses_cache,
                                 self._stateless(ex))
                msched = fleet.member(s)
                dev = msched.to_device()
                w_s = jnp.asarray(mem.env.weights)
                train_fn = task_s.local_train if ex.schedule == 'dense' \
                    else task_s.local_train_rows
                if self._pdef.prepare_state is not None:
                    self._pdef.prepare_state(st, w_s, ex, False, msched)
                start = 0
                for stop in evals:
                    seg = jax.tree.map(
                        lambda a, s=start, e=stop: a[s:e], dev)
                    self._pdef.scan_segment(st, seg, w_s, train_fn, ex)
                    if self._pdef.finish_segment is not None:
                        self._pdef.finish_segment(st, w_s, False)
                    _record_eval(hist, hist.records[stop - 1], task_s,
                                 st.global_w)
                    start = stop
                hist.final_global = st.global_w
            return hists

        # fleet engine: one init per member (deduped per distinct seed for
        # a shared task — vmapping init_global is NOT bit-stable), then one
        # broadcast into the fleet-major carry
        if tasks is not None:
            stacked = _stacked_task(tasks)
            ctx = stacked.fleet_ctx()
            train_fn = stacked.fleet_train
            g = _stack_trees([tasks[s].init_global(jax.random.PRNGKey(mem.seed))
                              for s, mem in enumerate(members)])
        else:
            ctx = None
            train_fn = self._train_fn(shared_task)
            g = init_fleet_global(shared_task, [mem.seed for mem in members])

        def bcast():
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[:, None],
                                           (a.shape[0], m) + a.shape[1:]), g)

        if self._stateless(ex):
            st = _RunState(g, None, None)
        else:
            st = _RunState(g, bcast(),
                           bcast() if self._pdef.uses_cache else None)
        if self._pdef.prepare_state is not None:
            self._pdef.prepare_state(st, weights, ex, True, fleet)
        start_seg = 0
        fingerprint = exp.fingerprint(members, tasks=tasks, task=shared_task)
        if checkpoint is not None and ckpt.exists(checkpoint):
            tree, start_seg, saved = ckpt.load_run(
                checkpoint, st.tree(), fingerprint=fingerprint)
            st.set_tree(tree)
            for hist, d in zip(hists, saved):
                _apply_saved_history(hist, d)

        dev = fleet.to_device()
        ndev = len(jax.devices())
        if ex.shard and ndev > 1 and len(members) % ndev == 0:
            from jax.sharding import Mesh, NamedSharding, PartitionSpec
            mesh = Mesh(np.asarray(jax.devices()), ('fleet',))
            sharding = NamedSharding(mesh, PartitionSpec('fleet'))
            tree, dev, weights, ctx = jax.device_put(
                (st.tree(), dev, weights, ctx), sharding)
            st.set_tree(tree)

        start = evals[start_seg - 1] if start_seg else 0
        done = 0
        g_host = jax.tree.map(np.asarray, st.global_w)
        for k in range(start_seg, len(evals)):
            stop = evals[k]
            seg = jax.tree.map(
                lambda a, s=start, e=stop: a[:, s:e], dev)
            self._pdef.fleet_segment(st, seg, weights, train_fn, ex, ctx)
            if self._pdef.finish_segment is not None:
                self._pdef.finish_segment(st, weights, True)
            # one host gather per leaf: slicing members out of a (possibly
            # device-sharded) fleet array S times is far slower than one
            # fetch + S host slices
            g_host = jax.tree.map(np.asarray, st.global_w)
            for s, hist in enumerate(hists):
                task_s = tasks[s] if tasks is not None else shared_task
                _record_eval(hist, hist.records[stop - 1], task_s,
                             _tree_member(g_host, s))
            start = stop
            done += 1
            if checkpoint is not None:
                ckpt.save_run(checkpoint, st.tree(), seg_done=k + 1,
                              histories=hists, fingerprint=fingerprint)
            if max_segments is not None and done >= max_segments \
                    and k + 1 < len(evals):
                break
        for s, hist in enumerate(hists):
            hist.final_global = _tree_member(g_host, s)
        return hists
