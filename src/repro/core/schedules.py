"""Schedule and result containers for the federation layer.

Everything here is a *data* type: per-round records, run histories, and
the precomputed mask schedules — single-run ``[rounds, m]`` and
fleet-major ``[S, rounds, m]`` — that the execution engines replay.  The
state machines that *produce* these schedules live in
``repro.core.federation``; the compiled engines that consume them live in
``repro.core.protocol``; the public entry point that wires the two
together is ``repro.core.api``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import protocol


@dataclasses.dataclass
class RoundRecord:
    round: int
    round_len: float
    t_dist: float
    eur: float
    sr: float
    vv: float
    n_picked: int
    n_committed: int
    n_crashed: int
    eval: Optional[dict] = None


@dataclasses.dataclass
class History:
    protocol: str
    records: list = dataclasses.field(default_factory=list)
    futility: float = 0.0
    best_eval: Optional[dict] = None
    final_global: Any = None

    def mean(self, field: str) -> float:
        return float(np.mean([getattr(r, field) for r in self.records]))

    def evals(self):
        return [(r.round, r.eval) for r in self.records if r.eval is not None]

    def to_dict(self) -> dict:
        """JSON-serialisable form (checkpoint metadata).  ``final_global``
        is a device pytree and is deliberately excluded — checkpoints
        persist the model state separately (``repro.checkpoint``)."""
        return {
            'protocol': self.protocol,
            'futility': float(self.futility),
            'best_eval': self.best_eval,
            'records': [dataclasses.asdict(r) for r in self.records],
        }

    @classmethod
    def from_dict(cls, d: dict) -> 'History':
        return cls(protocol=d['protocol'],
                   records=[RoundRecord(**r) for r in d['records']],
                   futility=d['futility'], best_eval=d['best_eval'])


@dataclasses.dataclass
class SweepMember:
    """One simulation in a fleet sweep: its own environment + protocol
    hyper-parameters.  All members of a sweep share the client count
    ``m``; they share the Task too unless the sweep carries per-member
    Tasks (``api.SweepSpec(tasks=...)``, padded stacking)."""
    env: Any                    # fedsim.FLEnv
    fraction: float = 0.5       # ignored by fedasync (fully asynchronous)
    lag_tolerance: int = 5      # SAFA only
    seed: int = 0               # numeric-init (and sync/local-selection) seed
    alpha: float = 0.6          # FedAsync only: base mixing weight
    staleness_exp: float = 0.5  # FedAsync only: staleness polynomial


@dataclasses.dataclass
class SafaSchedule:
    """Precomputed SAFA event process: [rounds, m] bool mask schedules plus
    the timing records they imply.  Independent of model weights."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.sync.shape[0]

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole run."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


@dataclasses.dataclass
class SyncSchedule:
    """Precomputed FedAvg/FedCS event process ([rounds, m] masks + records).
    ``completed`` is the per-round survivor mask (``~crashed``); the numeric
    round intersects it with ``selected`` itself."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.selected.shape[0]

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


@dataclasses.dataclass
class LocalSchedule:
    """Precomputed fully-local event process ([rounds, m] survivor mask +
    records).  ``completed`` is selected & survived — the only mask the
    numeric round needs (there is no aggregation until eval points)."""
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.completed.shape[0]

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


@dataclasses.dataclass
class FedasyncSchedule:
    """Precomputed FedAsync event process: [rounds, m] commit masks plus
    the arrival-ordered merge permutations and staleness-scaled mixing
    weights the sequential server applies each round.  Model weights never
    enter — merge order is pure arrival timing and the alphas depend only
    on staleness — so the whole sequential-merge schedule is known up
    front."""
    committed: np.ndarray       # [rounds, m] bool
    order: np.ndarray           # [rounds, m] int — arrival merge order
    alphas: np.ndarray          # [rounds, m] float — 0 for non-commits
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.committed.shape[0]

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Fleet-major stacking: [S, rounds, m] schedules for batched sweeps
# ---------------------------------------------------------------------------

class _FleetStack:
    """Shared fleet-major stacking machinery.  Subclasses set ``MASKS``
    (the [S, rounds, m] field names, first one authoritative for shapes)
    and ``_MEMBER_CLS`` (the single-run schedule type)."""
    MASKS: tuple = ()
    _MEMBER_CLS = None

    @property
    def size(self) -> int:
        return getattr(self, self.MASKS[0]).shape[0]

    @property
    def rounds(self) -> int:
        return getattr(self, self.MASKS[0]).shape[1]

    @classmethod
    def stack(cls, members: list):
        """Stack S single-run schedules (all with the same rounds and m)."""
        if len({getattr(s, cls.MASKS[0]).shape for s in members}) != 1:
            raise ValueError('fleet members must share (rounds, m)')
        return cls(**{k: np.stack([getattr(s, k) for s in members])
                      for k in cls.MASKS},
                   records=[s.records for s in members],
                   futility=np.array([s.futility for s in members]))

    def member(self, s: int):
        """Member s's schedule, identical to its own precompute."""
        return self._MEMBER_CLS(
            **{k: getattr(self, k)[s] for k in self.MASKS},
            records=self.records[s], futility=float(self.futility[s]))

    def _round_idx(self):
        """[S, rounds] per-member round indices for to_device()."""
        return jnp.asarray(np.broadcast_to(
            np.arange(1, self.rounds + 1, dtype=np.int32),
            (self.size, self.rounds)))


@dataclasses.dataclass
class FleetSchedule(_FleetStack):
    """S independent SAFA event processes stacked fleet-major.

    Mask tensors are [S, rounds, m]; ``records[s]`` / ``futility[s]`` hold
    member s's timing records and futility ratio, exactly as
    ``precompute_safa_schedule`` produced them."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('sync', 'committed', 'picked', 'undrafted', 'deprecated')
    _MEMBER_CLS = SafaSchedule

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole fleet ([S, rounds, m] masks,
        [S, rounds] round indices)."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=self._round_idx())


@dataclasses.dataclass
class SyncFleetSchedule(_FleetStack):
    """FedAvg/FedCS counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('selected', 'completed')
    _MEMBER_CLS = SyncSchedule

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())


@dataclasses.dataclass
class LocalFleetSchedule(_FleetStack):
    """Fully-local counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('completed',)
    _MEMBER_CLS = LocalSchedule

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())


@dataclasses.dataclass
class AsyncFleetSchedule(_FleetStack):
    """FedAsync counterpart of ``FleetSchedule``: [S, rounds, m] commit
    masks plus the merge-order/alpha tensors driving each member's
    arrival-ordered sequential mixes."""
    committed: np.ndarray
    order: np.ndarray
    alphas: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('committed', 'order', 'alphas')
    _MEMBER_CLS = FedasyncSchedule

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=self._round_idx())
