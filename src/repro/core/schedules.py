"""Schedule and result containers for the federation layer.

Everything here is a *data* type: per-round records, run histories, and
the precomputed mask schedules — single-run ``[rounds, m]`` and
fleet-major ``[S, rounds, m]`` — that the execution engines replay.  The
state machines that *produce* these schedules live in
``repro.core.federation``; the compiled engines that consume them live in
``repro.core.protocol``; the public entry point that wires the two
together is ``repro.core.api``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import protocol


@dataclasses.dataclass
class RoundRecord:
    round: int
    round_len: float
    t_dist: float
    eur: float
    sr: float
    vv: float
    n_picked: int
    n_committed: int
    n_crashed: int
    eval: Optional[dict] = None


@dataclasses.dataclass
class History:
    protocol: str
    records: list = dataclasses.field(default_factory=list)
    futility: float = 0.0
    best_eval: Optional[dict] = None
    final_global: Any = None

    def mean(self, field: str) -> float:
        return float(np.mean([getattr(r, field) for r in self.records]))

    def evals(self):
        return [(r.round, r.eval) for r in self.records if r.eval is not None]

    def to_dict(self) -> dict:
        """JSON-serialisable form (checkpoint metadata).  ``final_global``
        is a device pytree and is deliberately excluded — checkpoints
        persist the model state separately (``repro.checkpoint``)."""
        return {
            'protocol': self.protocol,
            'futility': float(self.futility),
            'best_eval': self.best_eval,
            'records': [dataclasses.asdict(r) for r in self.records],
        }

    @classmethod
    def from_dict(cls, d: dict) -> 'History':
        return cls(protocol=d['protocol'],
                   records=[RoundRecord(**r) for r in d['records']],
                   futility=d['futility'], best_eval=d['best_eval'])


@dataclasses.dataclass
class SweepMember:
    """One simulation in a fleet sweep: its own environment + protocol
    hyper-parameters.  All members of a sweep share the client count
    ``m``; they share the Task too unless the sweep carries per-member
    Tasks (``api.SweepSpec(tasks=...)``, padded stacking)."""
    #: the member's environment: an ``fedsim.EnvSpec`` (declarative —
    #: the sweep builds each member a fresh env, and ``overrides`` may
    #: then rewrite env fields) or a pre-built ``Env``/``FLEnv``.
    env: Any
    fraction: float = 0.5       # ignored by fedasync (fully asynchronous)
    lag_tolerance: int = 5      # SAFA only
    seed: int = 0               # numeric-init (and sync/local-selection) seed
    alpha: float = 0.6          # fedasync/seafl/csafl: base mixing weight
    staleness_exp: float = 0.5  # fedasync/seafl/csafl: poly discount exponent
    #: per-member field overrides, split by key at sweep resolution:
    #: ``EnvSpec`` field names (``crash_prob``, ``traces``, ``draw_seed``,
    #: device-class mixes via a new ``traces`` value, ...) rewrite the
    #: member's declarative env — one fleet dispatch then mixes scenarios —
    #: while the rest must be protocol-spec fields of a protocol that
    #: takes them (the staleness-adaptive family: ``staleness_fn``,
    #: ``hinge_a``/``hinge_b``, ``use_loss``/``loss_coef``, ``clusters``,
    #: and — weighted family only — ``scheme``).  ``None`` == no overrides;
    #: unknown keys are rejected before any device work.
    overrides: Optional[dict] = None


@dataclasses.dataclass
class SafaSchedule:
    """Precomputed SAFA event process: [rounds, m] bool mask schedules plus
    the timing records they imply.  Independent of model weights."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.sync.shape[0]

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole run."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))

    def to_sparse(self, capacity: Optional[int] = None) -> 'SparseSchedule':
        """Compact [rounds, K] form of the same event stream (see the
        sparse-schedule section below)."""
        m = self.sync.shape[1]
        rows = [safa_sparse_row(self.sync[t], self.committed[t],
                                self.picked[t], self.undrafted[t],
                                self.deprecated[t], bootstrap=(t == 0))
                for t in range(self.rounds)]
        idx, roles = pack_sparse_rows(rows, m, capacity)
        return SparseSchedule(m=m, idx=idx, roles=roles,
                              records=self.records, futility=self.futility)

    def to_tier(self, capacity: Optional[int] = None) -> 'TierSchedule':
        """Lag-tier compressed form: replay the version counters the SAFA
        state machine maintained (``v[sync] = gv`` before selection,
        ``v[committed] = t`` after) to recover each active client's base
        version, then hand the per-round event rows to the slot
        allocator.  ``federation.precompute_safa_schedule(form=
        'sparse_tier')`` records the same data inline, so the two paths
        build identical schedules."""
        m = self.sync.shape[1]
        v = np.zeros(m, np.int64)
        rows, base_rows = [], []
        for t in range(self.rounds):
            v[self.sync[t]] = t
            row = safa_sparse_row(self.sync[t], self.committed[t],
                                  self.picked[t], self.undrafted[t],
                                  self.deprecated[t], bootstrap=(t == 0))
            rows.append(row)
            base_rows.append(v[row[0]].copy())
            v[self.committed[t]] = t + 1
        return build_tier_schedule(m, rows, base_rows, self.records,
                                   self.futility, capacity=capacity)


@dataclasses.dataclass
class SyncSchedule:
    """Precomputed FedAvg/FedCS event process ([rounds, m] masks + records).
    ``completed`` is the per-round survivor mask (``~crashed``); the numeric
    round intersects it with ``selected`` itself."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.selected.shape[0]

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))

    def to_sparse(self, capacity: Optional[int] = None) -> 'SparseSyncSchedule':
        """Compact [rounds, K] form of the same event stream."""
        m = self.selected.shape[1]
        rows = [sync_sparse_row(self.selected[t], self.completed[t])
                for t in range(self.rounds)]
        idx, roles = pack_sparse_rows(rows, m, capacity)
        return SparseSyncSchedule(m=m, idx=idx, roles=roles,
                                  records=self.records,
                                  futility=self.futility)


@dataclasses.dataclass
class LocalSchedule:
    """Precomputed fully-local event process ([rounds, m] survivor mask +
    records).  ``completed`` is selected & survived — the only mask the
    numeric round needs (there is no aggregation until eval points)."""
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.completed.shape[0]

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


@dataclasses.dataclass
class FedasyncSchedule:
    """Precomputed FedAsync event process: [rounds, m] commit masks plus
    the arrival-ordered merge permutations and staleness-scaled mixing
    weights the sequential server applies each round.  Model weights never
    enter — merge order is pure arrival timing and the alphas depend only
    on staleness — so the whole sequential-merge schedule is known up
    front."""
    committed: np.ndarray       # [rounds, m] bool
    order: np.ndarray           # [rounds, m] int — arrival merge order
    alphas: np.ndarray          # [rounds, m] float — 0 for non-commits
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.committed.shape[0]

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


@dataclasses.dataclass
class WeightedSchedule:
    """Precomputed weighted-merge event process: [rounds, m] commit masks
    plus the per-client effective merge weights the one-shot server merge
    applies each round (``protocol.weighted_round``).

    This is the common lowering of the staleness-adaptive aggregation
    family (SEAFL adaptive weights, CSAFL per-cluster semi-async
    aggregation, folded FedAsync discounts): the scheme lives entirely in
    how ``wrow`` was computed, so every scheme replays through one
    engine.  Rows are zero off the committed set and sum to at most 1."""
    committed: np.ndarray       # [rounds, m] bool
    wrow: np.ndarray            # [rounds, m] float — 0 for non-commits
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.committed.shape[0]

    def to_device(self) -> protocol.WeightedSchedule:
        return protocol.WeightedSchedule(
            committed=jnp.asarray(self.committed),
            wrow=jnp.asarray(self.wrow, jnp.float32),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Sparse (active-set) schedules: [rounds, K] index + role tensors
# ---------------------------------------------------------------------------
#
# A dense schedule stores five [rounds, m] masks; at m = 1e6 that is the
# population, not the event process.  The sparse form stores only the
# per-round *active set* — the clients whose state a round can touch
# (SAFA: sync|committed|deprecated; sync protocols: selected) — as a
# [rounds, K] int32 index tensor padded with the sentinel index m, plus a
# [rounds, K] uint8 role bitmask per slot (protocol.ROLE_*/SROLE_*).  The
# dense masks are exactly reconstructible (every mask is a subset of the
# active set), so dense and sparse replay the same event stream.


def safa_sparse_row(sync, committed, picked, undrafted, deprecated, *,
                    bootstrap: bool = False):
    """One round's compact (idx, roles) from its dense [m] bool masks.

    ``bootstrap=True`` marks round 1, where every client trivially holds
    the current version and the dense sync mask covers the whole
    population.  A sync-only client's transition there — ``local :=
    global`` — is the identity, because every engine initialises
    ``local_w = cache = broadcast(global)``; those clients are elided so
    the active set stays quota-bounded instead of O(m) for one row.
    Clients holding any other role keep their sync bit."""
    role = (sync * protocol.ROLE_SYNC
            + committed * protocol.ROLE_COMMITTED
            + picked * protocol.ROLE_PICKED
            + undrafted * protocol.ROLE_UNDRAFTED
            + deprecated * protocol.ROLE_DEPRECATED).astype(np.uint8)
    if bootstrap:
        role = np.where(role == protocol.ROLE_SYNC, 0, role).astype(np.uint8)
    active = np.flatnonzero(role)
    return active.astype(np.int32), role[active]


def sync_sparse_row(selected, completed):
    """One round's compact (idx, roles) for a synchronous protocol.  The
    active set is the selected set; the survivor bit is stored per slot
    (the dense ``completed`` mask outside the selection never reaches the
    numeric round, which intersects the two)."""
    role = (selected * protocol.SROLE_SELECTED
            + (selected & completed) * protocol.SROLE_COMPLETED
            ).astype(np.uint8)
    active = np.flatnonzero(role)
    return active.astype(np.int32), role[active]


def pack_sparse_rows(rows, m: int, capacity: Optional[int] = None):
    """Pad per-round (idx, roles) pairs to [rounds, capacity] tensors.

    ``capacity`` defaults to the largest active set observed; an explicit
    capacity smaller than some round's active set is a hard error naming
    the round — silent truncation would drop events."""
    need = max([len(i) for i, _ in rows] or [0])
    cap = max(need, 1) if capacity is None else capacity
    idx = np.full((len(rows), cap), m, np.int32)
    roles = np.zeros((len(rows), cap), np.uint8)
    for t, (i, r) in enumerate(rows):
        if len(i) > cap:
            raise ValueError(
                f'sparse schedule capacity {cap} < active-set size '
                f'{len(i)} at round {t}: raise capacity (or the t_lim/'
                f'lag_tolerance knobs bounding the active set)')
        idx[t, :len(i)] = i
        roles[t, :len(i)] = r
    return idx, roles


@dataclasses.dataclass
class SparseSchedule:
    """Compact SAFA event process: [rounds, K] active-set indices + role
    bitmasks (see module section above).  ``records``/``futility`` are the
    same host-side timing stats the dense schedule carries."""
    m: int
    idx: np.ndarray             # [rounds, K] int32, sentinel == m
    roles: np.ndarray           # [rounds, K] uint8 of protocol.ROLE_* bits
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.idx.shape[0]

    @property
    def capacity(self) -> int:
        return self.idx.shape[1]

    @property
    def nbytes(self) -> int:
        return self.idx.nbytes + self.roles.nbytes

    def to_device(self) -> protocol.SparseRoundSchedule:
        return protocol.SparseRoundSchedule(
            idx=jnp.asarray(self.idx), roles=jnp.asarray(self.roles),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))

    def to_dense(self) -> SafaSchedule:
        """Reconstruct the dense [rounds, m] masks — exact, except that
        round 1's sync mask recovers only the active clients: the
        population-wide bootstrap sync is elided at emission time (see
        ``safa_sparse_row``) because it is a state no-op.  Engine results
        are bit-identical either way."""
        bits = {'sync': protocol.ROLE_SYNC,
                'committed': protocol.ROLE_COMMITTED,
                'picked': protocol.ROLE_PICKED,
                'undrafted': protocol.ROLE_UNDRAFTED,
                'deprecated': protocol.ROLE_DEPRECATED}
        masks = {k: np.zeros((self.rounds, self.m), bool) for k in bits}
        for t in range(self.rounds):
            valid = self.idx[t] < self.m
            i, r = self.idx[t][valid], self.roles[t][valid]
            for k, b in bits.items():
                masks[k][t, i] = (r & b) != 0
        return SafaSchedule(records=self.records, futility=self.futility,
                            **masks)


@dataclasses.dataclass
class SparseSyncSchedule:
    """Compact FedAvg/FedCS event process ([rounds, K] indices + SROLE_*
    bitmasks over the selected set)."""
    m: int
    idx: np.ndarray
    roles: np.ndarray
    records: list
    futility: float

    rounds = SparseSchedule.rounds
    capacity = SparseSchedule.capacity
    nbytes = SparseSchedule.nbytes

    def to_device(self) -> protocol.SparseSyncSchedule:
        return protocol.SparseSyncSchedule(
            idx=jnp.asarray(self.idx), roles=jnp.asarray(self.roles),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Lag-tier compressed schedules: version ring + active slab slot maps
# ---------------------------------------------------------------------------
#
# The sparse form above bounds *schedule* memory but SAFA's numeric state
# still carries [m, N] local/cache stacks.  The lag-tolerant distribution
# makes most of that redundant: an inactive client's local row is exactly
# the global snapshot at its version (lag <= tau, so at most tau+2
# distinct snapshots are ever live), and its cache row is either such a
# snapshot or one of the <= quota commit rows from its last active round.
# The tier form therefore replaces both stacks with ONE value buffer of
# ``capacity + 1`` rows (a version ring + active-commit slab, flat in one
# tensor; the trailing row is write-only scratch) plus host-precomputed
# per-round slot maps:
#
#   base_src[t, j]   slot holding slot j's base model (its version's
#                    global snapshot); scratch for synced slots.
#   cache_src[t, j]  slot holding slot j's cache row c0.
#   cache_dst[t, j]  slot that receives slot j's new cache row c2;
#                    scratch when the value is never read again (or when
#                    c2 is a global snapshot already resident in the ring).
#   global_dst[t]    slot that receives the round's output global ("the
#                    ring advances"); scratch once no later round reads it.
#
# Slots are assigned by value lifetime (first-fit free list over exact
# last-read rounds), so ``capacity`` is the peak number of simultaneously
# live distinct rows — O(tau + quota), independent of m.  Clients at the
# same lag share a slot by construction: their base reads name the same
# version value.  Within a round every read slot differs from every
# written slot (values written in round t are first read strictly later),
# which is what lets the fused kernels alias the buffer in place.
#
# Local state needs no buffer at all: a committed client is force-synced
# the next round it appears, so a trained local row is never read back —
# base rows are always version snapshots.


def build_tier_schedule(m: int, rows, base_rows, records, futility,
                        capacity: Optional[int] = None) -> 'TierSchedule':
    """Lower per-round sparse event rows + base versions to slot maps.

    ``rows`` are ``safa_sparse_row`` outputs; ``base_rows[t]`` holds the
    version counter (post sync, pre commit) of each active client, aligned
    with ``rows[t][0]``.  Two-pass: record every value read/write with
    exact rounds, then allocate buffer slots by lifetime."""
    rounds = len(rows)
    idx, roles = pack_sparse_rows(rows, m, capacity)
    width = idx.shape[1]
    R_S, R_P = protocol.ROLE_SYNC, protocol.ROLE_PICKED
    R_U, R_D = protocol.ROLE_UNDRAFTED, protocol.ROLE_DEPRECATED
    R_C = protocol.ROLE_COMMITTED

    # Pass A — value ids: version v -> v (0..rounds, ver 0 == init global,
    # ver t+1 == round t's output); commit events -> rounds+1+eid.
    n_vals = rounds + 1
    cache_ref: dict = {}        # client -> value id its cache row holds
    last_read: dict = {}        # value id -> last round reading it
    base_val = np.full((rounds, width), -1, np.int64)
    cache_val = np.full((rounds, width), -1, np.int64)
    commit_val = np.full((rounds, width), -1, np.int64)
    for i, ((act, rls), bv) in enumerate(zip(rows, base_rows)):
        for j in range(len(act)):
            k, r = int(act[j]), int(rls[j])
            if (r & R_C) and not (r & R_S):
                base_val[i, j] = v = int(bv[j])
                last_read[v] = i
            if r & (R_P | R_U | R_D):
                cache_val[i, j] = cv = cache_ref.get(k, 0)
                last_read[cv] = i
            if r & (R_P | R_U):
                commit_val[i, j] = cache_ref[k] = n_vals
                n_vals += 1
            elif r & R_D:
                # cache := current global — ver i is already resident in
                # the ring (or never read again), so no slot write.
                cache_ref[k] = i

    # Pass B — slot allocation in write order.  Version v is written at
    # round v-1 (ver 0 pre-run); commit values at their round.  A slot
    # frees the round after its value's last read.
    writes: dict = {wr: [] for wr in range(-1, rounds)}
    if 0 in last_read:
        writes[-1].append(0)
    for i in range(rounds):
        for j in range(width):
            v = int(commit_val[i, j])
            if v >= 0 and v in last_read:
                writes[i].append(v)
        if (i + 1) in last_read:
            writes[i].append(i + 1)
    slot_of: dict = {}
    free: list = []
    pending: dict = {wr: [] for wr in range(rounds + 1)}
    next_slot = 0
    for wr in range(-1, rounds):
        if wr >= 0:
            for s in pending[wr]:
                heapq.heappush(free, s)
        for val in writes[wr]:
            if free:
                s = heapq.heappop(free)
            else:
                s = next_slot
                next_slot += 1
            slot_of[val] = s
            pending.setdefault(last_read[val] + 1, []).append(s)

    scratch = next_slot
    base_src = np.full((rounds, width), scratch, np.int32)
    cache_src = np.full((rounds, width), scratch, np.int32)
    cache_dst = np.full((rounds, width), scratch, np.int32)
    global_dst = np.full(rounds, scratch, np.int32)
    for i in range(rounds):
        for j in range(width):
            if base_val[i, j] >= 0:
                base_src[i, j] = slot_of[int(base_val[i, j])]
            if cache_val[i, j] >= 0:
                cache_src[i, j] = slot_of[int(cache_val[i, j])]
            v = int(commit_val[i, j])
            if v >= 0 and v in slot_of:
                cache_dst[i, j] = slot_of[v]
        if (i + 1) in slot_of:
            global_dst[i] = slot_of[i + 1]
    versions_stored = sum(1 for v in slot_of if v <= rounds)
    return TierSchedule(
        m=m, idx=idx, roles=roles, base_src=base_src, cache_src=cache_src,
        cache_dst=cache_dst, global_dst=global_dst, capacity=next_slot,
        versions_stored=versions_stored,
        commits_stored=len(slot_of) - versions_stored,
        records=records, futility=futility)


@dataclasses.dataclass
class TierSchedule:
    """Lag-tier compressed SAFA event process (see section comment above):
    sparse [rounds, K] active-set indices/roles plus the slot maps that
    drive the single ``[capacity+1, N]`` value buffer.  ``capacity`` is the
    peak live-row count (O(tau + quota)); the extra row is scratch."""
    m: int
    idx: np.ndarray             # [rounds, K] int32, sentinel == m
    roles: np.ndarray           # [rounds, K] uint8 of protocol.ROLE_* bits
    base_src: np.ndarray        # [rounds, K] int32 buffer slots
    cache_src: np.ndarray       # [rounds, K] int32
    cache_dst: np.ndarray       # [rounds, K] int32 (scratch == discard)
    global_dst: np.ndarray      # [rounds] int32
    capacity: int               # live slots; scratch slot == capacity
    versions_stored: int
    commits_stored: int
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.idx.shape[0]

    @property
    def width(self) -> int:
        return self.idx.shape[1]

    @property
    def scratch(self) -> int:
        return self.capacity

    @property
    def nbytes(self) -> int:
        return (self.idx.nbytes + self.roles.nbytes + self.base_src.nbytes
                + self.cache_src.nbytes + self.cache_dst.nbytes
                + self.global_dst.nbytes)

    def to_device(self) -> protocol.TierRoundSchedule:
        return protocol.TierRoundSchedule(
            idx=jnp.asarray(self.idx), roles=jnp.asarray(self.roles),
            base_src=jnp.asarray(self.base_src),
            cache_src=jnp.asarray(self.cache_src),
            cache_dst=jnp.asarray(self.cache_dst),
            global_dst=jnp.asarray(self.global_dst),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))

    def to_sparse(self) -> SparseSchedule:
        """Drop the slot maps (the event stream is the sparse one)."""
        return SparseSchedule(m=self.m, idx=self.idx, roles=self.roles,
                              records=self.records, futility=self.futility)

    def to_dense(self) -> SafaSchedule:
        return self.to_sparse().to_dense()


# ---------------------------------------------------------------------------
# Fleet-major stacking: [S, rounds, m] schedules for batched sweeps
# ---------------------------------------------------------------------------

class _FleetStack:
    """Shared fleet-major stacking machinery.  Subclasses set ``MASKS``
    (the [S, rounds, m] field names, first one authoritative for shapes)
    and ``_MEMBER_CLS`` (the single-run schedule type)."""
    MASKS: tuple = ()
    _MEMBER_CLS = None

    @property
    def size(self) -> int:
        return getattr(self, self.MASKS[0]).shape[0]

    @property
    def rounds(self) -> int:
        return getattr(self, self.MASKS[0]).shape[1]

    @classmethod
    def stack(cls, members: list):
        """Stack S single-run schedules (all with the same rounds and m)."""
        if len({getattr(s, cls.MASKS[0]).shape for s in members}) != 1:
            raise ValueError('fleet members must share (rounds, m)')
        return cls(**{k: np.stack([getattr(s, k) for s in members])
                      for k in cls.MASKS},
                   records=[s.records for s in members],
                   futility=np.array([s.futility for s in members]))

    def member(self, s: int):
        """Member s's schedule, identical to its own precompute."""
        return self._MEMBER_CLS(
            **{k: getattr(self, k)[s] for k in self.MASKS},
            records=self.records[s], futility=float(self.futility[s]))

    def _round_idx(self):
        """[S, rounds] per-member round indices for to_device()."""
        return jnp.asarray(np.broadcast_to(
            np.arange(1, self.rounds + 1, dtype=np.int32),
            (self.size, self.rounds)))


@dataclasses.dataclass
class FleetSchedule(_FleetStack):
    """S independent SAFA event processes stacked fleet-major.

    Mask tensors are [S, rounds, m]; ``records[s]`` / ``futility[s]`` hold
    member s's timing records and futility ratio, exactly as
    ``precompute_safa_schedule`` produced them."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('sync', 'committed', 'picked', 'undrafted', 'deprecated')
    _MEMBER_CLS = SafaSchedule

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole fleet ([S, rounds, m] masks,
        [S, rounds] round indices)."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=self._round_idx())

    def to_sparse(self, capacity: Optional[int] = None) -> 'SparseFleetSchedule':
        """Compact [S, rounds, K] form (K = the fleet-wide max active set
        unless an explicit capacity is given)."""
        return SparseFleetSchedule.from_members(
            [self.member(s).to_sparse() for s in range(self.size)],
            capacity=capacity)

    def to_tier(self, capacity: Optional[int] = None) -> 'TierFleetSchedule':
        """Lag-tier compressed [S, rounds, K] form."""
        return TierFleetSchedule.from_members(
            [self.member(s).to_tier() for s in range(self.size)],
            capacity=capacity)


@dataclasses.dataclass
class SyncFleetSchedule(_FleetStack):
    """FedAvg/FedCS counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('selected', 'completed')
    _MEMBER_CLS = SyncSchedule

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())

    def to_sparse(self, capacity: Optional[int] = None) -> 'SparseSyncFleetSchedule':
        return SparseSyncFleetSchedule.from_members(
            [self.member(s).to_sparse() for s in range(self.size)],
            capacity=capacity)


@dataclasses.dataclass
class LocalFleetSchedule(_FleetStack):
    """Fully-local counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('completed',)
    _MEMBER_CLS = LocalSchedule

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())


@dataclasses.dataclass
class AsyncFleetSchedule(_FleetStack):
    """FedAsync counterpart of ``FleetSchedule``: [S, rounds, m] commit
    masks plus the merge-order/alpha tensors driving each member's
    arrival-ordered sequential mixes."""
    committed: np.ndarray
    order: np.ndarray
    alphas: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('committed', 'order', 'alphas')
    _MEMBER_CLS = FedasyncSchedule

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=self._round_idx())


@dataclasses.dataclass
class WeightedFleetSchedule(_FleetStack):
    """Weighted-merge counterpart of ``FleetSchedule``: [S, rounds, m]
    commit masks + effective merge-weight rows.  Because the scheme is
    data (the precomputed ``wrow``), members of one fleet may replay
    *different* schemes of the staleness-adaptive family in a single
    vmapped dispatch."""
    committed: np.ndarray
    wrow: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('committed', 'wrow')
    _MEMBER_CLS = WeightedSchedule

    def to_device(self) -> protocol.WeightedSchedule:
        return protocol.WeightedSchedule(
            committed=jnp.asarray(self.committed),
            wrow=jnp.asarray(self.wrow, jnp.float32),
            round_idx=self._round_idx())


# ---------------------------------------------------------------------------
# Sparse fleet stacking: [S, rounds, K] index/role tensors
# ---------------------------------------------------------------------------

class _SparseFleetStack:
    """Fleet-major stacking for sparse schedules.  Members may have grown
    different capacities; stacking re-pads everyone to the fleet max (or an
    explicit capacity) so the tensors batch, while ``capacities`` keeps
    each member's own active-set width so the sequential path hands back
    ragged (unpadded) member schedules instead of paying the fleet-max
    gather width.  Padded slots are sentinel no-ops (idx == m, roles == 0),
    so fleet and ragged-member replay stay bit-identical."""
    _MEMBER_CLS = None
    _SCHEDULE_CLS = None

    @classmethod
    def from_members(cls, members: list, capacity: Optional[int] = None):
        if len({(s.m, s.rounds) for s in members}) != 1:
            raise ValueError('fleet members must share (m, rounds)')
        m = members[0].m
        cap = max(s.capacity for s in members) if capacity is None else capacity
        need = max(s.capacity for s in members)
        if cap < need:
            raise ValueError(
                f'sparse fleet capacity {cap} < member active-set max {need}')

        def pad(a, fill):
            out = np.full(a.shape[:-1] + (cap,), fill, a.dtype)
            out[..., :a.shape[-1]] = a
            return out

        return cls(m=m,
                   idx=np.stack([pad(s.idx, m) for s in members]),
                   roles=np.stack([pad(s.roles, 0) for s in members]),
                   records=[s.records for s in members],
                   futility=np.array([s.futility for s in members]),
                   capacities=np.array([s.capacity for s in members],
                                       np.int32))

    @property
    def size(self) -> int:
        return self.idx.shape[0]

    @property
    def rounds(self) -> int:
        return self.idx.shape[1]

    @property
    def capacity(self) -> int:
        return self.idx.shape[2]

    @property
    def nbytes(self) -> int:
        return self.idx.nbytes + self.roles.nbytes

    def member(self, s: int):
        """Member s's schedule at its *own* capacity (ragged slice —
        identical to the member's standalone precompute)."""
        cap = (int(self.capacities[s]) if self.capacities is not None
               else self.capacity)
        return self._MEMBER_CLS(m=self.m, idx=self.idx[s, :, :cap],
                                roles=self.roles[s, :, :cap],
                                records=self.records[s],
                                futility=float(self.futility[s]))

    def to_device(self):
        return self._SCHEDULE_CLS(
            idx=jnp.asarray(self.idx), roles=jnp.asarray(self.roles),
            round_idx=jnp.asarray(np.broadcast_to(
                np.arange(1, self.rounds + 1, dtype=np.int32),
                (self.size, self.rounds))))


@dataclasses.dataclass
class SparseFleetSchedule(_SparseFleetStack):
    """S compact SAFA event processes, fleet-major ([S, rounds, K])."""
    m: int
    idx: np.ndarray
    roles: np.ndarray
    records: list
    futility: np.ndarray
    capacities: Optional[np.ndarray] = None     # [S] per-member widths

    _MEMBER_CLS = SparseSchedule
    _SCHEDULE_CLS = protocol.SparseRoundSchedule


@dataclasses.dataclass
class SparseSyncFleetSchedule(_SparseFleetStack):
    """S compact FedAvg/FedCS event processes ([S, rounds, K])."""
    m: int
    idx: np.ndarray
    roles: np.ndarray
    records: list
    futility: np.ndarray
    capacities: Optional[np.ndarray] = None     # [S] per-member widths

    _MEMBER_CLS = SparseSyncSchedule
    _SCHEDULE_CLS = protocol.SparseSyncSchedule


@dataclasses.dataclass
class TierFleetSchedule:
    """S lag-tier SAFA event processes, fleet-major ([S, rounds, K]).

    Members may differ in active-set width *and* slot capacity; stacking
    pads width with sentinel no-op slots and remaps each member's scratch
    slot (its own ``capacity``) to the fleet-max slot so one
    ``[S, capacity+1, N]`` value buffer batches.  ``member(s)`` hands back
    the padded-width schedule in fleet slot space, so sequential replay of
    a member matches the fleet run bit-for-bit."""
    m: int
    idx: np.ndarray             # [S, rounds, K]
    roles: np.ndarray
    base_src: np.ndarray
    cache_src: np.ndarray
    cache_dst: np.ndarray
    global_dst: np.ndarray      # [S, rounds]
    capacity: int               # fleet-max live slots; scratch == capacity
    capacities: np.ndarray      # [S] per-member live-slot counts
    widths: np.ndarray          # [S] per-member active-set widths
    versions_stored: np.ndarray
    commits_stored: np.ndarray
    records: list
    futility: np.ndarray

    @classmethod
    def from_members(cls, members: list,
                     capacity: Optional[int] = None) -> 'TierFleetSchedule':
        if len({(s.m, s.rounds) for s in members}) != 1:
            raise ValueError('fleet members must share (m, rounds)')
        m = members[0].m
        wid = max(s.width for s in members) if capacity is None else capacity
        need = max(s.width for s in members)
        if wid < need:
            raise ValueError(
                f'sparse fleet capacity {wid} < member active-set max {need}')
        cap = max(s.capacity for s in members)

        def pad(a, fill):
            out = np.full(a.shape[:-1] + (wid,), fill, a.dtype)
            out[..., :a.shape[-1]] = a
            return out

        def remap(s, a):
            # member scratch -> fleet scratch (slot layouts otherwise agree
            # with the member's own allocator output)
            return np.where(a == s.capacity, cap, a).astype(np.int32)

        return cls(
            m=m,
            idx=np.stack([pad(s.idx, m) for s in members]),
            roles=np.stack([pad(s.roles, 0) for s in members]),
            base_src=np.stack([pad(remap(s, s.base_src), cap)
                               for s in members]),
            cache_src=np.stack([pad(remap(s, s.cache_src), cap)
                                for s in members]),
            cache_dst=np.stack([pad(remap(s, s.cache_dst), cap)
                                for s in members]),
            global_dst=np.stack([remap(s, s.global_dst) for s in members]),
            capacity=cap,
            capacities=np.array([s.capacity for s in members], np.int32),
            widths=np.array([s.width for s in members], np.int32),
            versions_stored=np.array([s.versions_stored for s in members],
                                     np.int32),
            commits_stored=np.array([s.commits_stored for s in members],
                                    np.int32),
            records=[s.records for s in members],
            futility=np.array([s.futility for s in members]))

    @property
    def size(self) -> int:
        return self.idx.shape[0]

    @property
    def rounds(self) -> int:
        return self.idx.shape[1]

    @property
    def width(self) -> int:
        return self.idx.shape[2]

    @property
    def nbytes(self) -> int:
        return (self.idx.nbytes + self.roles.nbytes + self.base_src.nbytes
                + self.cache_src.nbytes + self.cache_dst.nbytes
                + self.global_dst.nbytes)

    def member(self, s: int) -> TierSchedule:
        """Member s in fleet slot space (scratch == fleet capacity) at the
        fleet-padded width: sequential replay then runs the exact program
        the vmapped fleet runs (same reduction widths), so fleet ==
        sequential stays *bit*-identical.  A standalone precompute of the
        same member (its own width/capacity) is allclose-, not bit-,
        equivalent — padded slots contribute exact zeros, but XLA may
        associate a different-length slot reduction differently."""
        return TierSchedule(
            m=self.m, idx=self.idx[s], roles=self.roles[s],
            base_src=self.base_src[s],
            cache_src=self.cache_src[s],
            cache_dst=self.cache_dst[s],
            global_dst=self.global_dst[s], capacity=self.capacity,
            versions_stored=int(self.versions_stored[s]),
            commits_stored=int(self.commits_stored[s]),
            records=self.records[s], futility=float(self.futility[s]))

    def to_device(self) -> protocol.TierRoundSchedule:
        return protocol.TierRoundSchedule(
            idx=jnp.asarray(self.idx), roles=jnp.asarray(self.roles),
            base_src=jnp.asarray(self.base_src),
            cache_src=jnp.asarray(self.cache_src),
            cache_dst=jnp.asarray(self.cache_dst),
            global_dst=jnp.asarray(self.global_dst),
            round_idx=jnp.asarray(np.broadcast_to(
                np.arange(1, self.rounds + 1, dtype=np.int32),
                (self.size, self.rounds))))
