"""Holistic protocol metrics (Eq. 4, 5, 9, 10)."""
from __future__ import annotations

import numpy as np


def eur_measured(picked: np.ndarray, crashed: np.ndarray) -> float:
    """Eq. 4: |P - P∩K| / |M|."""
    m = picked.shape[0]
    return float((picked & ~crashed).sum()) / m


def eur_theory_safa(C: float, R: float) -> float:
    """Eq. 5: post-training selection EUR."""
    return 1 - R if C >= 1 - R else C


def eur_theory_fedavg(C: float, R: float) -> float:
    """§III-B: selection-ahead-of-training EUR = C (1 - |K|/|M|)."""
    return C * (1 - R)


def sync_ratio(sync_counts, m: int, rounds: int) -> float:
    """Eq. 9, accumulated per-round sync counts."""
    return float(np.sum(sync_counts)) / (rounds * m)


def version_variance(version_lists) -> float:
    """Eq. 10: mean over rounds of var(V_t)."""
    vs = [np.var(v) for v in version_lists if len(v)]
    return float(np.mean(vs)) if vs else 0.0
