"""Closed-form bias analysis (paper §III-E, Appendix A; Eq. 11-16, 22-31).

bias(r) = P^(r)(A) / P^(r)(B) between the fastest client A and the slowest
client B, as a function of the federated round index r.
"""
from __future__ import annotations

import numpy as np


def case_of(C: float, R: float) -> int:
    """Selection-regime cases (paper §III-E)."""
    if C >= 1 - R:
        return 1
    if (1 - C) * (1 - R) <= C < 1 - R:
        return 2
    return 3


def sigma_paper(cr: float, k: int) -> float:
    """Eq. 15 EXACTLY as printed:
        sigma^(k) = (2 cr - (cr-1)^(k+1) - 3) / (cr - 2).
    Used to reproduce Fig. 5 faithfully.  Note this evaluates > 1 (e.g.
    2-cr at k=1), so it cannot be the complement of a probability — it is
    inconsistent with the paper's own recurrence (Eq. 22/24); see
    ``sigma`` for the corrected form and EXPERIMENTS.md for discussion.
    """
    return (2 * cr - (cr - 1) ** (k + 1) - 3) / (cr - 2)


def sigma(cr: float, k: int) -> float:
    """Corrected sigma^(k) = 1 - P_D^(k): exact solution of the paper's own
    recurrence P_D^(r) = (1-cr)(1 - P_D^(r-1)), P_D^(1) = 1-cr (Eq. 22/24):

        sigma^(k) = ((cr-1)^(k+1) - 1) / (cr - 2)

    Fixed point 1/(2-cr); validated by Monte-Carlo CFCFM simulation
    (tests/test_bias_montecarlo.py).
    """
    return ((cr - 1) ** (k + 1) - 1) / (cr - 2)


def p_direct(cr: float, r: int, case: int, fast: bool,
             faithful: bool = True) -> float:
    """Eq. 28 / 30: probability the client's update goes directly into the
    cache in round r.  ``faithful`` selects the paper's printed sigma
    (Fig. 5 reproduction) vs the corrected recurrence solution."""
    s = sigma_paper if faithful else sigma
    if fast:
        if case in (1, 2):
            return 1 - cr
        return (1 - cr) * s(cr, r - 1)
    else:
        if case == 1:
            return 1 - cr
        if case == 2:
            return (1 - cr) * s(cr, r - 1)
        return 0.0


def p_bypass(cr: float, r: int, case: int, fast: bool,
             faithful: bool = True) -> float:
    """Eq. 29 / 31: probability the bypass entry takes effect in round r."""
    s = sigma_paper if faithful else sigma
    if fast:
        if case in (1, 2):
            return 0.0
        return cr * (s(cr, r - 1) - cr)
    else:
        if case == 1:
            return 0.0
        if case == 2:
            return cr * (s(cr, r - 1) - cr)
        return 1 - cr


def p_contrib(cr: float, r: int, case: int, fast: bool,
              faithful: bool = True) -> float:
    """Eq. 13 / 14 via Proposition 2 (P = P_D + P_S)."""
    if r <= 1:
        return 1 - cr
    return (p_direct(cr, r, case, fast, faithful)
            + p_bypass(cr, r, case, fast, faithful))


def bias_safa(cr_a: float, cr_b: float, C: float, R: float, r: int,
              faithful: bool = True) -> float:
    """Eq. 16."""
    c = case_of(C, R)
    return (p_contrib(cr_a, r, c, True, faithful)
            / p_contrib(cr_b, r, c, False, faithful))


def bias_fedavg(cr_a: float, cr_b: float) -> float:
    """Eq. 12."""
    return (1 - cr_a) / (1 - cr_b)


def bias_curve(cr_a: float, cr_b: float, C: float, R: float, rounds: int,
               faithful: bool = True):
    return np.array([bias_safa(cr_a, cr_b, C, R, r, faithful)
                     for r in range(2, rounds + 2)])
