"""SAFA numeric protocol algebra (Eq. 3, 6, 7, 8) on stacked client pytrees.

Everything here is mask-driven and jit-able.  Client pytrees carry a leading
``clients`` dim of size m; in simulation mode it is a stacked replica axis,
in silo mode it is sharded over the ``("pod", "data")`` mesh axes.

The server's *cache* (one entry per client) and the *bypass* are realised as
masked updates: picked entries overwrite pre-aggregation (Eq. 6), undrafted
entries overwrite post-aggregation (Eq. 8) — bit-identical to the paper's
three-step discriminative aggregation (tests assert the step-by-step
equivalence).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def _bmask(mask, leaf):
    """Broadcast a [m] client mask against a [m, ...] leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def masked_select(mask, a, b):
    """Per-client where: leaf = mask ? a : b  (mask: [m] bool)."""
    return jax.tree.map(lambda x, y: jnp.where(_bmask(mask, x), x, y), a, b)


def broadcast_global(global_tree, m: int):
    """Tile the global model across the clients dim."""
    return jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (m,) + g.shape), global_tree)


# ---------------------------------------------------------------------------
# Eq. 3 — lag-tolerant distribution
# ---------------------------------------------------------------------------

def distribute(global_w, local_w, sync_mask):
    """sync_mask[k] True => client k (up-to-date or deprecated) takes the
    latest global model; tolerable clients keep their local model."""
    m = sync_mask.shape[0]
    g = broadcast_global(global_w, m)
    return masked_select(sync_mask, g, local_w)


def classify_versions(versions, global_version, lag_tolerance,
                      committed_prev=None):
    """Client states at round start.

    versions[k] = version of the base model client k currently holds.
    up-to-date:  committed last round (their base will be the new global);
    deprecated:  staleness >= lag_tolerance (Eq. 3: v < t - tau);
    tolerable:   in between.
    """
    staleness = global_version - versions
    if committed_prev is None:
        up_to_date = staleness <= 0
    else:
        up_to_date = committed_prev
    deprecated = (~up_to_date) & (staleness >= lag_tolerance)
    tolerable = (~up_to_date) & (~deprecated)
    return up_to_date, deprecated, tolerable


# ---------------------------------------------------------------------------
# Eq. 6/7/8 — three-step discriminative aggregation
# ---------------------------------------------------------------------------

class AggregationResult(NamedTuple):
    new_global: Any
    new_cache: Any


def pre_agg_cache_update(cache, trained, global_prev, picked, deprecated):
    """Eq. 6.  picked -> trained update; deprecated (and not picked) ->
    previous global; otherwise keep the existing entry."""
    m = picked.shape[0]
    g = broadcast_global(global_prev, m)
    out = masked_select(deprecated & ~picked, g, cache)
    out = masked_select(picked, trained, out)
    return out


def aggregate(cache, weights):
    """Eq. 7: w(t) = sum_k (n_k / n) * cache_k.  weights: [m], sums to 1."""
    def red(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)
    return jax.tree.map(red, cache)


def post_agg_cache_update(cache, trained, undrafted):
    """Eq. 8: undrafted updates enter the cache for the *next* round."""
    return masked_select(undrafted, trained, cache)


def discriminative_aggregation(cache, trained, global_prev, *, picked,
                               undrafted, deprecated, weights,
                               use_kernel=False) -> AggregationResult:
    """The full three-step aggregation.

    ``use_kernel`` routes the fused Pallas path (kernels/safa_aggregate):
    ``True`` launches the fused kernel once per pytree leaf; ``'packed'``
    flattens the model into one buffer and launches exactly once per call.
    """
    if use_kernel not in (False, True, 'packed'):
        raise ValueError(
            f'unknown use_kernel {use_kernel!r} (want False, True, or '
            f'"packed")')
    if use_kernel:
        from repro.kernels import ops as kops
        if use_kernel == 'packed':
            return kops.safa_aggregate_tree_packed(
                cache, trained, global_prev, picked=picked,
                undrafted=undrafted, deprecated=deprecated, weights=weights)
        return kops.safa_aggregate_tree(
            cache, trained, global_prev, picked=picked, undrafted=undrafted,
            deprecated=deprecated, weights=weights)
    cache1 = pre_agg_cache_update(cache, trained, global_prev, picked, deprecated)
    new_global = aggregate(cache1, weights)
    cache2 = post_agg_cache_update(cache1, trained, undrafted)
    return AggregationResult(new_global, cache2)


# ---------------------------------------------------------------------------
# One full numeric SAFA round (jit-able), generic over a local-train fn
# ---------------------------------------------------------------------------

def check_wire(wire: str):
    if wire not in ('f32', 'int8'):
        raise ValueError(f"unknown wire {wire!r} (want 'f32' or 'int8')")


def safa_round(global_w, local_w, cache, *, sync_mask, completed, picked,
               undrafted, deprecated, weights, local_train_fn, train_args=(),
               use_kernel: bool = False, wire: str = 'f32'):
    """Run one SAFA round numerically.

    local_train_fn(stacked_params, *train_args) -> stacked trained params
    (it is responsible for vmapping over the clients dim).

    ``wire='int8'`` runs the compressed-wire fast path: the client
    uploads cross the simulated wire as one block-quantised int8 pack
    buffer and the server dequantises them in-register inside the fused
    Eq. 6-8 kernel (``ops.safa_compressed_update``) — exactly 2 kernel
    dispatches per round regardless of model depth.  ``use_kernel`` is
    ignored on that path (the fused kernel IS the aggregation).

    Returns (new_global, new_local, new_cache).
    """
    check_wire(wire)
    base = distribute(global_w, local_w, sync_mask)
    trained = local_train_fn(base, *train_args)
    if wire == 'int8':
        from repro.kernels import ops as kops
        return kops.safa_compressed_update(
            base, trained, cache, global_w, picked=picked,
            undrafted=undrafted, deprecated=deprecated, completed=completed,
            weights=weights)
    # crashed clients make no visible progress this round
    trained = masked_select(completed, trained, base)
    res = discriminative_aggregation(
        cache, trained, global_w, picked=picked, undrafted=undrafted,
        deprecated=deprecated, weights=weights, use_kernel=use_kernel)
    # committed clients now hold their own trained model locally
    new_local = masked_select(completed, trained, base)
    return res.new_global, new_local, res.new_cache


# ---------------------------------------------------------------------------
# Compiled multi-round engines: jax.lax.scan over precomputed schedules
# ---------------------------------------------------------------------------
#
# The SAFA timing/event state machine (FLEnv draws, CFCFM selection, version
# bookkeeping) is pure numpy and independent of model weights, so every
# per-round mask can be precomputed into [k, m] schedules in one cheap host
# pass (federation.precompute_safa_schedule).  The whole numeric run then
# becomes ONE dispatch of a scanned round body with the (global, local,
# cache) carry donated — no per-round dispatch, no per-round host->device
# mask shuttling, no second full cache allocation.

class RoundSchedule(NamedTuple):
    """SAFA per-round masks, stacked [k, m] (plus round indices [k]) so k
    rounds cross host->device in a single transfer."""
    sync: Any
    completed: Any
    picked: Any
    undrafted: Any
    deprecated: Any
    round_idx: Any


class SyncSchedule(NamedTuple):
    """FedAvg/FedCS per-round masks, stacked [k, m]."""
    selected: Any
    completed: Any
    round_idx: Any


class LocalSchedule(NamedTuple):
    """Fully-local baseline per-round masks, stacked [k, m]: ``completed``
    is selected & survived — the only mask the numeric round needs."""
    completed: Any
    round_idx: Any


class AsyncSchedule(NamedTuple):
    """FedAsync per-round merge schedule, stacked [k, m]: the commit mask,
    the arrival-order merge permutation and the staleness-scaled mixing
    weights (0 for non-commits) — everything the sequential server mixes
    depend on, precomputed so the round body is schedule-driven."""
    committed: Any
    order: Any
    alphas: Any
    round_idx: Any


def _safa_scan(global_w, local_w, cache, schedule, weights, local_train_fn,
               use_kernel, wire='f32', train_extra=()):
    """Unjitted scan body shared by the single-run and fleet engines.

    ``train_extra`` holds per-run constants appended to the train call
    (``local_train_fn(base, round_idx, *train_extra)``) — the per-member
    data context of a per-member-Task fleet rides here."""
    def step(carry, sched):
        g, l, c = carry
        out = safa_round(
            g, l, c, sync_mask=sched.sync, completed=sched.completed,
            picked=sched.picked, undrafted=sched.undrafted,
            deprecated=sched.deprecated, weights=weights,
            local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra),
            use_kernel=use_kernel, wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (global_w, local_w, cache), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_scan(global_w, local_w, cache, schedule: RoundSchedule, weights,
                  *, local_train_fn, use_kernel=False, wire='f32'):
    """Run ``k = len(schedule.round_idx)`` SAFA rounds as one compiled scan.

    Bit-identical to ``k`` per-round ``safa_round`` dispatches: the scan
    body is the same trace, compiled once.  The carry is donated, so the
    caller's buffers are reused in place across the whole run.
    ``wire='int8'`` compiles the compressed-wire round body — 2 kernel
    dispatches per round inside the one scanned program.
    Returns (new_global, new_local, new_cache).
    """
    return _safa_scan(global_w, local_w, cache, schedule, weights,
                      local_train_fn, use_kernel, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_fleet(global_w, local_w, cache, schedule: RoundSchedule, weights,
                   *, local_train_fn, use_kernel=False, wire='f32',
                   train_ctx=None):
    """Run S independent SAFA simulations as ONE vmapped-scan dispatch.

    Every operand gains a leading fleet axis: global_w [S, ...] leaves,
    local_w/cache [S, m, ...], schedule fields [S, k, m] (round_idx [S, k]),
    weights [S, m].  Fleet members may differ in crash draws, selection
    masks, lag tolerance, fraction and aggregation weights — anything the
    precomputed schedule captures — but share the Task (model shapes and
    client data) and round count.

    ``train_ctx`` (optional) is a pytree of [S, ...] leaves vmapped with
    the carry and handed to every train call as an extra argument
    (``local_train_fn(base, round_idx, ctx)``) — this is how a fleet of
    per-member Tasks ships each member its own (padded) client data while
    the train function stays one static, shared callable.

    Per member this computes exactly the ``safa_run_scan`` program; the
    regression tests assert per-run bit-identity against S sequential scan
    runs.  The whole [S, ...] carry is donated, so sweeping S configs costs
    one dispatch and no extra state copies.  Under ``use_kernel='packed'``
    the per-round pallas_call is vmapped into a batched-grid launch (still
    a single kernel dispatch per round for the whole fleet).
    Returns (new_global, new_local, new_cache), each fleet-stacked.
    """
    if train_ctx is None:
        run = lambda g, l, c, s, w: _safa_scan(g, l, c, s, w, local_train_fn,
                                               use_kernel, wire)
        return jax.vmap(run)(global_w, local_w, cache, schedule, weights)
    run = lambda g, l, c, s, w, ctx: _safa_scan(
        g, l, c, s, w, local_train_fn, use_kernel, wire, train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, cache, schedule, weights,
                         train_ctx)


def _fedavg_scan(global_w, local_w, schedule, weights, local_train_fn,
                 wire='f32', train_extra=()):
    def step(carry, sched):
        g, l = carry
        ng, nl = fedavg_round(
            g, l, selected=sched.selected, completed=sched.completed,
            weights=weights, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra), wire=wire)
        return (ng, nl), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_scan(global_w, local_w, schedule: SyncSchedule, weights, *,
                    local_train_fn, wire='f32'):
    """FedAvg counterpart of ``safa_run_scan``: k synchronous rounds in one
    dispatch with the (global, local) carry donated.  ``wire='int8'``
    round-trips the uploads through the packed int8 wire format (2 kernel
    dispatches per round) before the synchronous aggregation."""
    return _fedavg_scan(global_w, local_w, schedule, weights, local_train_fn,
                        wire)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_fleet(global_w, local_w, schedule: SyncSchedule, weights, *,
                     local_train_fn, wire='f32', train_ctx=None):
    """FedAvg/FedCS counterpart of ``safa_run_fleet``: S synchronous
    simulations (schedule fields [S, k, m], weights [S, m]) in one vmapped
    scan with the fleet-stacked (global, local) carry donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    if train_ctx is None:
        run = lambda g, l, s, w: _fedavg_scan(g, l, s, w, local_train_fn,
                                              wire)
        return jax.vmap(run)(global_w, local_w, schedule, weights)
    run = lambda g, l, s, w, ctx: _fedavg_scan(g, l, s, w, local_train_fn,
                                               wire, train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, schedule, weights, train_ctx)


def _local_scan(local_w, schedule, local_train_fn, train_extra=()):
    def step(l, sched):
        return local_only_round(
            l, completed=sched.completed, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra)), None

    carry, _ = jax.lax.scan(step, local_w, schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn',))
def local_run_scan(local_w, schedule: LocalSchedule, *, local_train_fn):
    """Fully-local counterpart of ``safa_run_scan``: k rounds of train +
    survivor masking in one dispatch with the local stack donated.  There
    is no global model in the carry — the caller aggregates at eval
    points."""
    return _local_scan(local_w, schedule, local_train_fn)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn',))
def local_run_fleet(local_w, schedule: LocalSchedule, *, local_train_fn,
                    train_ctx=None):
    """S fully-local simulations (local_w [S, m, ...], schedule fields
    [S, k, m]) in one vmapped scan with the fleet stack donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    if train_ctx is None:
        run = lambda l, s: _local_scan(l, s, local_train_fn)
        return jax.vmap(run)(local_w, schedule)
    run = lambda l, s, ctx: _local_scan(l, s, local_train_fn,
                                        train_extra=(ctx,))
    return jax.vmap(run)(local_w, schedule, train_ctx)


def _fedasync_scan(global_w, local_w, schedule, local_train_fn,
                   train_extra=()):
    def step(carry, sched):
        g, l = carry
        return fedasync_round(
            g, l, committed=sched.committed, order=sched.order,
            alphas=sched.alphas, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra)), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn',))
def fedasync_run_scan(global_w, local_w, schedule: AsyncSchedule, weights=None,
                      *, local_train_fn):
    """FedAsync counterpart of ``safa_run_scan``: k rounds in one dispatch
    with the (global, local) carry donated.  The per-round arrival-ordered
    server mixes run as an inner ``lax.scan`` over the schedule's
    precomputed [k, m] merge-order/alpha tensors (``fedasync_merge``), so
    the whole run is still a single compiled program.  ``weights`` is
    accepted for signature parity with the other engines and ignored
    (FedAsync's mixing weights live in the schedule)."""
    del weights
    return _fedasync_scan(global_w, local_w, schedule, local_train_fn)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn',))
def fedasync_run_fleet(global_w, local_w, schedule: AsyncSchedule,
                       weights=None, *, local_train_fn, train_ctx=None):
    """S FedAsync simulations (schedule fields [S, k, m]) in one vmapped
    scan with the fleet-stacked (global, local) carry donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    del weights
    if train_ctx is None:
        run = lambda g, l, s: _fedasync_scan(g, l, s, local_train_fn)
        return jax.vmap(run)(global_w, local_w, schedule)
    run = lambda g, l, s, ctx: _fedasync_scan(g, l, s, local_train_fn,
                                              train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, schedule, train_ctx)


# ---------------------------------------------------------------------------
# Baseline numeric rounds
# ---------------------------------------------------------------------------

def fedavg_round(global_w, local_w, *, selected, completed, weights,
                 local_train_fn, train_args=(), wire: str = 'f32'):
    """FedAvg: selected clients sync + train; aggregate over the selected
    clients that actually committed (renormalised weights); everyone else
    idles.  ``wire='int8'`` ships the uploads through the packed int8 wire
    (one quantize + one dequantize grid dispatch for the whole stacked
    tree — ``ops.wire_roundtrip_packed``), so the server aggregates what a
    compressed transfer actually delivers.  Returns (new_global,
    new_local)."""
    check_wire(wire)
    base = distribute(global_w, local_w, selected)
    trained = local_train_fn(base, *train_args)
    if wire == 'int8':
        from repro.kernels import ops as kops
        trained = kops.wire_roundtrip_packed(trained, like=global_w)
    ok = selected & completed
    wsum = jnp.maximum(jnp.sum(weights * ok), 1e-12)
    eff_w = jnp.where(ok, weights, 0.0) / wsum

    def red(t, g):
        w = eff_w.reshape((-1,) + (1,) * (t.ndim - 1)).astype(jnp.float32)
        agg = jnp.sum(t.astype(jnp.float32) * w, axis=0)
        any_ok = jnp.sum(ok) > 0
        return jnp.where(any_ok, agg, g.astype(jnp.float32)).astype(g.dtype)

    new_global = jax.tree.map(red, trained, global_w)
    new_local = masked_select(ok, trained, base)
    return new_global, new_local


def local_only_round(local_w, *, completed, local_train_fn, train_args=()):
    """Fully-local baseline: train, never aggregate."""
    trained = local_train_fn(local_w, *train_args)
    return masked_select(completed, trained, local_w)


def fedasync_merge(global_w, trained, *, order, alphas):
    """FedAsync (Xie et al. [9]) server: merge updates one-by-one in arrival
    order with staleness-scaled mixing:

        w <- (1 - alpha_k) w + alpha_k w'_k

    trained: stacked [m, ...]; order: [m] int arrival permutation;
    alphas: [m] effective mixing weight per client (0 for non-commits).
    Returns the post-merge global model.
    """
    def merge(g, idx):
        a = alphas[idx].astype(jnp.float32)
        def mix(gl, tr):
            upd = tr[idx].astype(jnp.float32)
            return ((1.0 - a) * gl.astype(jnp.float32) + a * upd).astype(gl.dtype)
        return jax.tree.map(mix, g, trained), None

    new_global, _ = jax.lax.scan(merge, global_w, order)
    return new_global


def fedasync_round(global_w, local_w, *, committed, order, alphas,
                   local_train_fn, train_args=()):
    """One full numeric FedAsync round: every client trains, crashed/late
    clients are masked out, the server merges the arrivals one-by-one
    (``fedasync_merge``), and committed clients pull the fresh global
    model.  Shared by the per-round loop engine and the scan body so the
    two stay step-identical.  Returns (new_global, new_local)."""
    m = committed.shape[0]
    trained = local_train_fn(local_w, *train_args)
    trained = masked_select(committed, trained, local_w)
    new_global = fedasync_merge(global_w, trained, order=order, alphas=alphas)
    # committed clients pull the fresh global model
    new_local = masked_select(committed, broadcast_global(new_global, m),
                              masked_select(committed, trained, local_w))
    return new_global, new_local
