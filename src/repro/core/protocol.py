"""SAFA numeric protocol algebra (Eq. 3, 6, 7, 8) on stacked client pytrees.

Everything here is mask-driven and jit-able.  Client pytrees carry a leading
``clients`` dim of size m; in simulation mode it is a stacked replica axis,
in silo mode it is sharded over the ``("pod", "data")`` mesh axes.

The server's *cache* (one entry per client) and the *bypass* are realised as
masked updates: picked entries overwrite pre-aggregation (Eq. 6), undrafted
entries overwrite post-aggregation (Eq. 8) — bit-identical to the paper's
three-step discriminative aggregation (tests assert the step-by-step
equivalence).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


def _bmask(mask, leaf):
    """Broadcast a [m] client mask against a [m, ...] leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def masked_select(mask, a, b):
    """Per-client where: leaf = mask ? a : b  (mask: [m] bool)."""
    return jax.tree.map(lambda x, y: jnp.where(_bmask(mask, x), x, y), a, b)


def broadcast_global(global_tree, m: int):
    """Tile the global model across the clients dim."""
    return jax.tree.map(
        lambda g: jnp.broadcast_to(g[None], (m,) + g.shape), global_tree)


# ---------------------------------------------------------------------------
# Eq. 3 — lag-tolerant distribution
# ---------------------------------------------------------------------------

def distribute(global_w, local_w, sync_mask):
    """sync_mask[k] True => client k (up-to-date or deprecated) takes the
    latest global model; tolerable clients keep their local model."""
    m = sync_mask.shape[0]
    g = broadcast_global(global_w, m)
    return masked_select(sync_mask, g, local_w)


def classify_versions(versions, global_version, lag_tolerance,
                      committed_prev=None):
    """Client states at round start.

    versions[k] = version of the base model client k currently holds.
    up-to-date:  committed last round (their base will be the new global);
    deprecated:  staleness >= lag_tolerance (Eq. 3: v < t - tau);
    tolerable:   in between.
    """
    staleness = global_version - versions
    if committed_prev is None:
        up_to_date = staleness <= 0
    else:
        up_to_date = committed_prev
    deprecated = (~up_to_date) & (staleness >= lag_tolerance)
    tolerable = (~up_to_date) & (~deprecated)
    return up_to_date, deprecated, tolerable


# ---------------------------------------------------------------------------
# Eq. 6/7/8 — three-step discriminative aggregation
# ---------------------------------------------------------------------------

class AggregationResult(NamedTuple):
    new_global: Any
    new_cache: Any


def pre_agg_cache_update(cache, trained, global_prev, picked, deprecated):
    """Eq. 6.  picked -> trained update; deprecated (and not picked) ->
    previous global; otherwise keep the existing entry."""
    m = picked.shape[0]
    g = broadcast_global(global_prev, m)
    out = masked_select(deprecated & ~picked, g, cache)
    out = masked_select(picked, trained, out)
    return out


def aggregate(cache, weights):
    """Eq. 7: w(t) = sum_k (n_k / n) * cache_k.  weights: [m], sums to 1."""
    def red(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0).astype(leaf.dtype)
    return jax.tree.map(red, cache)


def post_agg_cache_update(cache, trained, undrafted):
    """Eq. 8: undrafted updates enter the cache for the *next* round."""
    return masked_select(undrafted, trained, cache)


def discriminative_aggregation(cache, trained, global_prev, *, picked,
                               undrafted, deprecated, weights,
                               use_kernel=False) -> AggregationResult:
    """The full three-step aggregation.

    ``use_kernel`` routes the fused Pallas path (kernels/safa_aggregate):
    ``True`` launches the fused kernel once per pytree leaf; ``'packed'``
    flattens the model into one buffer and launches exactly once per call.
    """
    if use_kernel not in (False, True, 'packed'):
        raise ValueError(
            f'unknown use_kernel {use_kernel!r} (want False, True, or '
            f'"packed")')
    if use_kernel:
        from repro.kernels import ops as kops
        if use_kernel == 'packed':
            return kops.safa_aggregate_tree_packed(
                cache, trained, global_prev, picked=picked,
                undrafted=undrafted, deprecated=deprecated, weights=weights)
        return kops.safa_aggregate_tree(
            cache, trained, global_prev, picked=picked, undrafted=undrafted,
            deprecated=deprecated, weights=weights)
    cache1 = pre_agg_cache_update(cache, trained, global_prev, picked, deprecated)
    new_global = aggregate(cache1, weights)
    cache2 = post_agg_cache_update(cache1, trained, undrafted)
    return AggregationResult(new_global, cache2)


# ---------------------------------------------------------------------------
# One full numeric SAFA round (jit-able), generic over a local-train fn
# ---------------------------------------------------------------------------

def check_wire(wire: str):
    if wire not in ('f32', 'int8'):
        raise ValueError(f"unknown wire {wire!r} (want 'f32' or 'int8')")


def safa_server_step(base, trained, cache, global_w, *, completed, picked,
                     undrafted, deprecated, weights, use_kernel=False,
                     wire='f32'):
    """Everything the SAFA server does after local training: the wire
    transfer plus the Eq. 6-8 discriminative aggregation plus the local
    sync.  Split out of ``safa_round`` so the sparse-schedule round can
    scatter its trained rows into the dense stacks and then run the exact
    same trace — that is what makes sparse==dense a bit-identity, not an
    allclose.  Returns (new_global, new_local, new_cache)."""
    if wire == 'int8':
        from repro.kernels import ops as kops
        return kops.safa_compressed_update(
            base, trained, cache, global_w, picked=picked,
            undrafted=undrafted, deprecated=deprecated, completed=completed,
            weights=weights)
    # crashed clients make no visible progress this round
    trained = masked_select(completed, trained, base)
    res = discriminative_aggregation(
        cache, trained, global_w, picked=picked, undrafted=undrafted,
        deprecated=deprecated, weights=weights, use_kernel=use_kernel)
    # committed clients now hold their own trained model locally
    new_local = masked_select(completed, trained, base)
    return res.new_global, new_local, res.new_cache


def safa_round(global_w, local_w, cache, *, sync_mask, completed, picked,
               undrafted, deprecated, weights, local_train_fn, train_args=(),
               use_kernel: bool = False, wire: str = 'f32'):
    """Run one SAFA round numerically.

    local_train_fn(stacked_params, *train_args) -> stacked trained params
    (it is responsible for vmapping over the clients dim).

    ``wire='int8'`` runs the compressed-wire fast path: the client
    uploads cross the simulated wire as one block-quantised int8 pack
    buffer and the server dequantises them in-register inside the fused
    Eq. 6-8 kernel (``ops.safa_compressed_update``) — exactly 2 kernel
    dispatches per round regardless of model depth.  ``use_kernel`` is
    ignored on that path (the fused kernel IS the aggregation).

    Returns (new_global, new_local, new_cache).
    """
    check_wire(wire)
    base = distribute(global_w, local_w, sync_mask)
    trained = local_train_fn(base, *train_args)
    return safa_server_step(
        base, trained, cache, global_w, completed=completed, picked=picked,
        undrafted=undrafted, deprecated=deprecated, weights=weights,
        use_kernel=use_kernel, wire=wire)


# ---------------------------------------------------------------------------
# Compiled multi-round engines: jax.lax.scan over precomputed schedules
# ---------------------------------------------------------------------------
#
# The SAFA timing/event state machine (FLEnv draws, CFCFM selection, version
# bookkeeping) is pure numpy and independent of model weights, so every
# per-round mask can be precomputed into [k, m] schedules in one cheap host
# pass (federation.precompute_safa_schedule).  The whole numeric run then
# becomes ONE dispatch of a scanned round body with the (global, local,
# cache) carry donated — no per-round dispatch, no per-round host->device
# mask shuttling, no second full cache allocation.

class RoundSchedule(NamedTuple):
    """SAFA per-round masks, stacked [k, m] (plus round indices [k]) so k
    rounds cross host->device in a single transfer."""
    sync: Any
    completed: Any
    picked: Any
    undrafted: Any
    deprecated: Any
    round_idx: Any


class SyncSchedule(NamedTuple):
    """FedAvg/FedCS per-round masks, stacked [k, m]."""
    selected: Any
    completed: Any
    round_idx: Any


class LocalSchedule(NamedTuple):
    """Fully-local baseline per-round masks, stacked [k, m]: ``completed``
    is selected & survived — the only mask the numeric round needs."""
    completed: Any
    round_idx: Any


class AsyncSchedule(NamedTuple):
    """FedAsync per-round merge schedule, stacked [k, m]: the commit mask,
    the arrival-order merge permutation and the staleness-scaled mixing
    weights (0 for non-commits) — everything the sequential server mixes
    depend on, precomputed so the round body is schedule-driven."""
    committed: Any
    order: Any
    alphas: Any
    round_idx: Any


def _safa_scan(global_w, local_w, cache, schedule, weights, local_train_fn,
               use_kernel, wire='f32', train_extra=()):
    """Unjitted scan body shared by the single-run and fleet engines.

    ``train_extra`` holds per-run constants appended to the train call
    (``local_train_fn(base, round_idx, *train_extra)``) — the per-member
    data context of a per-member-Task fleet rides here."""
    def step(carry, sched):
        g, l, c = carry
        out = safa_round(
            g, l, c, sync_mask=sched.sync, completed=sched.completed,
            picked=sched.picked, undrafted=sched.undrafted,
            deprecated=sched.deprecated, weights=weights,
            local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra),
            use_kernel=use_kernel, wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (global_w, local_w, cache), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_scan(global_w, local_w, cache, schedule: RoundSchedule, weights,
                  *, local_train_fn, use_kernel=False, wire='f32'):
    """Run ``k = len(schedule.round_idx)`` SAFA rounds as one compiled scan.

    Bit-identical to ``k`` per-round ``safa_round`` dispatches: the scan
    body is the same trace, compiled once.  The carry is donated, so the
    caller's buffers are reused in place across the whole run.
    ``wire='int8'`` compiles the compressed-wire round body — 2 kernel
    dispatches per round inside the one scanned program.
    Returns (new_global, new_local, new_cache).
    """
    return _safa_scan(global_w, local_w, cache, schedule, weights,
                      local_train_fn, use_kernel, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_fleet(global_w, local_w, cache, schedule: RoundSchedule, weights,
                   *, local_train_fn, use_kernel=False, wire='f32',
                   train_ctx=None):
    """Run S independent SAFA simulations as ONE vmapped-scan dispatch.

    Every operand gains a leading fleet axis: global_w [S, ...] leaves,
    local_w/cache [S, m, ...], schedule fields [S, k, m] (round_idx [S, k]),
    weights [S, m].  Fleet members may differ in crash draws, selection
    masks, lag tolerance, fraction and aggregation weights — anything the
    precomputed schedule captures — but share the Task (model shapes and
    client data) and round count.

    ``train_ctx`` (optional) is a pytree of [S, ...] leaves vmapped with
    the carry and handed to every train call as an extra argument
    (``local_train_fn(base, round_idx, ctx)``) — this is how a fleet of
    per-member Tasks ships each member its own (padded) client data while
    the train function stays one static, shared callable.

    Per member this computes exactly the ``safa_run_scan`` program; the
    regression tests assert per-run bit-identity against S sequential scan
    runs.  The whole [S, ...] carry is donated, so sweeping S configs costs
    one dispatch and no extra state copies.  Under ``use_kernel='packed'``
    the per-round pallas_call is vmapped into a batched-grid launch (still
    a single kernel dispatch per round for the whole fleet).
    Returns (new_global, new_local, new_cache), each fleet-stacked.
    """
    if train_ctx is None:
        run = lambda g, l, c, s, w: _safa_scan(g, l, c, s, w, local_train_fn,
                                               use_kernel, wire)
        return jax.vmap(run)(global_w, local_w, cache, schedule, weights)
    run = lambda g, l, c, s, w, ctx: _safa_scan(
        g, l, c, s, w, local_train_fn, use_kernel, wire, train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, cache, schedule, weights,
                         train_ctx)


def _fedavg_scan(global_w, local_w, schedule, weights, local_train_fn,
                 wire='f32', train_extra=()):
    def step(carry, sched):
        g, l = carry
        ng, nl = fedavg_round(
            g, l, selected=sched.selected, completed=sched.completed,
            weights=weights, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra), wire=wire)
        return (ng, nl), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_scan(global_w, local_w, schedule: SyncSchedule, weights, *,
                    local_train_fn, wire='f32'):
    """FedAvg counterpart of ``safa_run_scan``: k synchronous rounds in one
    dispatch with the (global, local) carry donated.  ``wire='int8'``
    round-trips the uploads through the packed int8 wire format (2 kernel
    dispatches per round) before the synchronous aggregation."""
    return _fedavg_scan(global_w, local_w, schedule, weights, local_train_fn,
                        wire)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_fleet(global_w, local_w, schedule: SyncSchedule, weights, *,
                     local_train_fn, wire='f32', train_ctx=None):
    """FedAvg/FedCS counterpart of ``safa_run_fleet``: S synchronous
    simulations (schedule fields [S, k, m], weights [S, m]) in one vmapped
    scan with the fleet-stacked (global, local) carry donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    if train_ctx is None:
        run = lambda g, l, s, w: _fedavg_scan(g, l, s, w, local_train_fn,
                                              wire)
        return jax.vmap(run)(global_w, local_w, schedule, weights)
    run = lambda g, l, s, w, ctx: _fedavg_scan(g, l, s, w, local_train_fn,
                                               wire, train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, schedule, weights, train_ctx)


def _local_scan(local_w, schedule, local_train_fn, train_extra=()):
    def step(l, sched):
        return local_only_round(
            l, completed=sched.completed, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra)), None

    carry, _ = jax.lax.scan(step, local_w, schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn',))
def local_run_scan(local_w, schedule: LocalSchedule, *, local_train_fn):
    """Fully-local counterpart of ``safa_run_scan``: k rounds of train +
    survivor masking in one dispatch with the local stack donated.  There
    is no global model in the carry — the caller aggregates at eval
    points."""
    return _local_scan(local_w, schedule, local_train_fn)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn',))
def local_run_fleet(local_w, schedule: LocalSchedule, *, local_train_fn,
                    train_ctx=None):
    """S fully-local simulations (local_w [S, m, ...], schedule fields
    [S, k, m]) in one vmapped scan with the fleet stack donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    if train_ctx is None:
        run = lambda l, s: _local_scan(l, s, local_train_fn)
        return jax.vmap(run)(local_w, schedule)
    run = lambda l, s, ctx: _local_scan(l, s, local_train_fn,
                                        train_extra=(ctx,))
    return jax.vmap(run)(local_w, schedule, train_ctx)


def _fedasync_scan(global_w, local_w, schedule, local_train_fn,
                   train_extra=()):
    def step(carry, sched):
        g, l = carry
        return fedasync_round(
            g, l, committed=sched.committed, order=sched.order,
            alphas=sched.alphas, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra)), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn',))
def fedasync_run_scan(global_w, local_w, schedule: AsyncSchedule, weights=None,
                      *, local_train_fn):
    """FedAsync counterpart of ``safa_run_scan``: k rounds in one dispatch
    with the (global, local) carry donated.  The per-round arrival-ordered
    server mixes run as an inner ``lax.scan`` over the schedule's
    precomputed [k, m] merge-order/alpha tensors (``fedasync_merge``), so
    the whole run is still a single compiled program.  ``weights`` is
    accepted for signature parity with the other engines and ignored
    (FedAsync's mixing weights live in the schedule)."""
    del weights
    return _fedasync_scan(global_w, local_w, schedule, local_train_fn)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn',))
def fedasync_run_fleet(global_w, local_w, schedule: AsyncSchedule,
                       weights=None, *, local_train_fn, train_ctx=None):
    """S FedAsync simulations (schedule fields [S, k, m]) in one vmapped
    scan with the fleet-stacked (global, local) carry donated.
    ``train_ctx``: per-member train context, as in ``safa_run_fleet``."""
    del weights
    if train_ctx is None:
        run = lambda g, l, s: _fedasync_scan(g, l, s, local_train_fn)
        return jax.vmap(run)(global_w, local_w, schedule)
    run = lambda g, l, s, ctx: _fedasync_scan(g, l, s, local_train_fn,
                                              train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, schedule, train_ctx)


# ---------------------------------------------------------------------------
# Baseline numeric rounds
# ---------------------------------------------------------------------------

def fedavg_round(global_w, local_w, *, selected, completed, weights,
                 local_train_fn, train_args=(), wire: str = 'f32'):
    """FedAvg: selected clients sync + train; aggregate over the selected
    clients that actually committed (renormalised weights); everyone else
    idles.  ``wire='int8'`` ships the uploads through the packed int8 wire
    (one quantize + one dequantize grid dispatch for the whole stacked
    tree — ``ops.wire_roundtrip_packed``), so the server aggregates what a
    compressed transfer actually delivers.  Returns (new_global,
    new_local)."""
    check_wire(wire)
    base = distribute(global_w, local_w, selected)
    trained = local_train_fn(base, *train_args)
    return fedavg_server_step(base, trained, global_w, selected=selected,
                              completed=completed, weights=weights, wire=wire)


def fedavg_server_step(base, trained, global_w, *, selected, completed,
                       weights, wire: str = 'f32'):
    """FedAvg's post-train server math (wire transfer + renormalised
    aggregation + local sync), shared by the dense and sparse-schedule
    rounds so the two are trace-identical.  Returns (new_global,
    new_local)."""
    if wire == 'int8':
        from repro.kernels import ops as kops
        trained = kops.wire_roundtrip_packed(trained, like=global_w)
    ok = selected & completed
    wsum = jnp.maximum(jnp.sum(weights * ok), 1e-12)
    eff_w = jnp.where(ok, weights, 0.0) / wsum

    def red(t, g):
        w = eff_w.reshape((-1,) + (1,) * (t.ndim - 1)).astype(jnp.float32)
        agg = jnp.sum(t.astype(jnp.float32) * w, axis=0)
        any_ok = jnp.sum(ok) > 0
        return jnp.where(any_ok, agg, g.astype(jnp.float32)).astype(g.dtype)

    new_global = jax.tree.map(red, trained, global_w)
    new_local = masked_select(ok, trained, base)
    return new_global, new_local


def local_only_round(local_w, *, completed, local_train_fn, train_args=()):
    """Fully-local baseline: train, never aggregate."""
    trained = local_train_fn(local_w, *train_args)
    return masked_select(completed, trained, local_w)


def fedasync_merge(global_w, trained, *, order, alphas):
    """FedAsync (Xie et al. [9]) server: merge updates one-by-one in arrival
    order with staleness-scaled mixing:

        w <- (1 - alpha_k) w + alpha_k w'_k

    trained: stacked [m, ...]; order: [m] int arrival permutation;
    alphas: [m] effective mixing weight per client (0 for non-commits).
    Returns the post-merge global model.
    """
    def merge(g, idx):
        a = alphas[idx].astype(jnp.float32)
        def mix(gl, tr):
            upd = tr[idx].astype(jnp.float32)
            return ((1.0 - a) * gl.astype(jnp.float32) + a * upd).astype(gl.dtype)
        return jax.tree.map(mix, g, trained), None

    new_global, _ = jax.lax.scan(merge, global_w, order)
    return new_global


# ---------------------------------------------------------------------------
# Sparse (active-set) schedules: [k, K] index + role tensors instead of
# [k, m] masks
# ---------------------------------------------------------------------------
#
# At production scale only O(quota) of the m clients touch a round: the
# sync/committed/deprecated sets.  A sparse schedule stores, per round, the
# indices of that active set (padded to a fixed capacity K with the sentinel
# index m) plus a per-slot role bitmask.  Every numeric state change of the
# dense round is covered — picked and undrafted are subsets of committed,
# and rows outside sync|committed|deprecated keep their local/cache entries
# bit-for-bit — so the dense masks are exactly reconstructible.
#
# Two execution modes consume the same schedule:
#   * 'sparse' (exact): train only the K active rows, scatter them into the
#     dense stacks, then run the *identical* dense server trace
#     (``safa_server_step``/``fedavg_server_step``).  FLOPs of local
#     training — the dominant cost — drop from O(m·train) to O(K·train);
#     memory stays O(m·N) for the carried state.  Bit-identical to dense.
#   * 'sparse_delta': update a carried running aggregate
#     ``agg = sum_k w_k cache_k`` from the K active rows only —
#     O(K·N) FLOPs per round, and for stateless protocols (FedAvg/FedCS)
#     no [m, N] buffer at all.  Equivalent to dense up to float summation
#     order (allclose, not bitwise).

# SAFA per-slot role bits (a slot may carry several: picked implies
# committed, deprecated clients are also synced, ...)
ROLE_SYNC = 1
ROLE_COMMITTED = 2
ROLE_PICKED = 4
ROLE_UNDRAFTED = 8
ROLE_DEPRECATED = 16

# synchronous-protocol (FedAvg/FedCS) role bits
SROLE_SELECTED = 1
SROLE_COMPLETED = 2


class SparseRoundSchedule(NamedTuple):
    """SAFA sparse per-round schedule: ``idx`` [k, K] int32 active-set row
    indices (sentinel m pads unused slots), ``roles`` [k, K] uint8 ROLE_*
    bitmasks, ``round_idx`` [k]."""
    idx: Any
    roles: Any
    round_idx: Any


class SparseSyncSchedule(NamedTuple):
    """FedAvg/FedCS sparse per-round schedule: ``idx`` [k, K] int32 selected
    row indices (sentinel m), ``roles`` [k, K] uint8 SROLE_* bitmasks,
    ``round_idx`` [k]."""
    idx: Any
    roles: Any
    round_idx: Any


class TierRoundSchedule(NamedTuple):
    """SAFA lag-tier per-round schedule: the sparse ``idx``/``roles``
    tensors plus [k, K] buffer-slot maps into the single value buffer the
    tier engines carry (``schedules.build_tier_schedule``).  ``base_src``/
    ``cache_src`` name the slots holding each active client's base model
    and cache row; ``cache_dst`` the slot its new cache row lands in
    (scratch == discard); ``global_dst`` [k] the slot the round's output
    global is recorded in."""
    idx: Any
    roles: Any
    base_src: Any
    cache_src: Any
    cache_dst: Any
    global_dst: Any
    round_idx: Any


def has_role(roles, bit):
    """Per-slot bool mask for one ROLE_*/SROLE_* bit."""
    return (roles & bit) != 0


def scatter_masks(idx, roles, m: int, bits):
    """Reconstruct dense [m] bool masks from one round's (idx, roles).

    Sentinel slots (idx == m) are dropped; returns one mask per bit in
    ``bits``, bit-equal to the dense precompute's masks."""
    return tuple(
        jnp.zeros((m,), bool).at[idx].set(has_role(roles, b), mode='drop')
        for b in bits)


def tree_gather(tree, idx):
    """Gather rows of every [m, ...] leaf.  Out-of-range (sentinel) indices
    clamp under jit — gathered padding rows are garbage by contract and
    must be masked by the caller's role bits."""
    return jax.tree.map(lambda a: a[idx], tree)


def tree_scatter(tree, idx, rows):
    """Scatter [K, ...] rows back into [m, ...] leaves; sentinel slots
    (idx == m) are dropped, all other rows are overwritten."""
    return jax.tree.map(lambda a, r: a.at[idx].set(r, mode='drop'),
                        tree, rows)


def _slot_weights(idx, weights):
    """Aggregation weight per slot, 0 at sentinel slots."""
    valid = idx < weights.shape[0]
    return jnp.where(valid, weights[idx], 0.0).astype(jnp.float32)


def init_aggregate(cache, weights):
    """The running aggregate carried by sparse_delta engines:
    ``agg = sum_k w_k cache_k`` as an f32 tree of global-shaped leaves.
    Computed once at run start from the dense cache; each round then
    adjusts it from the active rows only."""
    def red(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return jnp.sum(leaf.astype(jnp.float32) * w, axis=0)
    return jax.tree.map(red, cache)


def safa_round_sparse(global_w, local_w, cache, *, idx, roles, weights,
                      local_train_fn, train_args=(), use_kernel=False,
                      wire: str = 'f32'):
    """One SAFA round from a sparse schedule, bit-identical to
    ``safa_round`` on the dense masks that (idx, roles) encode.

    Only the K active rows are trained —
    ``local_train_fn(base_rows, rows, *train_args)`` is the rows-train
    contract (``Task.local_train_rows``) — then the raw trained rows are
    scattered over the dense base stack and the identical dense server
    trace runs.  Returns (new_global, new_local, new_cache)."""
    check_wire(wire)
    m = weights.shape[0]
    sync_mask, completed, picked, undrafted, deprecated = scatter_masks(
        idx, roles, m, (ROLE_SYNC, ROLE_COMMITTED, ROLE_PICKED,
                        ROLE_UNDRAFTED, ROLE_DEPRECATED))
    base = distribute(global_w, local_w, sync_mask)
    base_rows = tree_gather(base, idx)
    trained_rows = local_train_fn(base_rows, idx, *train_args)
    trained = tree_scatter(base, idx, trained_rows)
    return safa_server_step(
        base, trained, cache, global_w, completed=completed, picked=picked,
        undrafted=undrafted, deprecated=deprecated, weights=weights,
        use_kernel=use_kernel, wire=wire)


def safa_round_sparse_delta(global_w, local_w, cache, agg, *, idx, roles,
                            weights, local_train_fn, train_args=(),
                            wire: str = 'f32'):
    """One SAFA round in O(K·N): Eq. 6-8 as deltas on the carried running
    aggregate ``agg = sum_k w_k cache_k``.

        new_global = agg + sum_slots w (c1 - c_old)      (Eq. 6+7)
        new_agg    = new_global + sum_slots w (c2 - c1)  (Eq. 8)

    Only active cache/local rows are gathered, trained, and scattered
    back; no [m, N] intermediate is formed.  Equivalent to the dense round
    up to float summation order.  Returns (new_global, new_local,
    new_cache, new_agg)."""
    check_wire(wire)
    k = idx.shape[0]
    sync_r = has_role(roles, ROLE_SYNC)
    com_r = has_role(roles, ROLE_COMMITTED)
    pick_r = has_role(roles, ROLE_PICKED)
    und_r = has_role(roles, ROLE_UNDRAFTED)
    dep_r = has_role(roles, ROLE_DEPRECATED)
    g_rows = broadcast_global(global_w, k)
    base_rows = masked_select(sync_r, g_rows, tree_gather(local_w, idx))
    trained_rows = local_train_fn(base_rows, idx, *train_args)
    if wire == 'int8':
        from repro.kernels import ops as kops
        trained_rows = kops.wire_roundtrip_packed(trained_rows, like=global_w)
    trained_rows = masked_select(com_r, trained_rows, base_rows)
    c_rows = tree_gather(cache, idx)
    w_rows = _slot_weights(idx, weights)

    def delta(a, new, old):
        w = w_rows.reshape((-1,) + (1,) * (new.ndim - 1))
        return a + jnp.sum(
            (new.astype(jnp.float32) - old.astype(jnp.float32)) * w, axis=0)

    # Eq. 6 on the active rows only
    c1_rows = masked_select(dep_r & ~pick_r, g_rows, c_rows)
    c1_rows = masked_select(pick_r, trained_rows, c1_rows)
    # Eq. 7: the full weighted sum moves by the rows that changed
    agg1 = jax.tree.map(delta, agg, c1_rows, c_rows)
    new_global = jax.tree.map(lambda a, g: a.astype(g.dtype), agg1, global_w)
    # Eq. 8: undrafted arrivals enter the cache for the next round
    c2_rows = masked_select(und_r, trained_rows, c1_rows)
    new_agg = jax.tree.map(delta, agg1, c2_rows, c1_rows)
    new_cache = tree_scatter(cache, idx, c2_rows)
    new_local = tree_scatter(local_w, idx, trained_rows)
    return new_global, new_local, new_cache, new_agg


def fedavg_round_sparse(global_w, local_w, *, idx, roles, weights,
                        local_train_fn, train_args=(), wire: str = 'f32'):
    """FedAvg round from a sparse schedule, bit-identical to
    ``fedavg_round``: train the selected rows only, scatter, then run the
    dense server trace.  Returns (new_global, new_local)."""
    check_wire(wire)
    m = weights.shape[0]
    selected, completed = scatter_masks(
        idx, roles, m, (SROLE_SELECTED, SROLE_COMPLETED))
    base = distribute(global_w, local_w, selected)
    base_rows = tree_gather(base, idx)
    trained_rows = local_train_fn(base_rows, idx, *train_args)
    trained = tree_scatter(base, idx, trained_rows)
    return fedavg_server_step(base, trained, global_w, selected=selected,
                              completed=completed, weights=weights, wire=wire)


def fedavg_round_sparse_delta(global_w, *, idx, roles, weights,
                              local_train_fn, train_args=(),
                              wire: str = 'f32'):
    """Stateless O(K·N) FedAvg round: selected clients always sync to the
    global model, and a client's local model never feeds back into the
    aggregate (it is overwritten by the sync on its next selection), so no
    [m, N] local stack needs to exist at all — the only carried state is
    the global model.  Equivalent to the dense round up to float summation
    order.  Returns new_global."""
    check_wire(wire)
    k = idx.shape[0]
    com_r = has_role(roles, SROLE_COMPLETED) & (idx < weights.shape[0])
    base_rows = broadcast_global(global_w, k)
    trained_rows = local_train_fn(base_rows, idx, *train_args)
    if wire == 'int8':
        from repro.kernels import ops as kops
        trained_rows = kops.wire_roundtrip_packed(trained_rows, like=global_w)
    w_rows = jnp.where(com_r, _slot_weights(idx, weights), 0.0)
    wsum = jnp.maximum(jnp.sum(w_rows), 1e-12)
    eff_w = w_rows / wsum
    any_ok = jnp.sum(com_r) > 0

    def red(t, g):
        w = eff_w.reshape((-1,) + (1,) * (t.ndim - 1))
        agg = jnp.sum(t.astype(jnp.float32) * w, axis=0)
        return jnp.where(any_ok, agg, g.astype(jnp.float32)).astype(g.dtype)

    return jax.tree.map(red, trained_rows, global_w)


# -- sparse scan/fleet engines ----------------------------------------------

def _safa_sparse_scan(global_w, local_w, cache, schedule, weights,
                      local_train_fn, use_kernel, wire='f32'):
    def step(carry, sched):
        g, l, c = carry
        out = safa_round_sparse(
            g, l, c, idx=sched.idx, roles=sched.roles, weights=weights,
            local_train_fn=local_train_fn, train_args=(sched.round_idx,),
            use_kernel=use_kernel, wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (global_w, local_w, cache), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_scan_sparse(global_w, local_w, cache,
                         schedule: SparseRoundSchedule, weights, *,
                         local_train_fn, use_kernel=False, wire='f32'):
    """Sparse-schedule counterpart of ``safa_run_scan``.  Bit-identical to
    the dense scan on the masks the schedule encodes; local training runs
    over the K active rows only.  ``local_train_fn`` follows the
    rows-train contract (``Task.local_train_rows``)."""
    return _safa_sparse_scan(global_w, local_w, cache, schedule, weights,
                             local_train_fn, use_kernel, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def safa_run_fleet_sparse(global_w, local_w, cache,
                          schedule: SparseRoundSchedule, weights, *,
                          local_train_fn, use_kernel=False, wire='f32'):
    """S sparse SAFA simulations in one vmapped scan (schedule fields
    [S, k, K], carry fleet-stacked and donated), per-member bit-identical
    to ``safa_run_scan_sparse``."""
    run = lambda g, l, c, s, w: _safa_sparse_scan(
        g, l, c, s, w, local_train_fn, use_kernel, wire)
    return jax.vmap(run)(global_w, local_w, cache, schedule, weights)


def _safa_sparse_delta_scan(global_w, local_w, cache, agg, schedule, weights,
                            local_train_fn, wire='f32'):
    def step(carry, sched):
        out = safa_round_sparse_delta(
            *carry, idx=sched.idx, roles=sched.roles, weights=weights,
            local_train_fn=local_train_fn, train_args=(sched.round_idx,),
            wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (global_w, local_w, cache, agg), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=('local_train_fn', 'wire'))
def safa_run_scan_sparse_delta(global_w, local_w, cache, agg,
                               schedule: SparseRoundSchedule, weights, *,
                               local_train_fn, wire='f32'):
    """O(K·N)-per-round SAFA scan: carries (global, local, cache, agg) with
    ``agg = init_aggregate(cache, weights)`` at entry.  Allclose- (not
    bit-) equivalent to the dense scan."""
    return _safa_sparse_delta_scan(global_w, local_w, cache, agg, schedule,
                                   weights, local_train_fn, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=('local_train_fn', 'wire'))
def safa_run_fleet_sparse_delta(global_w, local_w, cache, agg,
                                schedule: SparseRoundSchedule, weights, *,
                                local_train_fn, wire='f32'):
    """Fleet counterpart of ``safa_run_scan_sparse_delta`` (one vmapped
    scan, [S, ...] carry donated)."""
    run = lambda g, l, c, a, s, w: _safa_sparse_delta_scan(
        g, l, c, a, s, w, local_train_fn, wire)
    return jax.vmap(run)(global_w, local_w, cache, agg, schedule, weights)


def _fedavg_sparse_scan(global_w, local_w, schedule, weights, local_train_fn,
                        wire='f32'):
    def step(carry, sched):
        g, l = carry
        ng, nl = fedavg_round_sparse(
            g, l, idx=sched.idx, roles=sched.roles, weights=weights,
            local_train_fn=local_train_fn, train_args=(sched.round_idx,),
            wire=wire)
        return (ng, nl), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_scan_sparse(global_w, local_w, schedule: SparseSyncSchedule,
                           weights, *, local_train_fn, wire='f32'):
    """Sparse-schedule counterpart of ``fedavg_run_scan`` (bit-identical to
    the dense scan; trains the selected rows only)."""
    return _fedavg_sparse_scan(global_w, local_w, schedule, weights,
                               local_train_fn, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_fleet_sparse(global_w, local_w, schedule: SparseSyncSchedule,
                            weights, *, local_train_fn, wire='f32'):
    """S sparse FedAvg/FedCS simulations in one vmapped scan."""
    run = lambda g, l, s, w: _fedavg_sparse_scan(g, l, s, w, local_train_fn,
                                                 wire)
    return jax.vmap(run)(global_w, local_w, schedule, weights)


def _fedavg_sparse_delta_scan(global_w, schedule, weights, local_train_fn,
                              wire='f32'):
    def step(g, sched):
        ng = fedavg_round_sparse_delta(
            g, idx=sched.idx, roles=sched.roles, weights=weights,
            local_train_fn=local_train_fn, train_args=(sched.round_idx,),
            wire=wire)
        return ng, None

    carry, _ = jax.lax.scan(step, global_w, schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_scan_sparse_delta(global_w, schedule: SparseSyncSchedule,
                                 weights, *, local_train_fn, wire='f32'):
    """Stateless FedAvg/FedCS scan: the global model is the whole carry —
    peak device memory is O(N + K·N), independent of m."""
    return _fedavg_sparse_delta_scan(global_w, schedule, weights,
                                     local_train_fn, wire)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=('local_train_fn', 'wire'))
def fedavg_run_fleet_sparse_delta(global_w, schedule: SparseSyncSchedule,
                                  weights, *, local_train_fn, wire='f32'):
    """Fleet counterpart of ``fedavg_run_scan_sparse_delta``."""
    run = lambda g, s, w: _fedavg_sparse_delta_scan(g, s, w, local_train_fn,
                                                    wire)
    return jax.vmap(run)(global_w, schedule, weights)


# -- packed sparse-delta engine: rows kernels on resident pack buffers ------

def safa_round_sparse_delta_packed(gbuf, lbuf, cbuf, abuf, *, idx, roles,
                                   weights, local_train_fn, train_args=(),
                                   spec, wire: str = 'f32'):
    """One O(K·N) SAFA round entirely on pack buffers, aggregation fused.

    gbuf [N] f32 global pack; lbuf/cbuf [m+1, N] local/cache packs (the
    trailing scratch row absorbs sentinel slots); abuf [N] f32 running
    aggregate.  Active rows move through ``ops.gather_rows`` -> unpack ->
    rows-train -> repack -> one ``safa_aggregate_packed_rows`` dispatch
    (Eq. 6-8 + both delta sums fused) -> ``ops.scatter_rows`` writes the
    cache/local rows back in place.  Under ``wire='int8'`` the repacked
    rows are block-quantised and the q8 rows kernel dequantises
    in-register (``spec`` must then be the QBLOCK-aligned ``wire_spec``).
    Allclose- (not bit-) equivalent to ``safa_round_sparse_delta`` — the
    kernel accumulates slot-by-slot over tiles instead of one tree-wide
    sum.  Returns (gbuf', lbuf', cbuf', abuf')."""
    check_wire(wire)
    from repro.kernels import ops as kops
    com_r = has_role(roles, ROLE_COMMITTED)
    pick_r = has_role(roles, ROLE_PICKED)
    und_r = has_role(roles, ROLE_UNDRAFTED)
    dep_r = has_role(roles, ROLE_DEPRECATED)
    sync_r = has_role(roles, ROLE_SYNC)
    w_rows = _slot_weights(idx, weights)
    l_rows = kops.gather_rows(lbuf, idx)
    base_rows = jnp.where(sync_r[:, None], gbuf[None].astype(lbuf.dtype),
                          l_rows)
    trained = kops.pack_stacked(
        local_train_fn(kops.unpack_stacked(base_rows, spec), idx,
                       *train_args), spec)
    if wire == 'int8':
        q, scales = kops.quantize_packed(trained)
        ng, na, c2_rows, local_rows = kops.safa_aggregate_packed_q8_rows(
            q, scales, base_rows, cbuf, gbuf, abuf, idx, pick_r, und_r,
            dep_r, com_r, w_rows)
    else:
        local_rows = jnp.where(com_r[:, None], trained, base_rows)
        ng, na, c2_rows = kops.safa_aggregate_packed_rows(
            cbuf, local_rows, gbuf, abuf, idx, pick_r, und_r, dep_r, w_rows)
    new_c = kops.scatter_rows(cbuf, idx, c2_rows.astype(cbuf.dtype))
    new_l = kops.scatter_rows(lbuf, idx, local_rows.astype(lbuf.dtype))
    return ng.astype(gbuf.dtype), new_l, new_c, na


def _safa_sparse_delta_packed_scan(gbuf, lbuf, cbuf, abuf, schedule, weights,
                                   local_train_fn, spec, wire='f32'):
    def step(carry, sched):
        out = safa_round_sparse_delta_packed(
            *carry, idx=sched.idx, roles=sched.roles, weights=weights,
            local_train_fn=local_train_fn, train_args=(sched.round_idx,),
            spec=spec, wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (gbuf, lbuf, cbuf, abuf), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=('local_train_fn', 'spec', 'wire'))
def safa_run_scan_sparse_delta_packed(gbuf, lbuf, cbuf, abuf,
                                      schedule: SparseRoundSchedule,
                                      weights, *, local_train_fn, spec,
                                      wire='f32'):
    """Packed-buffer counterpart of ``safa_run_scan_sparse_delta``: the
    carry is (global [N], local [m+1, N], cache [m+1, N], agg [N]) pack
    buffers and every round is gather + train + ONE fused rows dispatch +
    two in-place scatters.  ``spec`` is the (static) pack layout —
    ``ops.wire_spec`` under ``wire='int8'``, ``ops.pack_spec`` otherwise;
    callers pack once before and unpack once after the whole run."""
    return _safa_sparse_delta_packed_scan(gbuf, lbuf, cbuf, abuf, schedule,
                                          weights, local_train_fn, spec, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=('local_train_fn', 'spec', 'wire'))
def safa_run_fleet_sparse_delta_packed(gbuf, lbuf, cbuf, abuf,
                                       schedule: SparseRoundSchedule,
                                       weights, *, local_train_fn, spec,
                                       wire='f32'):
    """Fleet counterpart of ``safa_run_scan_sparse_delta_packed`` (one
    vmapped scan over [S, ...] pack buffers; the rows kernels batch under
    vmap into the same launches as their explicit ``*_fleet`` forms)."""
    run = lambda g, l, c, a, s, w: _safa_sparse_delta_packed_scan(
        g, l, c, a, s, w, local_train_fn, spec, wire)
    return jax.vmap(run)(gbuf, lbuf, cbuf, abuf, schedule, weights)


# -- lag-tier engine: version ring + active slab instead of [m, N] stacks ---
#
# SAFA's lag-tolerant distribution (Eq. 2-3) bounds every client's lag by
# tau, and a committed client is force-synced the next round it appears —
# so a trained local row is never read back, and every base model a round
# reads is a *global version snapshot* (at most tau+2 live at once).  Cache
# rows are such snapshots or commit rows of recently active clients.  The
# tier round therefore carries ONE value buffer ``buf`` of
# ``capacity + 1`` rows (capacity = peak live distinct rows, O(tau+quota);
# the trailing row is scratch) and replays the host-precomputed slot maps:
# gather bases at ``base_src``, caches at ``cache_src``, run the exact
# sparse_delta slot math, scatter the new cache rows to ``cache_dst`` and
# record the round's output global at ``global_dst``.  Per round the
# written slots are disjoint from the read slots (a value written in round
# t is first read strictly later), which lets the packed kernels alias the
# buffer in place.  Memory: O((tau+quota)·N), independent of m.

def safa_round_sparse_tier(global_w, buf, agg, *, idx, roles, base_src,
                           cache_src, cache_dst, global_dst, weights,
                           local_train_fn, train_args=(), wire: str = 'f32'):
    """One SAFA round in O((tau+quota)·N) via the lag-tier value buffer.

    Identical slot math to ``safa_round_sparse_delta`` — base/cache rows
    are simply gathered through the slot indirection instead of per-client
    stacks — so the two agree wherever both run (and both are equivalent
    to the dense round up to float summation order).  Returns
    (new_global, new_buf, new_agg)."""
    check_wire(wire)
    k = idx.shape[0]
    sync_r = has_role(roles, ROLE_SYNC)
    com_r = has_role(roles, ROLE_COMMITTED)
    pick_r = has_role(roles, ROLE_PICKED)
    und_r = has_role(roles, ROLE_UNDRAFTED)
    dep_r = has_role(roles, ROLE_DEPRECATED)
    g_rows = broadcast_global(global_w, k)
    base_rows = masked_select(sync_r, g_rows, tree_gather(buf, base_src))
    trained_rows = local_train_fn(base_rows, idx, *train_args)
    if wire == 'int8':
        from repro.kernels import ops as kops
        trained_rows = kops.wire_roundtrip_packed(trained_rows, like=global_w)
    trained_rows = masked_select(com_r, trained_rows, base_rows)
    c_rows = tree_gather(buf, cache_src)
    w_rows = _slot_weights(idx, weights)

    def delta(a, new, old):
        w = w_rows.reshape((-1,) + (1,) * (new.ndim - 1))
        return a + jnp.sum(
            (new.astype(jnp.float32) - old.astype(jnp.float32)) * w, axis=0)

    c1_rows = masked_select(dep_r & ~pick_r, g_rows, c_rows)
    c1_rows = masked_select(pick_r, trained_rows, c1_rows)
    agg1 = jax.tree.map(delta, agg, c1_rows, c_rows)
    new_global = jax.tree.map(lambda a, g: a.astype(g.dtype), agg1, global_w)
    c2_rows = masked_select(und_r, trained_rows, c1_rows)
    new_agg = jax.tree.map(delta, agg1, c2_rows, c1_rows)
    new_buf = tree_scatter(buf, cache_dst, c2_rows)
    new_buf = jax.tree.map(
        lambda b, g: b.at[global_dst].set(g.astype(b.dtype)), new_buf,
        new_global)
    return new_global, new_buf, new_agg


def _safa_sparse_tier_scan(global_w, buf, agg, schedule, weights,
                           local_train_fn, wire='f32'):
    def step(carry, sched):
        out = safa_round_sparse_tier(
            *carry, idx=sched.idx, roles=sched.roles,
            base_src=sched.base_src, cache_src=sched.cache_src,
            cache_dst=sched.cache_dst, global_dst=sched.global_dst,
            weights=weights, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,), wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (global_w, buf, agg), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'wire'))
def safa_run_scan_sparse_tier(global_w, buf, agg,
                              schedule: TierRoundSchedule, weights, *,
                              local_train_fn, wire='f32'):
    """Lag-tier SAFA scan: carries (global, value buffer, agg) with
    ``buf = broadcast(global)`` over capacity+1 rows and
    ``agg = global * sum(weights)`` at entry (every cache row starts as
    the init global).  Peak state is O((tau+quota)·N) — no [m, N] stack
    exists anywhere in the program."""
    return _safa_sparse_tier_scan(global_w, buf, agg, schedule, weights,
                                  local_train_fn, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'wire'))
def safa_run_fleet_sparse_tier(global_w, buf, agg,
                               schedule: TierRoundSchedule, weights, *,
                               local_train_fn, wire='f32'):
    """Fleet counterpart of ``safa_run_scan_sparse_tier`` (one vmapped
    scan; schedule fields [S, k, K], buffer [S, capacity+1, ...])."""
    run = lambda g, b, a, s, w: _safa_sparse_tier_scan(
        g, b, a, s, w, local_train_fn, wire)
    return jax.vmap(run)(global_w, buf, agg, schedule, weights)


def safa_round_sparse_tier_packed(gbuf, tbuf, abuf, *, idx, roles, base_src,
                                  cache_src, cache_dst, global_dst, weights,
                                  local_train_fn, train_args=(), spec,
                                  wire: str = 'f32'):
    """Packed-buffer lag-tier round: gbuf [N] f32, tbuf [capacity+1, N]
    value buffer, abuf [N] f32 running aggregate.  One fused tier-rows
    dispatch does Eq. 6-8, both delta sums, and the ``cache_dst`` scatter
    in place (the buffer aliases through the kernel); only the
    ``global_dst`` row write remains outside.  Returns
    (gbuf', tbuf', abuf')."""
    check_wire(wire)
    from repro.kernels import ops as kops
    sync_r = has_role(roles, ROLE_SYNC)
    com_r = has_role(roles, ROLE_COMMITTED)
    pick_r = has_role(roles, ROLE_PICKED)
    und_r = has_role(roles, ROLE_UNDRAFTED)
    dep_r = has_role(roles, ROLE_DEPRECATED)
    w_rows = _slot_weights(idx, weights)
    b_rows = kops.gather_rows(tbuf, base_src)
    base_rows = jnp.where(sync_r[:, None], gbuf[None].astype(tbuf.dtype),
                          b_rows)
    trained = kops.pack_stacked(
        local_train_fn(kops.unpack_stacked(base_rows, spec), idx,
                       *train_args), spec)
    if wire == 'int8':
        q, scales = kops.quantize_packed(trained)
        ng, na, new_t = kops.safa_aggregate_packed_q8_tier_rows(
            q, scales, base_rows, tbuf, gbuf, abuf, cache_src, cache_dst,
            pick_r, und_r, dep_r, com_r, w_rows)
    else:
        local_rows = jnp.where(com_r[:, None], trained, base_rows)
        ng, na, new_t = kops.safa_aggregate_packed_tier_rows(
            tbuf, local_rows, gbuf, abuf, cache_src, cache_dst, pick_r,
            und_r, dep_r, w_rows)
    new_t = new_t.at[global_dst].set(ng.astype(new_t.dtype))
    return ng.astype(gbuf.dtype), new_t, na


def _safa_sparse_tier_packed_scan(gbuf, tbuf, abuf, schedule, weights,
                                  local_train_fn, spec, wire='f32'):
    def step(carry, sched):
        out = safa_round_sparse_tier_packed(
            *carry, idx=sched.idx, roles=sched.roles,
            base_src=sched.base_src, cache_src=sched.cache_src,
            cache_dst=sched.cache_dst, global_dst=sched.global_dst,
            weights=weights, local_train_fn=local_train_fn,
            train_args=(sched.round_idx,), spec=spec, wire=wire)
        return out, None

    carry, _ = jax.lax.scan(step, (gbuf, tbuf, abuf), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'spec', 'wire'))
def safa_run_scan_sparse_tier_packed(gbuf, tbuf, abuf,
                                     schedule: TierRoundSchedule, weights,
                                     *, local_train_fn, spec, wire='f32'):
    """Packed counterpart of ``safa_run_scan_sparse_tier``: the whole run
    is one scanned program whose carry is three pack buffers totalling
    O((tau+quota)·N) bytes."""
    return _safa_sparse_tier_packed_scan(gbuf, tbuf, abuf, schedule,
                                         weights, local_train_fn, spec, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2),
                   static_argnames=('local_train_fn', 'spec', 'wire'))
def safa_run_fleet_sparse_tier_packed(gbuf, tbuf, abuf,
                                      schedule: TierRoundSchedule, weights,
                                      *, local_train_fn, spec, wire='f32'):
    """Fleet counterpart of ``safa_run_scan_sparse_tier_packed`` (one
    vmapped scan; the tier-rows kernels batch under vmap)."""
    run = lambda g, t, a, s, w: _safa_sparse_tier_packed_scan(
        g, t, a, s, w, local_train_fn, spec, wire)
    return jax.vmap(run)(gbuf, tbuf, abuf, schedule, weights)


def fedasync_round(global_w, local_w, *, committed, order, alphas,
                   local_train_fn, train_args=()):
    """One full numeric FedAsync round: every client trains, crashed/late
    clients are masked out, the server merges the arrivals one-by-one
    (``fedasync_merge``), and committed clients pull the fresh global
    model.  Shared by the per-round loop engine and the scan body so the
    two stay step-identical.  Returns (new_global, new_local)."""
    m = committed.shape[0]
    trained = local_train_fn(local_w, *train_args)
    trained = masked_select(committed, trained, local_w)
    new_global = fedasync_merge(global_w, trained, order=order, alphas=alphas)
    # committed clients pull the fresh global model
    new_local = masked_select(committed, broadcast_global(new_global, m),
                              masked_select(committed, trained, local_w))
    return new_global, new_local


# ---------------------------------------------------------------------------
# Weighted-merge engine: the staleness-adaptive aggregation family
# ---------------------------------------------------------------------------
#
# SEAFL-style adaptive weighting, CSAFL-style per-cluster semi-async
# aggregation, and (via an exact host-side fold of the sequential merge
# recursion) the FedAsync s(dt) discount family all lower to one schedule
# representation: a precomputed [rounds, m] weight row ``wrow`` with
#
#     new_global = (1 - sum(wrow)) * global + sum_k wrow[k] * trained_k
#
# The row is zero off the committed set, so one round body — and therefore
# one scan/fleet engine — replays every scheme in the family.  Cluster
# structure (CSAFL) folds in host-side: wrow[k] = alpha_g * what_k where
# alpha_g is cluster g's mixing coefficient and what_k the intra-cluster
# weight, so the kernel path below computes the masked per-cluster
# sub-aggregates implicitly through the weight operand.

class WeightedSchedule(NamedTuple):
    """Weighted-merge per-round schedule, stacked [k, m]: the commit mask
    and the precomputed per-client merge weights (0 for non-commits)."""
    committed: Any
    wrow: Any
    round_idx: Any


def weighted_merge(global_w, trained, *, wrow, use_kernel=False):
    """One-shot weighted server merge:

        w <- (1 - sum_k wrow_k) w + sum_k wrow_k w'_k

    trained: stacked [m, ...]; wrow: [m] f32 effective merge weight per
    client (0 for non-commits; sum(wrow) <= 1).  ``use_kernel='packed'``
    routes the fused single-dispatch Pallas path
    (``ops.weighted_merge_tree_packed``).  Returns the post-merge global
    model."""
    if use_kernel == 'packed':
        from repro.kernels import ops as kops
        return kops.weighted_merge_tree_packed(trained, global_w, wrow=wrow)
    residual = (1.0 - jnp.sum(wrow)).astype(jnp.float32)

    def mix(g, t):
        w = wrow.reshape((-1,) + (1,) * (t.ndim - 1)).astype(jnp.float32)
        agg = jnp.sum(t.astype(jnp.float32) * w, axis=0)
        return (residual * g.astype(jnp.float32) + agg).astype(g.dtype)

    return jax.tree.map(mix, global_w, trained)


@functools.partial(jax.jit, static_argnames=('local_train_fn', 'use_kernel',
                                             'wire'))
def weighted_round(global_w, local_w, *, committed, wrow, local_train_fn,
                   train_args=(), use_kernel=False, wire: str = 'f32'):
    """One full numeric weighted-merge round: every client trains from its
    local model, crashed/late clients are masked out, the server applies
    the precomputed weight row in ONE batched merge, and committed clients
    pull the fresh global model (non-commits keep training on their stale
    copy — that is what makes the precomputed staleness meaningful).

    Jitted (unlike the sequential-merge rounds, whose float math all sits
    inside an inner ``lax.scan`` and therefore always compiles): the
    one-shot merge is plain elementwise math, and the loop engine must
    execute the same compiled expressions as the scan body or the two
    drift by an fma contraction.

    ``wire='int8'`` round-trips the uploads through the packed int8 wire
    (``ops.wire_roundtrip_packed``) before the merge — the server merges
    what a compressed transfer actually delivers; non-committed clients
    never upload, so their local state stays un-quantised.  Returns
    (new_global, new_local)."""
    check_wire(wire)
    m = committed.shape[0]
    trained = local_train_fn(local_w, *train_args)
    trained = masked_select(committed, trained, local_w)
    uploads = trained
    if wire == 'int8':
        from repro.kernels import ops as kops
        uploads = kops.wire_roundtrip_packed(trained, like=global_w)
    new_global = weighted_merge(global_w, uploads, wrow=wrow,
                                use_kernel=use_kernel)
    new_local = masked_select(committed, broadcast_global(new_global, m),
                              trained)
    return new_global, new_local


def _weighted_scan(global_w, local_w, schedule, local_train_fn, use_kernel,
                   wire='f32', train_extra=()):
    def step(carry, sched):
        g, l = carry
        return weighted_round(
            g, l, committed=sched.committed, wrow=sched.wrow,
            local_train_fn=local_train_fn,
            train_args=(sched.round_idx,) + tuple(train_extra),
            use_kernel=use_kernel, wire=wire), None

    carry, _ = jax.lax.scan(step, (global_w, local_w), schedule)
    return carry


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def weighted_run_scan(global_w, local_w, schedule: WeightedSchedule,
                      weights=None, *, local_train_fn, use_kernel=False,
                      wire='f32'):
    """Weighted-merge counterpart of ``safa_run_scan``: k rounds in one
    dispatch with the (global, local) carry donated.  The whole
    aggregation scheme lives in the schedule's [k, m] weight rows, so
    every scheme in the staleness-adaptive family compiles to this same
    program.  ``weights`` is accepted for signature parity and ignored
    (the merge weights live in the schedule)."""
    del weights
    return _weighted_scan(global_w, local_w, schedule, local_train_fn,
                          use_kernel, wire)


@functools.partial(jax.jit, donate_argnums=(0, 1),
                   static_argnames=('local_train_fn', 'use_kernel', 'wire'))
def weighted_run_fleet(global_w, local_w, schedule: WeightedSchedule,
                       weights=None, *, local_train_fn, use_kernel=False,
                       wire='f32', train_ctx=None):
    """S weighted-merge simulations (schedule fields [S, k, m]) in one
    vmapped scan with the fleet-stacked (global, local) carry donated.
    Members may replay *different* schemes of the family (SEAFL, CSAFL,
    folded FedAsync discounts) — the scheme is data, not trace.  Under
    ``use_kernel='packed'`` the per-round merge kernel vmaps into a
    batched-grid launch.  ``train_ctx``: per-member train context, as in
    ``safa_run_fleet``."""
    del weights
    if train_ctx is None:
        run = lambda g, l, s: _weighted_scan(g, l, s, local_train_fn,
                                             use_kernel, wire)
        return jax.vmap(run)(global_w, local_w, schedule)
    run = lambda g, l, s, ctx: _weighted_scan(g, l, s, local_train_fn,
                                              use_kernel, wire,
                                              train_extra=(ctx,))
    return jax.vmap(run)(global_w, local_w, schedule, train_ctx)
