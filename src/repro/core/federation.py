"""Federation orchestrators: SAFA / FedAvg / FedCS / fully-local.

The orchestrator owns the *protocol* state machine (versions, commit flags,
pending straggler progress) in numpy, drives the event simulator for
timing/crash draws, and (optionally, ``numeric=True``) executes the model
math via the jit-able mask algebra in ``repro.core.protocol``.

Timing-only mode (``numeric=False``) reproduces the paper's round-length /
T_dist / SR / futility tables at full scale without touching model weights —
those metrics depend only on the event process, exactly as in the paper.

Because the event process never looks at model weights, every per-round mask
is known before the first gradient step: ``precompute_safa_schedule`` /
``precompute_sync_schedule`` run the whole state machine in one cheap host
pass and emit [rounds, m] mask schedules.  The numeric run then picks an
*engine*:

* ``engine='scan'`` (default) — the entire span between eval points runs as
  a single ``jax.lax.scan`` dispatch with the (global, local, cache) carry
  donated (``protocol.safa_run_scan`` / ``protocol.fedavg_run_scan``);
* ``engine='loop'`` — the seed's per-round Python loop, kept as the
  reference mode (one dispatch per op per round, masks shuttled
  host->device every round); bit-identical to the scanned engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import protocol, selection
from repro.fedsim import FLEnv


@dataclasses.dataclass
class RoundRecord:
    round: int
    round_len: float
    t_dist: float
    eur: float
    sr: float
    vv: float
    n_picked: int
    n_committed: int
    n_crashed: int
    eval: Optional[dict] = None


@dataclasses.dataclass
class History:
    protocol: str
    records: list = dataclasses.field(default_factory=list)
    futility: float = 0.0
    best_eval: Optional[dict] = None
    final_global: Any = None

    def mean(self, field: str) -> float:
        return float(np.mean([getattr(r, field) for r in self.records]))

    def evals(self):
        return [(r.round, r.eval) for r in self.records if r.eval is not None]


class Task:
    """A federated learning task: model init/train/eval, model-agnostic for
    the protocol layer.  ``local_train(stacked_params, round_idx)`` must
    train every client replica for E epochs (vmapped inside).

    ``round_idx`` is a Python int under ``engine='loop'`` but a traced
    int32 scalar under the default scanned engine — implementations must
    not branch on it in Python (use ``jnp.where``/``lax.cond`` if the
    round number matters)."""

    def init_global(self, key):
        raise NotImplementedError

    def local_train(self, stacked_params, round_idx):
        raise NotImplementedError

    def evaluate(self, global_params) -> dict:
        raise NotImplementedError


def _to_j(mask: np.ndarray):
    return jnp.asarray(mask)


class _NumericState:
    def __init__(self, task: Task, m: int, seed: int):
        key = jax.random.PRNGKey(seed)
        self.global_w = task.init_global(key)
        self.local_w = protocol.broadcast_global(self.global_w, m)
        self.cache = protocol.broadcast_global(self.global_w, m)


@dataclasses.dataclass
class SafaSchedule:
    """Precomputed SAFA event process: [rounds, m] bool mask schedules plus
    the timing records they imply.  Independent of model weights."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.sync.shape[0]

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole run."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def precompute_safa_schedule(env: FLEnv, *, fraction: float,
                             lag_tolerance: int, rounds: int) -> SafaSchedule:
    """Run the SAFA timing/event state machine (Eq. 3 version bookkeeping,
    crash draws, CFCFM selection) for all rounds in one numpy host pass.

    The event process never reads model weights, so the full [rounds, m]
    mask schedule — and every timing metric — is known up front.  Consumes
    ``env``'s rng exactly as the seed's round-by-round loop did.
    """
    m = env.m
    v = np.zeros(m, dtype=int)             # base-model versions
    committed_prev = np.ones(m, bool)      # round 1: everyone holds w(0)
    picked_prev = np.zeros(m, bool)
    pending = np.zeros(m)                  # straggler partial progress (fraction)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs      # per-round work units
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    masks = {k: np.zeros((rounds, m), bool)
             for k in ('sync', 'committed', 'picked', 'undrafted',
                       'deprecated')}
    records = []

    for t in range(1, rounds + 1):
        gv = t - 1
        up, dep, _ = protocol.classify_versions(v, gv, lag_tolerance,
                                                committed_prev)
        sync = up | dep
        # forced sync discards any pending straggler progress (futility)
        wasted += float(np.sum(pending[sync] * work[sync]))
        pending[sync] = 0.0
        v[sync] = gv

        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        remaining = 1.0 - pending
        t_train = remaining * full_tt
        t_dist = env.t_dist(int(sync.sum()))
        arrival = t_dist + env.t_updown * (1 + sync.astype(float)) + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += float(np.sum(np.where(completed, remaining,
                                           cfrac * remaining) * work))
        base_versions = v.copy()

        sel = selection.cfcfm(arrival, completed, picked_prev, fraction, env.t_lim)
        pending = np.where(crashed, np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending[sel.committed] = 0.0
        v[sel.committed] = t

        i = t - 1
        masks['sync'][i] = sync
        masks['committed'][i] = sel.committed
        masks['picked'][i] = sel.picked
        masks['undrafted'][i] = sel.undrafted
        masks['deprecated'][i] = dep

        trained_v = base_versions[sel.committed]
        records.append(RoundRecord(
            round=t,
            round_len=min(env.t_lim, sel.quota_met_time),
            t_dist=t_dist,
            eur=float(sel.picked.sum()) / m,
            sr=float(sync.sum()) / m,
            vv=float(np.var(trained_v)) if trained_v.size else 0.0,
            n_picked=int(sel.picked.sum()),
            n_committed=int(sel.committed.sum()),
            n_crashed=int(crashed.sum()),
        ))
        committed_prev = sel.committed.copy()
        picked_prev = sel.picked.copy()

    return SafaSchedule(records=records,
                        futility=wasted / max(performed, 1e-9), **masks)


def _quantized_train_fn(base_fn):
    """int8-compressed uplink (beyond-paper; comm_quant kernel): the server
    sees the dequantised client update, exactly as a real compressed
    transfer would deliver it.  The wrapper is memoised on the owning Task
    so it stays a stable static argument to ``safa_run_scan`` (a fresh
    closure per run would retrace the whole scanned program) without
    pinning Tasks beyond their own lifetime."""
    def train_fn(stacked, *args):
        from repro.kernels import ops as kops
        trained = base_fn(stacked, *args)
        return kops.dequantize_tree(kops.quantize_tree(trained), trained)

    owner = getattr(base_fn, '__self__', None)
    if owner is None:
        return train_fn
    cached = getattr(owner, '_quantized_train_fn', None)
    if cached is None:
        owner._quantized_train_fn = cached = train_fn
    return cached


def _eval_rounds(rounds: int, eval_every: int):
    """Rounds at which the orchestrators evaluate the global model.

    These are also the scan-engine segment boundaries: at most two distinct
    segment lengths exist per run (eval_every and a ragged final remainder),
    so the scanned program traces at most twice."""
    stops = sorted(set(range(eval_every, rounds + 1, eval_every)) | {rounds})
    return [t for t in stops if t >= 1]


def _record_eval(hist: History, rec: RoundRecord, task: Task, global_w):
    rec.eval = task.evaluate(global_w)
    if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
        hist.best_eval = rec.eval


def run_safa(task: Optional[Task], env: FLEnv, *, fraction: float,
             lag_tolerance: int, rounds: int, eval_every: int = 10,
             numeric: bool = True, use_kernel=False,
             quantize_uploads: bool = False, seed: int = 0,
             engine: str = 'scan') -> History:
    m = env.m
    sched = precompute_safa_schedule(env, fraction=fraction,
                                     lag_tolerance=lag_tolerance,
                                     rounds=rounds)
    hist = History('safa', records=sched.records, futility=sched.futility)
    if not numeric:
        return hist

    ns = _NumericState(task, m, seed)
    weights = jnp.asarray(env.weights)
    train_fn = _quantized_train_fn(task.local_train) if quantize_uploads \
        else task.local_train

    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        dev = sched.to_device()
        start = 0
        for stop in evals:
            seg = jax.tree.map(lambda a: a[start:stop], dev)
            ns.global_w, ns.local_w, ns.cache = protocol.safa_run_scan(
                ns.global_w, ns.local_w, ns.cache, seg, weights,
                local_train_fn=train_fn, use_kernel=use_kernel)
            _record_eval(hist, sched.records[stop - 1], task, ns.global_w)
            start = stop
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.global_w, ns.local_w, ns.cache = protocol.safa_round(
                ns.global_w, ns.local_w, ns.cache,
                sync_mask=_to_j(sched.sync[i]),
                completed=_to_j(sched.committed[i]),
                picked=_to_j(sched.picked[i]),
                undrafted=_to_j(sched.undrafted[i]),
                deprecated=_to_j(sched.deprecated[i]), weights=weights,
                local_train_fn=train_fn, train_args=(t,),
                use_kernel=use_kernel)
            if t in evals:
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    hist.final_global = ns.global_w
    return hist


def _capped_round_len(arrival: np.ndarray, mask: np.ndarray,
                      t_lim: float) -> float:
    """Deadline-capped max arrival over ``mask``, ignoring non-finite
    entries; returns ``t_lim`` when nothing finite remains (e.g. every
    client crashed, arrival all inf) so inf never leaks into a
    RoundRecord."""
    live = arrival[mask]
    live = live[np.isfinite(live)]
    return min(t_lim, float(live.max())) if live.size else t_lim


def _sync_round_common(env: FLEnv, selected: np.ndarray, crashed: np.ndarray,
                       cfrac: np.ndarray, full_tt: np.ndarray):
    """Shared FedAvg/FedCS timing: server waits for every selected client;
    a crash is detected when the client drops (at its partial-progress
    point), so the round ends at max(finish/drop times), capped at T_lim."""
    t_dist = env.t_dist(int(selected.sum()))
    finish = t_dist + 2 * env.t_updown + full_tt
    drop = t_dist + env.t_updown + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    if selected.any():
        round_len = float(np.max(per_client[selected]))
    else:
        round_len = t_dist
    return min(env.t_lim, round_len), t_dist


@dataclasses.dataclass
class SyncSchedule:
    """Precomputed FedAvg/FedCS event process ([rounds, m] masks + records).
    ``completed`` is the per-round survivor mask (``~crashed``); the numeric
    round intersects it with ``selected`` itself."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.selected.shape[0]

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def precompute_sync_schedule(env: FLEnv, *, fraction: float, rounds: int,
                             seed: int, fedcs: bool) -> SyncSchedule:
    """Host pass for the synchronous baselines (selection + crash draws)."""
    m = env.m
    rng = np.random.default_rng(seed + 1)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    selected_s = np.zeros((rounds, m), bool)
    completed_s = np.zeros((rounds, m), bool)
    records = []

    for t in range(1, rounds + 1):
        if fedcs:
            est = 2 * env.t_updown + full_tt
            sel = selection.fedcs_select(est, fraction, env.t_lim)
        else:
            sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac, full_tt)
        # clients that cannot make the deadline are reckoned crashed (§III-B)
        too_slow = (t_dist + 2 * env.t_updown + full_tt) > env.t_lim
        crashed = crashed | too_slow
        completed = sel & ~crashed
        performed += float(np.sum(np.where(sel, np.where(crashed, cfrac, 1.0), 0.0) * work))
        wasted += float(np.sum((sel & crashed) * cfrac * work))

        selected_s[t - 1] = sel
        completed_s[t - 1] = ~crashed
        records.append(RoundRecord(
            round=t, round_len=round_len, t_dist=t_dist,
            eur=float(completed.sum()) / m,
            sr=float(sel.sum()) / m, vv=0.0,
            n_picked=int(completed.sum()), n_committed=int(completed.sum()),
            n_crashed=int(crashed.sum())))

    return SyncSchedule(selected=selected_s, completed=completed_s,
                        records=records,
                        futility=wasted / max(performed, 1e-9))


def run_fedavg(task: Optional[Task], env: FLEnv, *, fraction: float,
               rounds: int, eval_every: int = 10, numeric: bool = True,
               seed: int = 0, fedcs: bool = False,
               engine: str = 'scan') -> History:
    sched = precompute_sync_schedule(env, fraction=fraction, rounds=rounds,
                                     seed=seed, fedcs=fedcs)
    hist = History('fedcs' if fedcs else 'fedavg', records=sched.records,
                   futility=sched.futility)
    if not numeric:
        return hist

    ns = _NumericState(task, env.m, seed)
    weights = jnp.asarray(env.weights)
    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        dev = sched.to_device()
        start = 0
        for stop in evals:
            seg = jax.tree.map(lambda a: a[start:stop], dev)
            ns.global_w, ns.local_w = protocol.fedavg_run_scan(
                ns.global_w, ns.local_w, seg, weights,
                local_train_fn=task.local_train)
            _record_eval(hist, sched.records[stop - 1], task, ns.global_w)
            start = stop
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.global_w, ns.local_w = protocol.fedavg_round(
                ns.global_w, ns.local_w, selected=_to_j(sched.selected[i]),
                completed=_to_j(sched.completed[i]), weights=weights,
                local_train_fn=task.local_train, train_args=(t,))
            if t in evals:
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    hist.final_global = ns.global_w
    return hist


def run_fedcs(task, env, **kw) -> History:
    return run_fedavg(task, env, fedcs=True, **kw)


def run_local(task: Optional[Task], env: FLEnv, *, fraction: float,
              rounds: int, eval_every: int = 10, numeric: bool = True,
              seed: int = 0) -> History:
    """Fully-local baseline: C-fraction of clients train each round with no
    aggregation; a single weighted aggregation happens after the last round."""
    m = env.m
    hist = History('local')
    rng = np.random.default_rng(seed + 2)
    ns = _NumericState(task, m, seed) if numeric else None
    full_tt = env.full_train_time()

    for t in range(1, rounds + 1):
        sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = env.draw_round()
        completed = sel & ~crashed
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac, full_tt)
        if numeric:
            trained = task.local_train(ns.local_w, t)
            ns.local_w = protocol.masked_select(_to_j(completed), trained, ns.local_w)
        rec = RoundRecord(round=t, round_len=round_len, t_dist=0.0,
                          eur=0.0, sr=0.0, vv=0.0,
                          n_picked=0, n_committed=int(completed.sum()),
                          n_crashed=int(crashed.sum()))
        if numeric and (t % eval_every == 0 or t == rounds):
            gw = protocol.aggregate(ns.local_w, jnp.asarray(env.weights))
            _record_eval(hist, rec, task, gw)
        hist.records.append(rec)

    if numeric:
        hist.final_global = protocol.aggregate(ns.local_w, jnp.asarray(env.weights))
    hist.futility = 0.0
    return hist


def run_fedasync(task: Optional[Task], env: FLEnv, *, fraction: float = 1.0,
                 rounds: int = 100, eval_every: int = 10,
                 numeric: bool = True, alpha: float = 0.6,
                 staleness_exp: float = 0.5, seed: int = 0) -> History:
    """FedAsync baseline (Xie et al. [9], paper §II): every willing client
    trains every round and the server merges each arriving update
    immediately with staleness-polynomial mixing
    alpha_eff = alpha * (1 + staleness)^(-staleness_exp).

    ``fraction`` is ignored (fully asynchronous — the paper's critique is
    precisely that the server must absorb every update: SR == 1 and m
    model merges per virtual round).
    """
    del fraction
    m = env.m
    hist = History('fedasync')
    full_tt = env.full_train_time()
    versions = np.zeros(m, dtype=float)   # global version at last pull
    global_version = 0
    ns = _NumericState(task, m, seed) if numeric else None

    for t in range(1, rounds + 1):
        crashed, cfrac = env.draw_round()
        arrival = env.t_dist(m) + 2 * env.t_updown + full_tt
        arrival = np.where(~crashed, arrival, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        order = np.argsort(arrival, kind='stable')
        staleness = np.maximum(0.0, global_version - versions)
        alphas = np.where(committed,
                          alpha * (1.0 + staleness) ** (-staleness_exp), 0.0)

        if numeric:
            trained = task.local_train(ns.local_w, t)
            trained = protocol.masked_select(_to_j(committed), trained,
                                             ns.local_w)
            ns.global_w = protocol.fedasync_merge(
                ns.global_w, trained, order=jnp.asarray(order),
                alphas=jnp.asarray(alphas, jnp.float32))
            # committed clients pull the fresh global model
            ns.local_w = protocol.masked_select(
                _to_j(committed), protocol.broadcast_global(ns.global_w, m),
                protocol.masked_select(_to_j(committed), trained, ns.local_w))

        global_version += int(committed.sum())
        versions[committed] = global_version
        rec = RoundRecord(
            round=t,
            round_len=_capped_round_len(arrival, committed, env.t_lim),
            t_dist=env.t_dist(int(committed.sum())),
            eur=float(committed.sum()) / m,
            sr=1.0,  # every client syncs every round: max downlink pressure
            vv=float(np.var(staleness[committed])) if committed.any() else 0.0,
            n_picked=int(committed.sum()),
            n_committed=int(committed.sum()),
            n_crashed=int(crashed.sum()))
        if numeric and (t % eval_every == 0 or t == rounds):
            _record_eval(hist, rec, task, ns.global_w)
        hist.records.append(rec)

    if numeric:
        hist.final_global = ns.global_w
    return hist


PROTOCOLS = {
    'safa': run_safa,
    'fedavg': run_fedavg,
    'fedcs': run_fedcs,
    'local': run_local,
    'fedasync': run_fedasync,
}
