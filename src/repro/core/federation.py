"""Federation orchestrators: SAFA / FedAvg / FedCS / FedAsync / fully-local.

The orchestrator owns the *protocol* state machine (versions, commit flags,
pending straggler progress) in numpy, drives the event simulator for
timing/crash draws, and (optionally, ``numeric=True``) executes the model
math via the jit-able mask algebra in ``repro.core.protocol``.

Timing-only mode (``numeric=False``) reproduces the paper's round-length /
T_dist / SR / futility tables at full scale without touching model weights —
those metrics depend only on the event process, exactly as in the paper.

Because the event process never looks at model weights, every per-round mask
is known before the first gradient step: ``precompute_safa_schedule`` /
``precompute_sync_schedule`` run the whole state machine in one cheap host
pass and emit [rounds, m] mask schedules.  The numeric run then picks an
*engine*:

* ``engine='scan'`` (default) — the entire span between eval points runs as
  a single ``jax.lax.scan`` dispatch with the (global, local, cache) carry
  donated (``protocol.safa_run_scan`` / ``protocol.fedavg_run_scan``);
* ``engine='loop'`` — the seed's per-round Python loop, kept as the
  reference mode (one dispatch per op per round, masks shuttled
  host->device every round); bit-identical to the scanned engine.

Every runner in ``RUNNERS`` — SAFA, FedAvg, FedCS, fully-local and
FedAsync — has a schedule precompute and compiles to one scan dispatch per
eval segment; the per-round reference loops are kept as the bit-identical
``engine='loop'`` ground truth.

Because every paper result is a *sweep* (seeds x crash rates x lag
tolerances x fractions), schedules also stack fleet-major: ``FleetSchedule``
(and its sync/local/async counterparts) hold S independent event processes
as [S, rounds, m] mask tensors and ``run_sweep`` executes all S simulations
of any protocol in one ``jax.vmap``-over-scan dispatch
(``protocol.safa_run_fleet`` / ``fedavg_run_fleet`` / ``local_run_fleet`` /
``fedasync_run_fleet``), bit-identical per member to S sequential
``engine='scan'`` runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol, selection
from repro.fedsim import FLEnv


@dataclasses.dataclass
class RoundRecord:
    round: int
    round_len: float
    t_dist: float
    eur: float
    sr: float
    vv: float
    n_picked: int
    n_committed: int
    n_crashed: int
    eval: Optional[dict] = None


@dataclasses.dataclass
class History:
    protocol: str
    records: list = dataclasses.field(default_factory=list)
    futility: float = 0.0
    best_eval: Optional[dict] = None
    final_global: Any = None

    def mean(self, field: str) -> float:
        return float(np.mean([getattr(r, field) for r in self.records]))

    def evals(self):
        return [(r.round, r.eval) for r in self.records if r.eval is not None]


class Task:
    """A federated learning task: model init/train/eval, model-agnostic for
    the protocol layer.  ``local_train(stacked_params, round_idx)`` must
    train every client replica for E epochs (vmapped inside).

    ``round_idx`` is a Python int under ``engine='loop'`` but a traced
    int32 scalar under the default scanned engine — implementations must
    not branch on it in Python (use ``jnp.where``/``lax.cond`` if the
    round number matters)."""

    def init_global(self, key):
        raise NotImplementedError

    def local_train(self, stacked_params, round_idx):
        raise NotImplementedError

    def evaluate(self, global_params) -> dict:
        raise NotImplementedError


def _to_j(mask: np.ndarray):
    return jnp.asarray(mask)


class _NumericState:
    def __init__(self, task: Task, m: int, seed: int):
        key = jax.random.PRNGKey(seed)
        self.global_w = task.init_global(key)
        self.local_w = protocol.broadcast_global(self.global_w, m)
        self.cache = protocol.broadcast_global(self.global_w, m)


@dataclasses.dataclass
class SafaSchedule:
    """Precomputed SAFA event process: [rounds, m] bool mask schedules plus
    the timing records they imply.  Independent of model weights."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.sync.shape[0]

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole run."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def _masked_var(values: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Population variance of ``values`` over ``mask`` along the last axis
    (0.0 where the mask is empty).

    Formulated as masked sums so the single-run and fleet-major schedule
    precomputes reduce in the same order and agree bit for bit."""
    n = mask.sum(axis=-1)
    denom = np.maximum(n, 1)
    mean = np.sum(np.where(mask, values, 0), axis=-1) / denom
    dev = np.where(mask, (values - mean[..., None]) ** 2, 0.0)
    return np.where(n > 0, np.sum(dev, axis=-1) / denom, 0.0)


def precompute_safa_schedule(env: FLEnv, *, fraction: float,
                             lag_tolerance: int, rounds: int) -> SafaSchedule:
    """Run the SAFA timing/event state machine (Eq. 3 version bookkeeping,
    crash draws, CFCFM selection) for all rounds in one numpy host pass.

    The event process never reads model weights, so the full [rounds, m]
    mask schedule — and every timing metric — is known up front.  Consumes
    ``env``'s rng exactly as the seed's round-by-round loop did.
    """
    m = env.m
    v = np.zeros(m, dtype=int)             # base-model versions
    committed_prev = np.ones(m, bool)      # round 1: everyone holds w(0)
    picked_prev = np.zeros(m, bool)
    pending = np.zeros(m)                  # straggler partial progress (fraction)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs      # per-round work units
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    masks = {k: np.zeros((rounds, m), bool)
             for k in ('sync', 'committed', 'picked', 'undrafted',
                       'deprecated')}
    records = []

    for t in range(1, rounds + 1):
        gv = t - 1
        up, dep, _ = protocol.classify_versions(v, gv, lag_tolerance,
                                                committed_prev)
        sync = up | dep
        # forced sync discards any pending straggler progress (futility);
        # masked-sum form so the fleet-major precompute reduces identically
        wasted += float(np.sum(np.where(sync, pending * work, 0.0)))
        pending[sync] = 0.0
        v[sync] = gv

        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        remaining = 1.0 - pending
        t_train = remaining * full_tt
        t_dist = env.t_dist(int(sync.sum()))
        arrival = t_dist + env.t_updown * (1 + sync.astype(float)) + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += float(np.sum(np.where(completed, remaining,
                                           cfrac * remaining) * work))
        base_versions = v.copy()

        sel = selection.cfcfm(arrival, completed, picked_prev, fraction, env.t_lim)
        pending = np.where(crashed, np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending[sel.committed] = 0.0
        v[sel.committed] = t

        i = t - 1
        masks['sync'][i] = sync
        masks['committed'][i] = sel.committed
        masks['picked'][i] = sel.picked
        masks['undrafted'][i] = sel.undrafted
        masks['deprecated'][i] = dep

        records.append(RoundRecord(
            round=t,
            round_len=min(env.t_lim, sel.quota_met_time),
            t_dist=t_dist,
            eur=float(sel.picked.sum()) / m,
            sr=float(sync.sum()) / m,
            vv=float(_masked_var(base_versions, sel.committed)),
            n_picked=int(sel.picked.sum()),
            n_committed=int(sel.committed.sum()),
            n_crashed=int(crashed.sum()),
        ))
        committed_prev = sel.committed.copy()
        picked_prev = sel.picked.copy()

    return SafaSchedule(records=records,
                        futility=wasted / max(performed, 1e-9), **masks)


def _quantized_train_fn(base_fn):
    """int8-compressed uplink, per-leaf REFERENCE path (comm_quant kernel):
    each client quantises each leaf of its own update independently —
    exactly what a real compressed transfer carries — costing 2 pallas
    dispatches per leaf per client.  This is the bit-identity ground truth
    for the packed fast path (``wire='int8'``), which ships the same
    numbers in 2 dispatches total.

    The wrapper is memoised on the owning Task, keyed by the wrapped
    function, so it stays a stable static argument to ``safa_run_scan``
    (a fresh closure per run would retrace the whole scanned program)
    without pinning Tasks beyond their own lifetime — and without
    handing back a stale closure when a *different* bound method of the
    same Task gets wrapped later."""
    def train_fn(stacked, *args):
        from repro.kernels import ops as kops
        trained = base_fn(stacked, *args)

        def per_leaf(x):
            flat = x.reshape(x.shape[0], -1)
            rows = [kops.dequantize(*kops.quantize(flat[k]), n=flat.shape[1])
                    for k in range(flat.shape[0])]
            return jnp.stack(rows).reshape(x.shape)

        return jax.tree.map(per_leaf, trained)

    owner = getattr(base_fn, '__self__', None)
    if owner is None:
        return train_fn
    key = getattr(base_fn, '__func__', base_fn)
    cache = owner.__dict__.setdefault('_quantized_train_fns', {})
    if key not in cache:
        cache[key] = train_fn
    return cache[key]


def _eval_rounds(rounds: int, eval_every: int):
    """Rounds at which the orchestrators evaluate the global model.

    These are also the scan-engine segment boundaries: at most two distinct
    segment lengths exist per run (eval_every and a ragged final remainder),
    so the scanned program traces at most twice."""
    stops = sorted(set(range(eval_every, rounds + 1, eval_every)) | {rounds})
    return [t for t in stops if t >= 1]


def _record_eval(hist: History, rec: RoundRecord, task: Task, global_w):
    rec.eval = task.evaluate(global_w)
    if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
        hist.best_eval = rec.eval


def _scan_segments(task: Task, hist: History, ns: _NumericState, dev,
                   weights, records, evals, *, proto: str, local_train_fn,
                   use_kernel=False, wire='f32'):
    """Drive one numeric run through the scan engine: one donated-carry
    dispatch per eval segment.  Shared by every single-run orchestrator
    and ``run_sweep(engine='sequential')`` so they stay step-identical.

    ``proto`` picks the scanned round body; for ``'local'`` there is no
    global model in the carry, so the eval-point aggregation happens here
    (and lands in ``ns.global_w`` so the caller's final_global handling is
    uniform)."""
    start = 0
    for stop in evals:
        seg = jax.tree.map(lambda a: a[start:stop], dev)
        if proto == 'safa':
            ns.global_w, ns.local_w, ns.cache = protocol.safa_run_scan(
                ns.global_w, ns.local_w, ns.cache, seg, weights,
                local_train_fn=local_train_fn, use_kernel=use_kernel,
                wire=wire)
        elif proto in ('fedavg', 'fedcs'):
            ns.global_w, ns.local_w = protocol.fedavg_run_scan(
                ns.global_w, ns.local_w, seg, weights,
                local_train_fn=local_train_fn, wire=wire)
        elif proto == 'local':
            ns.local_w = protocol.local_run_scan(
                ns.local_w, seg, local_train_fn=local_train_fn)
            ns.global_w = protocol.aggregate(ns.local_w, weights)
        else:  # fedasync
            ns.global_w, ns.local_w = protocol.fedasync_run_scan(
                ns.global_w, ns.local_w, seg,
                local_train_fn=local_train_fn)
        _record_eval(hist, records[stop - 1], task, ns.global_w)
        start = stop


def run_safa(task: Optional[Task], env: FLEnv, *, fraction: float,
             lag_tolerance: int, rounds: int, eval_every: int = 10,
             numeric: bool = True, use_kernel=False,
             quantize_uploads: bool = False, seed: int = 0,
             engine: str = 'scan', wire: str = 'f32') -> History:
    """``wire='int8'`` runs every round on the compressed-wire fast path
    (packed int8 uplink + fused dequant-aggregate kernel, 2 dispatches per
    round); ``quantize_uploads=True`` is the per-leaf reference form of
    the same wire (2 dispatches per leaf per client), kept as the
    bit-identity ground truth — the two are mutually exclusive."""
    protocol.check_wire(wire)
    if quantize_uploads and wire != 'f32':
        raise ValueError(
            "quantize_uploads=True is the per-leaf reference for the packed "
            "wire='int8' path; pass one or the other, not both")
    m = env.m
    sched = precompute_safa_schedule(env, fraction=fraction,
                                     lag_tolerance=lag_tolerance,
                                     rounds=rounds)
    hist = History('safa', records=sched.records, futility=sched.futility)
    if not numeric:
        return hist

    ns = _NumericState(task, m, seed)
    weights = jnp.asarray(env.weights)
    train_fn = _quantized_train_fn(task.local_train) if quantize_uploads \
        else task.local_train

    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        _scan_segments(task, hist, ns, sched.to_device(), weights,
                       sched.records, evals, proto='safa',
                       local_train_fn=train_fn, use_kernel=use_kernel,
                       wire=wire)
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.global_w, ns.local_w, ns.cache = protocol.safa_round(
                ns.global_w, ns.local_w, ns.cache,
                sync_mask=_to_j(sched.sync[i]),
                completed=_to_j(sched.committed[i]),
                picked=_to_j(sched.picked[i]),
                undrafted=_to_j(sched.undrafted[i]),
                deprecated=_to_j(sched.deprecated[i]), weights=weights,
                local_train_fn=train_fn, train_args=(t,),
                use_kernel=use_kernel, wire=wire)
            if t in evals:
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    hist.final_global = ns.global_w
    return hist


def _capped_round_len(arrival: np.ndarray, mask: np.ndarray,
                      t_lim: float) -> float:
    """Deadline-capped max arrival over ``mask``, ignoring non-finite
    entries; returns ``t_lim`` when nothing finite remains (e.g. every
    client crashed, arrival all inf) so inf never leaks into a
    RoundRecord."""
    live = arrival[mask]
    live = live[np.isfinite(live)]
    return min(t_lim, float(live.max())) if live.size else t_lim


def _sync_round_common(env: FLEnv, selected: np.ndarray, crashed: np.ndarray,
                       cfrac: np.ndarray, full_tt: np.ndarray):
    """Shared FedAvg/FedCS timing: server waits for every selected client;
    a crash is detected when the client drops (at its partial-progress
    point), so the round ends at max(finish/drop times), capped at T_lim."""
    t_dist = env.t_dist(int(selected.sum()))
    finish = t_dist + 2 * env.t_updown + full_tt
    drop = t_dist + env.t_updown + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    if selected.any():
        round_len = float(np.max(per_client[selected]))
    else:
        round_len = t_dist
    return min(env.t_lim, round_len), t_dist


def _sync_rounds_common(selected, crashed, cfrac, full_tt, *, t_lim,
                        t_updown, msize, server_bw):
    """``_sync_round_common`` vectorised over stacked leading axes.

    selected/crashed/cfrac: [..., m] (e.g. [rounds, m] or [S, rounds, m]);
    the env constants must already broadcast against those shapes (for a
    fleet: full_tt [S, 1, m], t_updown [S, 1, 1], msize/server_bw/t_lim
    [S, 1]).  Bit-identical per round to the scalar helper: the masked max
    equals the compressed max, and every arithmetic expression keeps the
    scalar path's evaluation order.  Returns (round_len [...], t_dist
    [...])."""
    t_dist = selected.sum(axis=-1) * msize * 8.0 / server_bw
    finish = t_dist[..., None] + 2 * t_updown + full_tt
    drop = t_dist[..., None] + t_updown + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    live_max = np.max(np.where(selected, per_client, -np.inf), axis=-1)
    round_len = np.where(selected.any(axis=-1), live_max, t_dist)
    return np.minimum(t_lim, round_len), t_dist


@dataclasses.dataclass
class SyncSchedule:
    """Precomputed FedAvg/FedCS event process ([rounds, m] masks + records).
    ``completed`` is the per-round survivor mask (``~crashed``); the numeric
    round intersects it with ``selected`` itself."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.selected.shape[0]

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def precompute_sync_schedule(env: FLEnv, *, fraction: float, rounds: int,
                             seed: int, fedcs: bool) -> SyncSchedule:
    """Host pass for the synchronous baselines (selection + crash draws)."""
    m = env.m
    rng = np.random.default_rng(seed + 1)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    selected_s = np.zeros((rounds, m), bool)
    completed_s = np.zeros((rounds, m), bool)
    records = []

    for t in range(1, rounds + 1):
        if fedcs:
            est = 2 * env.t_updown + full_tt
            sel = selection.fedcs_select(est, fraction, env.t_lim)
        else:
            sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac, full_tt)
        # clients that cannot make the deadline are reckoned crashed (§III-B)
        too_slow = (t_dist + 2 * env.t_updown + full_tt) > env.t_lim
        crashed = crashed | too_slow
        completed = sel & ~crashed
        performed += float(np.sum(np.where(sel, np.where(crashed, cfrac, 1.0), 0.0) * work))
        wasted += float(np.sum((sel & crashed) * cfrac * work))

        selected_s[t - 1] = sel
        completed_s[t - 1] = ~crashed
        records.append(RoundRecord(
            round=t, round_len=round_len, t_dist=t_dist,
            eur=float(completed.sum()) / m,
            sr=float(sel.sum()) / m, vv=0.0,
            n_picked=int(completed.sum()), n_committed=int(completed.sum()),
            n_crashed=int(crashed.sum())))

    return SyncSchedule(selected=selected_s, completed=completed_s,
                        records=records,
                        futility=wasted / max(performed, 1e-9))


def run_fedavg(task: Optional[Task], env: FLEnv, *, fraction: float,
               rounds: int, eval_every: int = 10, numeric: bool = True,
               seed: int = 0, fedcs: bool = False,
               engine: str = 'scan', wire: str = 'f32') -> History:
    """``wire='int8'`` ships the uploads through the packed int8 wire
    (cross-protocol comparison against SAFA's compressed fast path)."""
    protocol.check_wire(wire)
    sched = precompute_sync_schedule(env, fraction=fraction, rounds=rounds,
                                     seed=seed, fedcs=fedcs)
    hist = History('fedcs' if fedcs else 'fedavg', records=sched.records,
                   futility=sched.futility)
    if not numeric:
        return hist

    ns = _NumericState(task, env.m, seed)
    weights = jnp.asarray(env.weights)
    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        _scan_segments(task, hist, ns, sched.to_device(), weights,
                       sched.records, evals,
                       proto='fedcs' if fedcs else 'fedavg',
                       local_train_fn=task.local_train, wire=wire)
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.global_w, ns.local_w = protocol.fedavg_round(
                ns.global_w, ns.local_w, selected=_to_j(sched.selected[i]),
                completed=_to_j(sched.completed[i]), weights=weights,
                local_train_fn=task.local_train, train_args=(t,), wire=wire)
            if t in evals:
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    hist.final_global = ns.global_w
    return hist


def run_fedcs(task, env, **kw) -> History:
    return run_fedavg(task, env, fedcs=True, **kw)


@dataclasses.dataclass
class LocalSchedule:
    """Precomputed fully-local event process ([rounds, m] survivor mask +
    records).  ``completed`` is selected & survived — the only mask the
    numeric round needs (there is no aggregation until eval points)."""
    completed: np.ndarray
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.completed.shape[0]

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def precompute_local_schedule(env: FLEnv, *, fraction: float, rounds: int,
                              seed: int) -> LocalSchedule:
    """Host pass for the fully-local baseline (selection + crash draws).

    Consumes the selection rng (``seed + 2``) and the env's crash stream
    exactly as the per-round reference loop does: the two are independent
    generators, so bulk-drawing each preserves both streams."""
    m = env.m
    rng = np.random.default_rng(seed + 2)
    full_tt = env.full_train_time()
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    selected = selection.fedavg_select_batch([rng], m, fraction, rounds)[0]
    completed = selected & ~crashed_all
    round_len, _ = _sync_rounds_common(
        selected, crashed_all, cfrac_all, full_tt, t_lim=env.t_lim,
        t_updown=env.t_updown, msize=env.model_size_mb,
        server_bw=env.server_bw_mbps)
    round_len = round_len.tolist()
    n_committed = completed.sum(axis=-1).tolist()
    n_crashed = crashed_all.sum(axis=-1).tolist()
    records = [RoundRecord(round=i + 1, round_len=round_len[i], t_dist=0.0,
                           eur=0.0, sr=0.0, vv=0.0, n_picked=0,
                           n_committed=n_committed[i],
                           n_crashed=n_crashed[i])
               for i in range(rounds)]
    return LocalSchedule(completed=completed, records=records, futility=0.0)


@dataclasses.dataclass
class FedasyncSchedule:
    """Precomputed FedAsync event process: [rounds, m] commit masks plus
    the arrival-ordered merge permutations and staleness-scaled mixing
    weights the sequential server applies each round.  Model weights never
    enter — merge order is pure arrival timing and the alphas depend only
    on staleness — so the whole sequential-merge schedule is known up
    front."""
    committed: np.ndarray       # [rounds, m] bool
    order: np.ndarray           # [rounds, m] int — arrival merge order
    alphas: np.ndarray          # [rounds, m] float — 0 for non-commits
    records: list
    futility: float

    @property
    def rounds(self) -> int:
        return self.committed.shape[0]

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=jnp.arange(1, self.rounds + 1, dtype=jnp.int32))


def precompute_fedasync_schedule(env: FLEnv, *, rounds: int,
                                 alpha: float = 0.6,
                                 staleness_exp: float = 0.5
                                 ) -> FedasyncSchedule:
    """Run the FedAsync bookkeeping (global-version counter, per-client
    staleness) for all rounds in one host pass, with the crash draws
    vectorised via ``draw_rounds`` (same rng stream as round-by-round
    ``draw_round`` calls)."""
    m = env.m
    full_tt = env.full_train_time()
    crashed_all, _ = env.draw_rounds(rounds)
    arrival_base = env.t_dist(m) + 2 * env.t_updown + full_tt
    versions = np.zeros(m, dtype=float)   # global version at last pull
    global_version = 0
    committed_s = np.zeros((rounds, m), bool)
    order_s = np.zeros((rounds, m), np.int64)
    alphas_s = np.zeros((rounds, m))
    records = []

    for t in range(1, rounds + 1):
        crashed = crashed_all[t - 1]
        arrival = np.where(~crashed, arrival_base, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        staleness = np.maximum(0.0, global_version - versions)
        i = t - 1
        committed_s[i] = committed
        order_s[i] = np.argsort(arrival, kind='stable')
        alphas_s[i] = np.where(
            committed, alpha * (1.0 + staleness) ** (-staleness_exp), 0.0)
        global_version += int(committed.sum())
        versions[committed] = global_version
        records.append(RoundRecord(
            round=t,
            round_len=_capped_round_len(arrival, committed, env.t_lim),
            t_dist=env.t_dist(int(committed.sum())),
            eur=float(committed.sum()) / m,
            sr=1.0,  # every client syncs every round: max downlink pressure
            vv=float(np.var(staleness[committed])) if committed.any() else 0.0,
            n_picked=int(committed.sum()),
            n_committed=int(committed.sum()),
            n_crashed=int(crashed.sum())))

    return FedasyncSchedule(committed=committed_s, order=order_s,
                            alphas=alphas_s, records=records, futility=0.0)


# ---------------------------------------------------------------------------
# Fleet engine: batched multi-seed / multi-config sweeps
# ---------------------------------------------------------------------------
#
# A sweep is S independent simulations of the same protocol over one shared
# Task.  Each member's event process is precomputed exactly as for a single
# run, the resulting [rounds, m] schedules stack into [S, rounds, m]
# tensors, and all S numeric runs execute as ONE vmapped-scan dispatch
# (protocol.safa_run_fleet / fedavg_run_fleet) — bit-identical per member
# to S sequential engine='scan' runs, but paying one dispatch, one compile
# and one fleet-major set of buffers for the whole grid.

@dataclasses.dataclass
class SweepMember:
    """One simulation in a fleet sweep: its own environment + protocol
    hyper-parameters.  All members of a sweep share the Task (model shapes
    and client data), so their envs must agree on ``m`` — build them from
    one base config (``fedsim.env_grid``), varying ``crash_prob``,
    ``draw_seed``, ``t_lim``, ... per member."""
    env: FLEnv
    fraction: float = 0.5       # ignored by fedasync (fully asynchronous)
    lag_tolerance: int = 5      # SAFA only
    seed: int = 0               # numeric-init (and sync/local-selection) seed
    alpha: float = 0.6          # FedAsync only: base mixing weight
    staleness_exp: float = 0.5  # FedAsync only: staleness polynomial


class _FleetStack:
    """Shared fleet-major stacking machinery.  Subclasses set ``MASKS``
    (the [S, rounds, m] field names, first one authoritative for shapes)
    and ``_MEMBER_CLS`` (the single-run schedule type)."""
    MASKS: tuple = ()
    _MEMBER_CLS = None

    @property
    def size(self) -> int:
        return getattr(self, self.MASKS[0]).shape[0]

    @property
    def rounds(self) -> int:
        return getattr(self, self.MASKS[0]).shape[1]

    @classmethod
    def stack(cls, members: list):
        """Stack S single-run schedules (all with the same rounds and m)."""
        if len({getattr(s, cls.MASKS[0]).shape for s in members}) != 1:
            raise ValueError('fleet members must share (rounds, m)')
        return cls(**{k: np.stack([getattr(s, k) for s in members])
                      for k in cls.MASKS},
                   records=[s.records for s in members],
                   futility=np.array([s.futility for s in members]))

    def member(self, s: int):
        """Member s's schedule, identical to its own precompute."""
        return self._MEMBER_CLS(
            **{k: getattr(self, k)[s] for k in self.MASKS},
            records=self.records[s], futility=float(self.futility[s]))

    def _round_idx(self):
        """[S, rounds] per-member round indices for to_device()."""
        return jnp.asarray(np.broadcast_to(
            np.arange(1, self.rounds + 1, dtype=np.int32),
            (self.size, self.rounds)))


@dataclasses.dataclass
class FleetSchedule(_FleetStack):
    """S independent SAFA event processes stacked fleet-major.

    Mask tensors are [S, rounds, m]; ``records[s]`` / ``futility[s]`` hold
    member s's timing records and futility ratio, exactly as
    ``precompute_safa_schedule`` produced them."""
    sync: np.ndarray
    committed: np.ndarray
    picked: np.ndarray
    undrafted: np.ndarray
    deprecated: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('sync', 'committed', 'picked', 'undrafted', 'deprecated')
    _MEMBER_CLS = SafaSchedule

    def to_device(self) -> protocol.RoundSchedule:
        """One host->device hop for the whole fleet ([S, rounds, m] masks,
        [S, rounds] round indices)."""
        return protocol.RoundSchedule(
            sync=jnp.asarray(self.sync), completed=jnp.asarray(self.committed),
            picked=jnp.asarray(self.picked),
            undrafted=jnp.asarray(self.undrafted),
            deprecated=jnp.asarray(self.deprecated),
            round_idx=self._round_idx())


@dataclasses.dataclass
class SyncFleetSchedule(_FleetStack):
    """FedAvg/FedCS counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    selected: np.ndarray
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('selected', 'completed')
    _MEMBER_CLS = SyncSchedule

    def to_device(self) -> protocol.SyncSchedule:
        return protocol.SyncSchedule(
            selected=jnp.asarray(self.selected),
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())


@dataclasses.dataclass
class LocalFleetSchedule(_FleetStack):
    """Fully-local counterpart of ``FleetSchedule`` ([S, rounds, m])."""
    completed: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('completed',)
    _MEMBER_CLS = LocalSchedule

    def to_device(self) -> protocol.LocalSchedule:
        return protocol.LocalSchedule(
            completed=jnp.asarray(self.completed),
            round_idx=self._round_idx())


@dataclasses.dataclass
class AsyncFleetSchedule(_FleetStack):
    """FedAsync counterpart of ``FleetSchedule``: [S, rounds, m] commit
    masks plus the merge-order/alpha tensors driving each member's
    arrival-ordered sequential mixes."""
    committed: np.ndarray
    order: np.ndarray
    alphas: np.ndarray
    records: list
    futility: np.ndarray

    MASKS = ('committed', 'order', 'alphas')
    _MEMBER_CLS = FedasyncSchedule

    def to_device(self) -> protocol.AsyncSchedule:
        return protocol.AsyncSchedule(
            committed=jnp.asarray(self.committed),
            order=jnp.asarray(self.order),
            alphas=jnp.asarray(self.alphas, jnp.float32),
            round_idx=self._round_idx())


def _stack_trees(trees):
    return jax.tree.map(lambda *a: jnp.stack(a), *trees)


def _tree_member(tree, s: int):
    return jax.tree.map(lambda a: a[s], tree)


def precompute_fleet_schedule(members, *, rounds: int) -> FleetSchedule:
    """Run S SAFA event state machines in ONE fleet-major host pass.

    Bit-identical to stacking S independent ``precompute_safa_schedule``
    calls (regression-tested): each member's crash/straggler draws come
    from its own env rng, consumed exactly as a standalone precompute
    would, while the version bookkeeping and CFCFM selection run
    vectorised on [S, m] arrays (``selection.cfcfm_batch``).  This is the
    host-side counterpart of the vmapped numeric engine — without it the
    per-member python state machine dominates sweep wall-clock."""
    s_count = len(members)
    envs = [mem.env for mem in members]
    m = envs[0].m
    if any(e.m != m for e in envs):
        raise ValueError('fleet members must share the client count m')
    fraction = np.array([mem.fraction for mem in members], float)
    quota = np.maximum(1, np.rint(fraction * m).astype(int))
    lag = np.array([mem.lag_tolerance for mem in members])[:, None]
    t_lim = np.array([e.t_lim for e in envs])
    t_updown = np.array([e.t_updown for e in envs])[:, None]
    msize = np.array([e.model_size_mb for e in envs])
    server_bw = np.array([e.server_bw_mbps for e in envs])
    full_tt = np.stack([e.full_train_time() for e in envs])
    work = np.stack([e.n_batches * e.epochs for e in envs])
    draws = [e.draw_rounds(rounds) for e in envs]
    crashed_all = np.stack([d[0] for d in draws])     # [S, rounds, m]
    cfrac_all = np.stack([d[1] for d in draws])

    v = np.zeros((s_count, m), dtype=int)
    committed_prev = np.ones((s_count, m), bool)
    picked_prev = np.zeros((s_count, m), bool)
    pending = np.zeros((s_count, m))
    wasted = np.zeros(s_count)
    performed = np.zeros(s_count)
    masks = {k: np.zeros((s_count, rounds, m), bool)
             for k in FleetSchedule.MASKS}
    # per-round [S] / [S, m] intermediates; record stats vectorise over
    # rounds after the loop (the loop itself stays O(state-machine) only)
    t_dist_l, quota_met_l, base_v_l = [], [], []

    for t in range(1, rounds + 1):
        gv = t - 1
        staleness = gv - v
        dep = ~committed_prev & (staleness >= lag)
        sync = committed_prev | dep
        wasted += np.sum(np.where(sync, pending * work, 0.0), axis=-1)
        pending = np.where(sync, 0.0, pending)
        v = np.where(sync, gv, v)

        crashed, cfrac = crashed_all[:, t - 1], cfrac_all[:, t - 1]
        remaining = 1.0 - pending
        t_train = remaining * full_tt
        t_dist = sync.sum(axis=-1) * msize * 8.0 / server_bw
        arrival = t_dist[:, None] + t_updown * (1 + sync.astype(float)) \
            + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += np.sum(np.where(completed, remaining,
                                     cfrac * remaining) * work, axis=-1)
        base_versions = v.copy()

        sel = selection.cfcfm_batch(arrival, completed, picked_prev,
                                    fraction, t_lim, quota=quota)
        pending = np.where(crashed,
                           np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending = np.where(sel.committed, 0.0, pending)
        v = np.where(sel.committed, t, v)

        i = t - 1
        masks['sync'][:, i] = sync
        masks['committed'][:, i] = sel.committed
        masks['picked'][:, i] = sel.picked
        masks['undrafted'][:, i] = sel.undrafted
        masks['deprecated'][:, i] = dep
        t_dist_l.append(t_dist)
        quota_met_l.append(sel.quota_met_time)
        base_v_l.append(base_versions)
        committed_prev = sel.committed
        picked_prev = sel.picked

    # bulk-convert stat tensors to python scalars once (.tolist()) rather
    # than casting S*rounds*9 numpy scalars one by one
    t_dist_a = np.stack(t_dist_l, axis=1).tolist()            # [S][rounds]
    round_len = np.minimum(t_lim[:, None],
                           np.stack(quota_met_l, axis=1)).tolist()
    n_picked = masks['picked'].sum(axis=-1).tolist()
    n_committed = masks['committed'].sum(axis=-1).tolist()
    n_crashed = crashed_all.sum(axis=-1).tolist()
    n_sync = masks['sync'].sum(axis=-1).tolist()
    vv = _masked_var(np.stack(base_v_l, axis=1),
                     masks['committed']).tolist()
    records = [[RoundRecord(
        round=i + 1,
        round_len=round_len[s][i],
        t_dist=t_dist_a[s][i],
        eur=n_picked[s][i] / m,
        sr=n_sync[s][i] / m,
        vv=vv[s][i],
        n_picked=n_picked[s][i],
        n_committed=n_committed[s][i],
        n_crashed=n_crashed[s][i],
    ) for i in range(rounds)] for s in range(s_count)]
    return FleetSchedule(records=records,
                         futility=wasted / np.maximum(performed, 1e-9),
                         **masks)


def precompute_sync_fleet_schedule(members, *, rounds: int,
                                   fedcs: bool) -> SyncFleetSchedule:
    """FedAvg/FedCS host pass for a whole fleet in one [S, rounds, m] sweep.

    Bit-identical to stacking S ``precompute_sync_schedule`` calls
    (regression-tested) with the per-member Python state loop eliminated:
    FedCS selection is one ``selection.fedcs_select_batch`` rank
    comparison (the time estimates are round-invariant, so one [S, m]
    selection broadcasts over rounds), FedAvg selections consume each
    member's own rng stream (``selection.fedavg_select_batch``), and the
    timing/crash algebra plus record stats vectorise over the full
    [S, rounds, m] block.  Synchronous protocols carry no cross-round
    state, so there is no per-round loop either — the futility
    accumulators use ``np.cumsum`` to keep the scalar path's sequential
    round-by-round addition order."""
    s_count = len(members)
    envs = [mem.env for mem in members]
    m = envs[0].m
    if any(e.m != m for e in envs):
        raise ValueError('fleet members must share the client count m')
    fraction = np.array([mem.fraction for mem in members], float)
    t_lim = np.array([e.t_lim for e in envs])
    t_updown = np.array([e.t_updown for e in envs])
    msize = np.array([e.model_size_mb for e in envs])
    server_bw = np.array([e.server_bw_mbps for e in envs])
    full_tt = np.stack([e.full_train_time() for e in envs])     # [S, m]
    work = np.stack([e.n_batches * e.epochs for e in envs])     # [S, m]
    draws = [e.draw_rounds(rounds) for e in envs]
    crashed_all = np.stack([d[0] for d in draws])               # [S, rounds, m]
    cfrac_all = np.stack([d[1] for d in draws])

    if fedcs:
        est = 2 * t_updown[:, None] + full_tt                   # [S, m]
        sel = selection.fedcs_select_batch(est, fraction, t_lim)
        selected = np.broadcast_to(sel[:, None],
                                   (s_count, rounds, m)).copy()
    else:
        rngs = [np.random.default_rng(mem.seed + 1) for mem in members]
        selected = selection.fedavg_select_batch(rngs, m, fraction, rounds)

    round_len, t_dist = _sync_rounds_common(
        selected, crashed_all, cfrac_all, full_tt[:, None],
        t_lim=t_lim[:, None], t_updown=t_updown[:, None, None],
        msize=msize[:, None], server_bw=server_bw[:, None])
    # clients that cannot make the deadline are reckoned crashed (§III-B)
    too_slow = (t_dist[..., None] + 2 * t_updown[:, None, None]
                + full_tt[:, None]) > t_lim[:, None, None]
    crashed = crashed_all | too_slow
    completed = selected & ~crashed
    performed = np.sum(np.where(selected, np.where(crashed, cfrac_all, 1.0),
                                0.0) * work[:, None], axis=-1)  # [S, rounds]
    wasted = np.sum((selected & crashed) * cfrac_all * work[:, None], axis=-1)
    performed_tot = np.cumsum(performed, axis=1)[:, -1]
    wasted_tot = np.cumsum(wasted, axis=1)[:, -1]

    round_len_l = round_len.tolist()
    t_dist_l = t_dist.tolist()
    n_completed = completed.sum(axis=-1).tolist()
    n_sel = selected.sum(axis=-1).tolist()
    n_crashed = crashed.sum(axis=-1).tolist()
    records = [[RoundRecord(
        round=i + 1, round_len=round_len_l[s][i], t_dist=t_dist_l[s][i],
        eur=n_completed[s][i] / m,
        sr=n_sel[s][i] / m, vv=0.0,
        n_picked=n_completed[s][i], n_committed=n_completed[s][i],
        n_crashed=n_crashed[s][i],
    ) for i in range(rounds)] for s in range(s_count)]
    return SyncFleetSchedule(
        selected=selected, completed=~crashed, records=records,
        futility=wasted_tot / np.maximum(performed_tot, 1e-9))


def run_sweep(task: Optional[Task], members, *, rounds: int,
              proto: str = 'safa', eval_every: int = 10,
              numeric: bool = True, use_kernel=False,
              engine: str = 'fleet', shard: bool = True,
              wire: str = 'f32') -> list:
    """Run S = len(members) simulations of one protocol as a batched fleet.

    Returns one ``History`` per member, in order.  ``engine='fleet'``
    (default) executes all members in a single vmapped-scan dispatch per
    eval segment; ``engine='sequential'`` drives the same precomputed
    schedules through S per-member ``engine='scan'`` runs (the reference
    path and the benchmark baseline) — both produce bit-identical
    per-member results.

    ``proto`` is any ``RUNNERS`` key ('safa', 'fedavg', 'fedcs', 'local',
    'fedasync'); one sweep runs one protocol (members of a fleet share a
    compiled program).  For 'local' the fleet carry is the local stack
    only, with one vmapped aggregation per eval point; for 'fedasync' the
    schedule carries each member's merge-order/alpha tensors and
    ``SweepMember.fraction`` is ignored (``alpha``/``staleness_exp`` apply
    instead).

    When multiple JAX devices are visible and S divides evenly, ``shard``
    (default True) splits the fleet axis across them — every op in the
    scanned program is fleet-parallel, so the shards run with zero
    communication (on CPU, ``--xla_force_host_platform_device_count=N``
    turns N cores into N such devices).

    ``wire='int8'`` runs every member on the compressed int8 wire
    (SAFA: fused quantize + dequant-aggregate; FedAvg/FedCS: packed
    quantize/dequantize round-trip); 'local' and 'fedasync' have no
    per-round upload-aggregate wire and reject it.

    Per-member bit-identity with sequential runs holds when the Task's
    math lowers batch-size independently — true for the shipped
    regression/SVM tasks, whose predictions are elementwise-mul+reduce
    (see ``data/tasks.py:_reg_pred``).  Tasks built on ``dot_general``
    (e.g. the CNN's matmuls/convs) are only guaranteed numerically
    equivalent, not bit-equal, under the fleet vmap.
    """
    if proto not in RUNNERS:
        raise ValueError(
            f'unknown proto {proto!r} (want one of {sorted(RUNNERS)})')
    if engine not in ('fleet', 'sequential'):
        raise ValueError(
            f'unknown engine {engine!r} (want "fleet" or "sequential")')
    protocol.check_wire(wire)
    if wire != 'f32' and proto in ('local', 'fedasync'):
        raise ValueError(
            f"proto {proto!r} has no upload-aggregate wire; wire='int8' "
            f"applies to safa/fedavg/fedcs only")
    if not members:
        raise ValueError('empty sweep')
    m = members[0].env.m
    if any(mem.env.m != m for mem in members):
        raise ValueError('fleet members must share the client count m')

    if proto == 'safa':
        fleet = precompute_fleet_schedule(members, rounds=rounds)
    elif proto in ('fedavg', 'fedcs'):
        fleet = precompute_sync_fleet_schedule(members, rounds=rounds,
                                               fedcs=proto == 'fedcs')
    elif proto == 'local':
        fleet = LocalFleetSchedule.stack([
            precompute_local_schedule(mem.env, fraction=mem.fraction,
                                      rounds=rounds, seed=mem.seed)
            for mem in members])
    else:  # fedasync
        fleet = AsyncFleetSchedule.stack([
            precompute_fedasync_schedule(mem.env, rounds=rounds,
                                         alpha=mem.alpha,
                                         staleness_exp=mem.staleness_exp)
            for mem in members])
    hists = [History(proto, records=fleet.records[s],
                     futility=float(fleet.futility[s]))
             for s in range(fleet.size)]
    if not numeric:
        return hists

    weights = jnp.asarray(np.stack([mem.env.weights for mem in members]))
    evals = _eval_rounds(rounds, eval_every)

    if engine == 'fleet':
        # one init per distinct seed (vmapping init_global is NOT bit-stable,
        # so inits stay per-member calls), broadcast fleet-major in one op
        init = {}
        for mem in members:
            if mem.seed not in init:
                init[mem.seed] = task.init_global(jax.random.PRNGKey(mem.seed))
        g = _stack_trees([init[mem.seed] for mem in members])

        def bcast():
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[:, None],
                                           (a.shape[0], m) + a.shape[1:]), g)

        l = bcast()
        c = bcast() if proto == 'safa' else None
        dev = fleet.to_device()
        ndev = len(jax.devices())
        if shard and ndev > 1 and len(members) % ndev == 0:
            from jax.sharding import Mesh, NamedSharding, PartitionSpec
            mesh = Mesh(np.asarray(jax.devices()), ('fleet',))
            sharding = NamedSharding(mesh, PartitionSpec('fleet'))
            g, l, c, dev, weights = jax.device_put((g, l, c, dev, weights),
                                                   sharding)
        start = 0
        for stop in evals:
            seg = jax.tree.map(lambda a: a[:, start:stop], dev)
            if proto == 'safa':
                g, l, c = protocol.safa_run_fleet(
                    g, l, c, seg, weights, local_train_fn=task.local_train,
                    use_kernel=use_kernel, wire=wire)
            elif proto in ('fedavg', 'fedcs'):
                g, l = protocol.fedavg_run_fleet(
                    g, l, seg, weights, local_train_fn=task.local_train,
                    wire=wire)
            elif proto == 'local':
                l = protocol.local_run_fleet(
                    l, seg, local_train_fn=task.local_train)
                g = jax.vmap(protocol.aggregate)(l, weights)
            else:  # fedasync
                g, l = protocol.fedasync_run_fleet(
                    g, l, seg, local_train_fn=task.local_train)
            # one host gather per leaf: slicing members out of a (possibly
            # device-sharded) fleet array S times is far slower than one
            # fetch + S host slices
            g_host = jax.tree.map(np.asarray, g)
            for s, hist in enumerate(hists):
                _record_eval(hist, fleet.records[s][stop - 1], task,
                             _tree_member(g_host, s))
            start = stop
        for s, hist in enumerate(hists):
            hist.final_global = _tree_member(g_host, s)
    else:
        for s, (mem, hist) in enumerate(zip(members, hists)):
            ns = _NumericState(task, m, mem.seed)
            _scan_segments(task, hist, ns, fleet.member(s).to_device(),
                           jnp.asarray(mem.env.weights), fleet.records[s],
                           evals, proto=proto,
                           local_train_fn=task.local_train,
                           use_kernel=use_kernel, wire=wire)
            hist.final_global = ns.global_w
    return hists


def run_local(task: Optional[Task], env: FLEnv, *, fraction: float,
              rounds: int, eval_every: int = 10, numeric: bool = True,
              seed: int = 0, engine: str = 'scan') -> History:
    """Fully-local baseline: C-fraction of clients train each round with no
    aggregation; a weighted aggregation happens at eval points (and after
    the last round) only."""
    sched = precompute_local_schedule(env, fraction=fraction, rounds=rounds,
                                      seed=seed)
    hist = History('local', records=sched.records, futility=0.0)
    if not numeric:
        return hist

    ns = _NumericState(task, env.m, seed)
    weights = jnp.asarray(env.weights)
    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        _scan_segments(task, hist, ns, sched.to_device(), weights,
                       sched.records, evals, proto='local',
                       local_train_fn=task.local_train)
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.local_w = protocol.local_only_round(
                ns.local_w, completed=_to_j(sched.completed[i]),
                local_train_fn=task.local_train, train_args=(t,))
            if t in evals:
                ns.global_w = protocol.aggregate(ns.local_w, weights)
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    # evals always include the final round, so the last aggregation is it
    hist.final_global = ns.global_w
    return hist


def run_fedasync(task: Optional[Task], env: FLEnv, *, fraction: float = 1.0,
                 rounds: int = 100, eval_every: int = 10,
                 numeric: bool = True, alpha: float = 0.6,
                 staleness_exp: float = 0.5, seed: int = 0,
                 engine: str = 'scan') -> History:
    """FedAsync baseline (Xie et al. [9], paper §II): every willing client
    trains every round and the server merges each arriving update
    immediately with staleness-polynomial mixing
    alpha_eff = alpha * (1 + staleness)^(-staleness_exp).

    ``fraction`` is ignored (fully asynchronous — the paper's critique is
    precisely that the server must absorb every update: SR == 1 and m
    model merges per virtual round).  The merge order and mixing weights
    are pure event-process quantities, so they precompute like every other
    schedule; under ``engine='scan'`` the arrival-ordered sequential mixes
    run as an inner ``lax.scan`` inside the one compiled dispatch per eval
    segment, bit-identical to the ``engine='loop'`` reference.
    """
    del fraction
    sched = precompute_fedasync_schedule(env, rounds=rounds, alpha=alpha,
                                         staleness_exp=staleness_exp)
    hist = History('fedasync', records=sched.records)
    if not numeric:
        return hist

    ns = _NumericState(task, env.m, seed)
    evals = _eval_rounds(rounds, eval_every)
    if engine == 'scan':
        _scan_segments(task, hist, ns, sched.to_device(), None,
                       sched.records, evals, proto='fedasync',
                       local_train_fn=task.local_train)
    elif engine == 'loop':
        for t in range(1, rounds + 1):
            i = t - 1
            ns.global_w, ns.local_w = protocol.fedasync_round(
                ns.global_w, ns.local_w,
                committed=_to_j(sched.committed[i]),
                order=jnp.asarray(sched.order[i]),
                alphas=jnp.asarray(sched.alphas[i], jnp.float32),
                local_train_fn=task.local_train, train_args=(t,))
            if t in evals:
                _record_eval(hist, sched.records[i], task, ns.global_w)
    else:
        raise ValueError(f'unknown engine {engine!r} (want "scan" or "loop")')

    hist.final_global = ns.global_w
    return hist


RUNNERS = {
    'safa': run_safa,
    'fedavg': run_fedavg,
    'fedcs': run_fedcs,
    'local': run_local,
    'fedasync': run_fedasync,
}

# Backwards-compatible alias (pre-unification name).
PROTOCOLS = RUNNERS
