"""Federation orchestrators: SAFA / FedAvg / FedCS / fully-local.

The orchestrator owns the *protocol* state machine (versions, commit flags,
pending straggler progress) in numpy, drives the event simulator for
timing/crash draws, and (optionally, ``numeric=True``) executes the model
math via the jit-able mask algebra in ``repro.core.protocol``.

Timing-only mode (``numeric=False``) reproduces the paper's round-length /
T_dist / SR / futility tables at full scale without touching model weights —
those metrics depend only on the event process, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import protocol, selection
from repro.fedsim import FLEnv


@dataclasses.dataclass
class RoundRecord:
    round: int
    round_len: float
    t_dist: float
    eur: float
    sr: float
    vv: float
    n_picked: int
    n_committed: int
    n_crashed: int
    eval: Optional[dict] = None


@dataclasses.dataclass
class History:
    protocol: str
    records: list = dataclasses.field(default_factory=list)
    futility: float = 0.0
    best_eval: Optional[dict] = None
    final_global: Any = None

    def mean(self, field: str) -> float:
        return float(np.mean([getattr(r, field) for r in self.records]))

    def evals(self):
        return [(r.round, r.eval) for r in self.records if r.eval is not None]


class Task:
    """A federated learning task: model init/train/eval, model-agnostic for
    the protocol layer.  ``local_train(stacked_params, round_idx)`` must
    train every client replica for E epochs (vmapped inside)."""

    def init_global(self, key):
        raise NotImplementedError

    def local_train(self, stacked_params, round_idx: int):
        raise NotImplementedError

    def evaluate(self, global_params) -> dict:
        raise NotImplementedError


def _to_j(mask: np.ndarray):
    return jnp.asarray(mask)


class _NumericState:
    def __init__(self, task: Task, m: int, seed: int):
        key = jax.random.PRNGKey(seed)
        self.global_w = task.init_global(key)
        self.local_w = protocol.broadcast_global(self.global_w, m)
        self.cache = protocol.broadcast_global(self.global_w, m)


def run_safa(task: Optional[Task], env: FLEnv, *, fraction: float,
             lag_tolerance: int, rounds: int, eval_every: int = 10,
             numeric: bool = True, use_kernel: bool = False,
             quantize_uploads: bool = False, seed: int = 0) -> History:
    m = env.m
    hist = History('safa')
    v = np.zeros(m, dtype=int)             # base-model versions
    committed_prev = np.ones(m, bool)      # round 1: everyone holds w(0)
    picked_prev = np.zeros(m, bool)
    pending = np.zeros(m)                  # straggler partial progress (fraction)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs      # per-round work units
    wasted = 0.0
    performed = 0.0
    ns = _NumericState(task, m, seed) if numeric else None

    for t in range(1, rounds + 1):
        gv = t - 1
        up, dep, tol = protocol.classify_versions(
            jnp.asarray(v), gv, lag_tolerance, _to_j(committed_prev))
        up, dep = np.asarray(up), np.asarray(dep)
        sync = up | dep
        # forced sync discards any pending straggler progress (futility)
        wasted += float(np.sum(pending[sync] * work[sync]))
        pending[sync] = 0.0
        v[sync] = gv

        crashed, cfrac = env.draw_round()
        remaining = 1.0 - pending
        t_train = remaining * full_tt
        t_dist = env.t_dist(int(sync.sum()))
        arrival = t_dist + env.t_updown * (1 + sync.astype(float)) + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += float(np.sum(np.where(completed, remaining,
                                           cfrac * remaining) * work))
        base_versions = v.copy()

        sel = selection.cfcfm(arrival, completed, picked_prev, fraction, env.t_lim)
        pending = np.where(crashed, np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending[sel.committed] = 0.0
        v[sel.committed] = t

        if numeric:
            train_fn = task.local_train
            if quantize_uploads:
                # int8-compressed uplink (beyond-paper; comm_quant kernel):
                # the server sees the dequantised client update, exactly as
                # a real compressed transfer would deliver it
                def train_fn(stacked, *args, _f=task.local_train):
                    from repro.kernels import ops as kops
                    trained = _f(stacked, *args)
                    return kops.dequantize_tree(kops.quantize_tree(trained),
                                                trained)
            ns.global_w, ns.local_w, ns.cache = protocol.safa_round(
                ns.global_w, ns.local_w, ns.cache,
                sync_mask=_to_j(sync), completed=_to_j(sel.committed),
                picked=_to_j(sel.picked), undrafted=_to_j(sel.undrafted),
                deprecated=_to_j(dep), weights=jnp.asarray(env.weights),
                local_train_fn=train_fn, train_args=(t,),
                use_kernel=use_kernel)

        trained_v = base_versions[sel.committed]
        rec = RoundRecord(
            round=t,
            round_len=min(env.t_lim, sel.quota_met_time),
            t_dist=t_dist,
            eur=float(sel.picked.sum()) / m,
            sr=float(sync.sum()) / m,
            vv=float(np.var(trained_v)) if trained_v.size else 0.0,
            n_picked=int(sel.picked.sum()),
            n_committed=int(sel.committed.sum()),
            n_crashed=int(crashed.sum()),
        )
        if numeric and (t % eval_every == 0 or t == rounds):
            rec.eval = task.evaluate(ns.global_w)
            if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
                hist.best_eval = rec.eval
        hist.records.append(rec)
        committed_prev = sel.committed.copy()
        picked_prev = sel.picked.copy()

    hist.futility = wasted / max(performed, 1e-9)
    if numeric:
        hist.final_global = ns.global_w
    return hist


def _sync_round_common(env: FLEnv, selected: np.ndarray, crashed: np.ndarray,
                       cfrac: np.ndarray, full_tt: np.ndarray):
    """Shared FedAvg/FedCS timing: server waits for every selected client;
    a crash is detected when the client drops (at its partial-progress
    point), so the round ends at max(finish/drop times), capped at T_lim."""
    t_dist = env.t_dist(int(selected.sum()))
    finish = t_dist + 2 * env.t_updown + full_tt
    drop = t_dist + env.t_updown + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    if selected.any():
        round_len = float(np.max(per_client[selected]))
    else:
        round_len = t_dist
    return min(env.t_lim, round_len), t_dist


def run_fedavg(task: Optional[Task], env: FLEnv, *, fraction: float,
               rounds: int, eval_every: int = 10, numeric: bool = True,
               seed: int = 0, fedcs: bool = False) -> History:
    m = env.m
    hist = History('fedcs' if fedcs else 'fedavg')
    rng = np.random.default_rng(seed + 1)
    full_tt = env.full_train_time()
    work = env.n_batches * env.epochs
    wasted = 0.0
    performed = 0.0
    ns = _NumericState(task, m, seed) if numeric else None

    for t in range(1, rounds + 1):
        if fedcs:
            est = 2 * env.t_updown + full_tt
            sel = selection.fedcs_select(est, fraction, env.t_lim)
        else:
            sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = env.draw_round()
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac, full_tt)
        # clients that cannot make the deadline are reckoned crashed (§III-B)
        too_slow = (t_dist + 2 * env.t_updown + full_tt) > env.t_lim
        crashed = crashed | too_slow
        completed = sel & ~crashed
        performed += float(np.sum(np.where(sel, np.where(crashed, cfrac, 1.0), 0.0) * work))
        wasted += float(np.sum((sel & crashed) * cfrac * work))

        if numeric:
            ns.global_w, ns.local_w = protocol.fedavg_round(
                ns.global_w, ns.local_w, selected=_to_j(sel),
                completed=_to_j(~crashed), weights=jnp.asarray(env.weights),
                local_train_fn=task.local_train, train_args=(t,))

        rec = RoundRecord(
            round=t, round_len=round_len, t_dist=t_dist,
            eur=float(completed.sum()) / m,
            sr=float(sel.sum()) / m, vv=0.0,
            n_picked=int(completed.sum()), n_committed=int(completed.sum()),
            n_crashed=int(crashed.sum()))
        if numeric and (t % eval_every == 0 or t == rounds):
            rec.eval = task.evaluate(ns.global_w)
            if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
                hist.best_eval = rec.eval
        hist.records.append(rec)

    hist.futility = wasted / max(performed, 1e-9)
    if numeric:
        hist.final_global = ns.global_w
    return hist


def run_fedcs(task, env, **kw) -> History:
    return run_fedavg(task, env, fedcs=True, **kw)


def run_local(task: Optional[Task], env: FLEnv, *, fraction: float,
              rounds: int, eval_every: int = 10, numeric: bool = True,
              seed: int = 0) -> History:
    """Fully-local baseline: C-fraction of clients train each round with no
    aggregation; a single weighted aggregation happens after the last round."""
    m = env.m
    hist = History('local')
    rng = np.random.default_rng(seed + 2)
    ns = _NumericState(task, m, seed) if numeric else None
    full_tt = env.full_train_time()

    for t in range(1, rounds + 1):
        sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = env.draw_round()
        completed = sel & ~crashed
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac, full_tt)
        if numeric:
            trained = task.local_train(ns.local_w, t)
            ns.local_w = protocol.masked_select(_to_j(completed), trained, ns.local_w)
        rec = RoundRecord(round=t, round_len=round_len, t_dist=0.0,
                          eur=0.0, sr=0.0, vv=0.0,
                          n_picked=0, n_committed=int(completed.sum()),
                          n_crashed=int(crashed.sum()))
        if numeric and (t % eval_every == 0 or t == rounds):
            gw = protocol.aggregate(ns.local_w, jnp.asarray(env.weights))
            rec.eval = task.evaluate(gw)
            if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
                hist.best_eval = rec.eval
        hist.records.append(rec)

    if numeric:
        hist.final_global = protocol.aggregate(ns.local_w, jnp.asarray(env.weights))
    hist.futility = 0.0
    return hist


def run_fedasync(task: Optional[Task], env: FLEnv, *, fraction: float = 1.0,
                 rounds: int = 100, eval_every: int = 10,
                 numeric: bool = True, alpha: float = 0.6,
                 staleness_exp: float = 0.5, seed: int = 0) -> History:
    """FedAsync baseline (Xie et al. [9], paper §II): every willing client
    trains every round and the server merges each arriving update
    immediately with staleness-polynomial mixing
    alpha_eff = alpha * (1 + staleness)^(-staleness_exp).

    ``fraction`` is ignored (fully asynchronous — the paper's critique is
    precisely that the server must absorb every update: SR == 1 and m
    model merges per virtual round).
    """
    del fraction
    m = env.m
    hist = History('fedasync')
    full_tt = env.full_train_time()
    versions = np.zeros(m, dtype=float)   # global version at last pull
    global_version = 0
    ns = _NumericState(task, m, seed) if numeric else None

    for t in range(1, rounds + 1):
        crashed, cfrac = env.draw_round()
        arrival = env.t_dist(m) + 2 * env.t_updown + full_tt
        arrival = np.where(~crashed, arrival, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        order = np.argsort(arrival, kind='stable')
        staleness = np.maximum(0.0, global_version - versions)
        alphas = np.where(committed,
                          alpha * (1.0 + staleness) ** (-staleness_exp), 0.0)

        if numeric:
            trained = task.local_train(ns.local_w, t)
            trained = protocol.masked_select(_to_j(committed), trained,
                                             ns.local_w)
            ns.global_w = protocol.fedasync_merge(
                ns.global_w, trained, order=jnp.asarray(order),
                alphas=jnp.asarray(alphas, jnp.float32))
            # committed clients pull the fresh global model
            ns.local_w = protocol.masked_select(
                _to_j(committed), protocol.broadcast_global(ns.global_w, m),
                protocol.masked_select(_to_j(committed), trained, ns.local_w))

        global_version += int(committed.sum())
        versions[committed] = global_version
        rec = RoundRecord(
            round=t,
            round_len=min(env.t_lim, float(np.max(arrival[committed]))
                          if committed.any() else env.t_lim),
            t_dist=env.t_dist(int(committed.sum())),
            eur=float(committed.sum()) / m,
            sr=1.0,  # every client syncs every round: max downlink pressure
            vv=float(np.var(staleness[committed])) if committed.any() else 0.0,
            n_picked=int(committed.sum()),
            n_committed=int(committed.sum()),
            n_crashed=int(crashed.sum()))
        if numeric and (t % eval_every == 0 or t == rounds):
            rec.eval = task.evaluate(ns.global_w)
            if hist.best_eval is None or rec.eval['loss'] < hist.best_eval['loss']:
                hist.best_eval = rec.eval
        hist.records.append(rec)

    if numeric:
        hist.final_global = ns.global_w
    return hist


PROTOCOLS = {
    'safa': run_safa,
    'fedavg': run_fedavg,
    'fedcs': run_fedcs,
    'local': run_local,
    'fedasync': run_fedasync,
}
