"""Federation event processes: SAFA / FedAvg / FedCS / FedAsync / local.

This module owns the *protocol state machines* (versions, commit flags,
pending straggler progress) in numpy: they drive the event simulator for
timing/crash draws and precompute whole runs — and whole sweeps — as mask
schedules, because the event process never looks at model weights.

* ``precompute_safa_schedule`` / ``precompute_sync_schedule`` /
  ``precompute_local_schedule`` / ``precompute_fedasync_schedule`` run a
  single simulation's state machine in one host pass and emit
  ``[rounds, m]`` mask schedules (containers in ``repro.core.schedules``).
* ``precompute_fleet_schedule`` / ``precompute_sync_fleet_schedule`` run S
  state machines fleet-major on ``[S, m]`` arrays, bit-identical to S
  independent precomputes.

Execution lives elsewhere: the compiled scan/fleet engines are in
``repro.core.protocol``, and the public entry point that wires specs,
schedules and engines together is ``repro.core.api`` (``repro.api``) —
declarative ``Experiment``s with checkpoint/resume-capable runners.

The historical free functions (``run_safa``, ``run_fedavg``, ``run_fedcs``,
``run_local``, ``run_fedasync``, ``run_sweep``) remain as thin shims over
``api.Experiment`` for backwards compatibility; they emit
``DeprecationWarning`` and are bit-identical to their spec spellings
(regression-tested).
"""
from __future__ import annotations

import sys
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol, schedules, selection
from repro.core.schedules import (
    AsyncFleetSchedule,
    FedasyncSchedule,
    FleetSchedule,
    History,
    LocalFleetSchedule,
    LocalSchedule,
    RoundRecord,
    SafaSchedule,
    SweepMember,
    SyncFleetSchedule,
    SyncSchedule,
)
from repro.fedsim import FLEnv

__all__ = [
    'AsyncFleetSchedule', 'FedasyncSchedule', 'FleetSchedule', 'History',
    'LocalFleetSchedule', 'LocalSchedule', 'RoundRecord', 'RUNNERS',
    'SafaSchedule', 'SweepMember', 'SyncFleetSchedule', 'SyncSchedule',
    'Task', 'precompute_fedasync_schedule', 'precompute_fleet_schedule',
    'precompute_local_schedule', 'precompute_safa_schedule',
    'precompute_sync_fleet_schedule', 'precompute_sync_schedule',
    'run_fedasync', 'run_fedavg', 'run_fedcs', 'run_local', 'run_safa',
    'run_sweep',
]


class Task:
    """A federated learning task: model init/train/eval, model-agnostic for
    the protocol layer.  ``local_train(stacked_params, round_idx)`` must
    train every client replica for E epochs (vmapped inside).

    ``round_idx`` is a Python int under ``engine='loop'`` but a traced
    int32 scalar under the default scanned engine — implementations must
    not branch on it in Python (use ``jnp.where``/``lax.cond`` if the
    round number matters)."""

    def init_global(self, key):
        raise NotImplementedError

    def local_train(self, stacked_params, round_idx):
        raise NotImplementedError

    def local_train_rows(self, params_rows, rows, round_idx):
        """Sparse-schedule training: train only the K client replicas in
        ``params_rows`` ([K, ...] leaves), whose client ids are ``rows``
        ([K] int32, device array; sentinel ids >= m gather-clamp to
        garbage rows whose output the engine discards).  Must produce, row
        for row, the same bits ``local_train`` produces for those clients —
        that is the sparse==dense contract."""
        raise NotImplementedError(
            f'{type(self).__name__} does not implement local_train_rows; '
            f'sparse schedules need the rows-train contract')

    def evaluate(self, global_params) -> dict:
        raise NotImplementedError


class _NumericState:
    def __init__(self, task: Task, m: int, seed: int):
        key = jax.random.PRNGKey(seed)
        self.global_w = task.init_global(key)
        self.local_w = protocol.broadcast_global(self.global_w, m)
        self.cache = protocol.broadcast_global(self.global_w, m)


def _masked_var(values: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Population variance of ``values`` over ``mask`` along the last axis
    (0.0 where the mask is empty).

    Formulated as masked sums so the single-run and fleet-major schedule
    precomputes reduce in the same order and agree bit for bit."""
    n = mask.sum(axis=-1)
    denom = np.maximum(n, 1)
    mean = np.sum(np.where(mask, values, 0), axis=-1) / denom
    dev = np.where(mask, (values - mean[..., None]) ** 2, 0.0)
    return np.where(n > 0, np.sum(dev, axis=-1) / denom, 0.0)


def precompute_safa_schedule(env: FLEnv, *, fraction: float,
                             lag_tolerance: int, rounds: int,
                             form: str = 'dense'):
    """Run the SAFA timing/event state machine (Eq. 3 version bookkeeping,
    crash draws, CFCFM selection) for all rounds in one numpy host pass.

    The event process never reads model weights, so the full [rounds, m]
    mask schedule — and every timing metric — is known up front.  Consumes
    ``env``'s rng exactly as the seed's round-by-round loop did.

    ``form='sparse'`` emits a compact ``SparseSchedule`` instead: the SAME
    loop runs (same draws, same selection, same records), but each round
    stores only its active set's (idx, roles) pair, so peak host memory is
    O(m + rounds * K) instead of O(rounds * m).  By construction
    ``precompute(form='sparse')`` equals ``precompute(form='dense')
    .to_sparse()`` exactly — one event stream, two encodings.

    ``form='sparse_tier'`` additionally records each active client's base
    version (the ``v`` counter this loop already maintains) and lowers the
    event stream to a ``TierSchedule``: sparse rows plus the slot maps
    that let the numeric engines carry one O(lag_tolerance + quota)-row
    value buffer instead of [m, N] local/cache stacks.  Equals
    ``precompute(form='dense').to_tier()`` exactly.
    """
    if form not in ('dense', 'sparse', 'sparse_tier'):
        raise ValueError(f"unknown form {form!r} (want 'dense', 'sparse', "
                         f"or 'sparse_tier')")
    m = env.m
    v = np.zeros(m, dtype=int)             # base-model versions
    committed_prev = np.ones(m, bool)      # round 1: everyone holds w(0)
    picked_prev = np.zeros(m, bool)
    pending = np.zeros(m)                  # straggler partial progress (fraction)
    tim = env.round_timing(rounds)         # [rounds, m] trace/wire-aware
    work = env.n_batches * env.epochs      # per-round work units
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    masks = {k: np.zeros((rounds, m), bool)
             for k in ('sync', 'committed', 'picked', 'undrafted',
                       'deprecated')} if form == 'dense' else None
    sparse_rows = []
    base_v_rows = []
    records = []

    for t in range(1, rounds + 1):
        gv = t - 1
        up, dep, _ = protocol.classify_versions(v, gv, lag_tolerance,
                                                committed_prev)
        sync = up | dep
        # forced sync discards any pending straggler progress (futility);
        # masked-sum form so the fleet-major precompute reduces identically
        wasted += float(np.sum(np.where(sync, pending * work, 0.0)))
        pending[sync] = 0.0
        v[sync] = gv

        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        remaining = 1.0 - pending
        t_train = remaining * tim.full_tt[t - 1]
        t_dist = env.t_dist(int(sync.sum()))
        # every live client uploads; sync'd ones first download the global
        # (== t_updown * (1 + sync) bitwise when the traces are constant)
        arrival = t_dist + (tim.t_up[t - 1] + sync * tim.t_down[t - 1]) \
            + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += float(np.sum(np.where(completed, remaining,
                                           cfrac * remaining) * work))
        base_versions = v.copy()

        sel = selection.cfcfm(arrival, completed, picked_prev, fraction, env.t_lim)
        pending = np.where(crashed, np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending[sel.committed] = 0.0
        v[sel.committed] = t

        if form == 'dense':
            i = t - 1
            masks['sync'][i] = sync
            masks['committed'][i] = sel.committed
            masks['picked'][i] = sel.picked
            masks['undrafted'][i] = sel.undrafted
            masks['deprecated'][i] = dep
        else:
            row = schedules.safa_sparse_row(
                sync, sel.committed, sel.picked, sel.undrafted, dep,
                bootstrap=(t == 1))
            sparse_rows.append(row)
            if form == 'sparse_tier':
                base_v_rows.append(base_versions[row[0]])

        records.append(RoundRecord(
            round=t,
            round_len=min(env.t_lim, sel.quota_met_time),
            t_dist=t_dist,
            eur=float(sel.picked.sum()) / m,
            sr=float(sync.sum()) / m,
            vv=float(_masked_var(base_versions, sel.committed)),
            n_picked=int(sel.picked.sum()),
            n_committed=int(sel.committed.sum()),
            n_crashed=int(crashed.sum()),
        ))
        committed_prev = sel.committed.copy()
        picked_prev = sel.picked.copy()

    futility = wasted / max(performed, 1e-9)
    if form == 'sparse_tier':
        return schedules.build_tier_schedule(m, sparse_rows, base_v_rows,
                                             records, futility)
    if form == 'sparse':
        idx, roles = schedules.pack_sparse_rows(sparse_rows, m)
        return schedules.SparseSchedule(m=m, idx=idx, roles=roles,
                                        records=records, futility=futility)
    return SafaSchedule(records=records, futility=futility, **masks)


def _quantized_train_fn(base_fn):
    """int8-compressed uplink, per-leaf REFERENCE path (comm_quant kernel):
    each client quantises each leaf of its own update independently —
    exactly what a real compressed transfer carries — costing 2 pallas
    dispatches per leaf per client.  This is the bit-identity ground truth
    for the packed fast path (``wire='int8'``), which ships the same
    numbers in 2 dispatches total.

    The wrapper is memoised on the owning Task, keyed by the wrapped
    function, so it stays a stable static argument to ``safa_run_scan``
    (a fresh closure per run would retrace the whole scanned program)
    without pinning Tasks beyond their own lifetime — and without
    handing back a stale closure when a *different* bound method of the
    same Task gets wrapped later."""
    def train_fn(stacked, *args):
        from repro.kernels import ops as kops
        trained = base_fn(stacked, *args)

        def per_leaf(x):
            flat = x.reshape(x.shape[0], -1)
            rows = [kops.dequantize(*kops.quantize(flat[k]), n=flat.shape[1])
                    for k in range(flat.shape[0])]
            return jnp.stack(rows).reshape(x.shape)

        return jax.tree.map(per_leaf, trained)

    owner = getattr(base_fn, '__self__', None)
    if owner is None:
        return train_fn
    key = getattr(base_fn, '__func__', base_fn)
    cache = owner.__dict__.setdefault('_quantized_train_fns', {})
    if key not in cache:
        cache[key] = train_fn
    return cache[key]


def _capped_round_len(arrival: np.ndarray, mask: np.ndarray,
                      t_lim: float) -> float:
    """Deadline-capped max arrival over ``mask``, ignoring non-finite
    entries; returns ``t_lim`` when nothing finite remains (e.g. every
    client crashed, arrival all inf) so inf never leaks into a
    RoundRecord."""
    live = arrival[mask]
    live = live[np.isfinite(live)]
    return min(t_lim, float(live.max())) if live.size else t_lim


def _sync_round_common(env, selected: np.ndarray, crashed: np.ndarray,
                       cfrac: np.ndarray, t_up: np.ndarray,
                       t_down: np.ndarray, full_tt: np.ndarray):
    """Shared FedAvg/FedCS timing: server waits for every selected client;
    a crash is detected when the client drops (at its partial-progress
    point), so the round ends at max(finish/drop times), capped at T_lim.

    ``t_up``/``t_down``/``full_tt`` are the round's [m] timing rows
    (``Env.round_timing``); with constant traces ``t_down + t_up`` equals
    the legacy ``2 * t_updown`` bitwise."""
    t_dist = env.t_dist(int(selected.sum()))
    finish = t_dist + (t_down + t_up) + full_tt
    drop = t_dist + t_down + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    if selected.any():
        round_len = float(np.max(per_client[selected]))
    else:
        round_len = t_dist
    return min(env.t_lim, round_len), t_dist


def _sync_rounds_common(selected, crashed, cfrac, full_tt, *, t_lim,
                        t_up, t_down, msize, server_bw):
    """``_sync_round_common`` vectorised over stacked leading axes.

    selected/crashed/cfrac: [..., m] (e.g. [rounds, m] or [S, rounds, m]);
    the timing arrays must already broadcast against those shapes (for a
    fleet: full_tt/t_up/t_down [S, rounds, m] — or [S, 1, m] when no
    member carries traces — and msize/server_bw/t_lim [S, 1]).
    Bit-identical per round to the scalar helper: the masked max equals
    the compressed max, and every arithmetic expression keeps the scalar
    path's evaluation order ((t_down + t_up) == 2 * t_updown bitwise for
    constant traces).  Returns (round_len [...], t_dist [...])."""
    t_dist = selected.sum(axis=-1) * msize * 8.0 / server_bw
    finish = t_dist[..., None] + (t_down + t_up) + full_tt
    drop = t_dist[..., None] + t_down + cfrac * full_tt
    per_client = np.where(crashed, drop, finish)
    live_max = np.max(np.where(selected, per_client, -np.inf), axis=-1)
    round_len = np.where(selected.any(axis=-1), live_max, t_dist)
    return np.minimum(t_lim, round_len), t_dist


def precompute_sync_schedule(env: FLEnv, *, fraction: float, rounds: int,
                             seed: int, fedcs: bool, form: str = 'dense',
                             sampler: str = 'choice'):
    """Host pass for the synchronous baselines (selection + crash draws).

    ``sampler`` picks the FedAvg selection stream: 'choice' is the legacy
    per-round ``Generator.choice`` draw; 'topk' is the vectorised
    without-replacement sampler (``selection.fedavg_select_topk``) whose
    bulk-uniform stream scales to large m.  FedCS selection is
    deterministic and ignores it.  ``form='sparse'`` emits a
    ``SparseSyncSchedule`` (same loop, compact per-round storage), exactly
    equal to the dense precompute's ``.to_sparse()``."""
    if form not in ('dense', 'sparse'):
        raise ValueError(f"unknown form {form!r} (want 'dense' or 'sparse')")
    m = env.m
    rng = np.random.default_rng(seed + 1)
    tim = env.round_timing(rounds)         # [rounds, m] trace/wire-aware
    work = env.n_batches * env.epochs
    wasted = 0.0
    performed = 0.0
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    sel_idx_all = None
    if not fedcs and sampler == 'topk':
        # one bulk uniform draw for all rounds (row t == round t's draw)
        sel_idx_all = selection.fedavg_select_topk(rng, m, fraction, rounds)
    elif sampler not in ('choice', 'topk'):
        raise ValueError(
            f"unknown sampler {sampler!r} (want 'choice' or 'topk')")
    dense = form == 'dense'
    selected_s = np.zeros((rounds, m), bool) if dense else None
    completed_s = np.zeros((rounds, m), bool) if dense else None
    sparse_rows = []
    records = []

    for t in range(1, rounds + 1):
        t_up, t_down = tim.t_up[t - 1], tim.t_down[t - 1]
        full_tt = tim.full_tt[t - 1]
        if fedcs:
            # per-round estimate: traces move the FedCS pick round to round
            est = (t_down + t_up) + full_tt
            sel = selection.fedcs_select(est, fraction, env.t_lim)
        elif sel_idx_all is not None:
            sel = np.zeros(m, bool)
            sel[sel_idx_all[t - 1]] = True
        else:
            sel = selection.fedavg_select(rng, m, fraction)
        crashed, cfrac = crashed_all[t - 1], cfrac_all[t - 1]
        round_len, t_dist = _sync_round_common(env, sel, crashed, cfrac,
                                               t_up, t_down, full_tt)
        # clients that cannot make the deadline are reckoned crashed (§III-B)
        too_slow = (t_dist + (t_down + t_up) + full_tt) > env.t_lim
        crashed = crashed | too_slow
        completed = sel & ~crashed
        performed += float(np.sum(np.where(sel, np.where(crashed, cfrac, 1.0), 0.0) * work))
        wasted += float(np.sum((sel & crashed) * cfrac * work))

        if dense:
            selected_s[t - 1] = sel
            completed_s[t - 1] = ~crashed
        else:
            sparse_rows.append(schedules.sync_sparse_row(sel, ~crashed))
        records.append(RoundRecord(
            round=t, round_len=round_len, t_dist=t_dist,
            eur=float(completed.sum()) / m,
            sr=float(sel.sum()) / m, vv=0.0,
            n_picked=int(completed.sum()), n_committed=int(completed.sum()),
            n_crashed=int(crashed.sum())))

    futility = wasted / max(performed, 1e-9)
    if not dense:
        idx, roles = schedules.pack_sparse_rows(sparse_rows, m)
        return schedules.SparseSyncSchedule(m=m, idx=idx, roles=roles,
                                            records=records,
                                            futility=futility)
    return SyncSchedule(selected=selected_s, completed=completed_s,
                        records=records, futility=futility)


def precompute_local_schedule(env: FLEnv, *, fraction: float, rounds: int,
                              seed: int) -> LocalSchedule:
    """Host pass for the fully-local baseline (selection + crash draws).

    Consumes the selection rng (``seed + 2``) and the env's crash stream
    exactly as the per-round reference loop does: the two are independent
    generators, so bulk-drawing each preserves both streams."""
    m = env.m
    rng = np.random.default_rng(seed + 2)
    tim = env.round_timing(rounds)         # [rounds, m] trace/wire-aware
    crashed_all, cfrac_all = env.draw_rounds(rounds)
    selected = selection.fedavg_select_batch([rng], m, fraction, rounds)[0]
    completed = selected & ~crashed_all
    round_len, _ = _sync_rounds_common(
        selected, crashed_all, cfrac_all, tim.full_tt, t_lim=env.t_lim,
        t_up=tim.t_up, t_down=tim.t_down, msize=env._dist_mb(),
        server_bw=env.server_bw_mbps)
    round_len = round_len.tolist()
    n_committed = completed.sum(axis=-1).tolist()
    n_crashed = crashed_all.sum(axis=-1).tolist()
    records = [RoundRecord(round=i + 1, round_len=round_len[i], t_dist=0.0,
                           eur=0.0, sr=0.0, vv=0.0, n_picked=0,
                           n_committed=n_committed[i],
                           n_crashed=n_crashed[i])
               for i in range(rounds)]
    return LocalSchedule(completed=completed, records=records, futility=0.0)


def precompute_fedasync_schedule(env: FLEnv, *, rounds: int,
                                 alpha: float = 0.6,
                                 staleness_exp: float = 0.5
                                 ) -> FedasyncSchedule:
    """Run the FedAsync bookkeeping (global-version counter, per-client
    staleness) for all rounds in one host pass, with the crash draws
    vectorised via ``draw_rounds`` (same rng stream as round-by-round
    ``draw_round`` calls)."""
    m = env.m
    tim = env.round_timing(rounds)         # [rounds, m] trace/wire-aware
    crashed_all, _ = env.draw_rounds(rounds)
    # every client syncs every round, so t_dist(m) is round-invariant; the
    # per-client leg varies with the round's traces
    t_dist_m = env.t_dist(m)
    versions = np.zeros(m, dtype=float)   # global version at last pull
    global_version = 0
    committed_s = np.zeros((rounds, m), bool)
    order_s = np.zeros((rounds, m), np.int64)
    alphas_s = np.zeros((rounds, m))
    records = []

    for t in range(1, rounds + 1):
        crashed = crashed_all[t - 1]
        arrival_base = t_dist_m \
            + (tim.t_down[t - 1] + tim.t_up[t - 1]) + tim.full_tt[t - 1]
        arrival = np.where(~crashed, arrival_base, np.inf)
        too_slow = arrival > env.t_lim
        committed = ~crashed & ~too_slow
        staleness = np.maximum(0.0, global_version - versions)
        i = t - 1
        committed_s[i] = committed
        order_s[i] = np.argsort(arrival, kind='stable')
        alphas_s[i] = np.where(
            committed, alpha * (1.0 + staleness) ** (-staleness_exp), 0.0)
        global_version += int(committed.sum())
        versions[committed] = global_version
        records.append(RoundRecord(
            round=t,
            round_len=_capped_round_len(arrival, committed, env.t_lim),
            t_dist=env.t_dist(int(committed.sum())),
            eur=float(committed.sum()) / m,
            sr=1.0,  # every client syncs every round: max downlink pressure
            vv=float(np.var(staleness[committed])) if committed.any() else 0.0,
            n_picked=int(committed.sum()),
            n_committed=int(committed.sum()),
            n_crashed=int(crashed.sum())))

    return FedasyncSchedule(committed=committed_s, order=order_s,
                            alphas=alphas_s, records=records, futility=0.0)


# ---------------------------------------------------------------------------
# Fleet precomputes: batched multi-seed / multi-config sweeps
# ---------------------------------------------------------------------------
#
# A sweep is S independent simulations of the same protocol.  Each member's
# event process is precomputed exactly as for a single run and the resulting
# [rounds, m] schedules stack into [S, rounds, m] tensors — here the whole
# fleet-major state machine runs in one host pass, bit-identical to S
# independent precomputes (regression-tested).

def precompute_fleet_schedule(members, *, rounds: int) -> FleetSchedule:
    """Run S SAFA event state machines in ONE fleet-major host pass.

    Bit-identical to stacking S independent ``precompute_safa_schedule``
    calls (regression-tested): each member's crash/straggler draws come
    from its own env rng, consumed exactly as a standalone precompute
    would, while the version bookkeeping and CFCFM selection run
    vectorised on [S, m] arrays (``selection.cfcfm_batch``).  This is the
    host-side counterpart of the vmapped numeric engine — without it the
    per-member python state machine dominates sweep wall-clock."""
    s_count = len(members)
    envs = [mem.env for mem in members]
    m = envs[0].m
    if any(e.m != m for e in envs):
        raise ValueError('fleet members must share the client count m')
    fraction = np.array([mem.fraction for mem in members], float)
    quota = np.maximum(1, np.rint(fraction * m).astype(int))
    lag = np.array([mem.lag_tolerance for mem in members])[:, None]
    t_lim = np.array([e.t_lim for e in envs])
    msize = np.array([e._dist_mb() for e in envs])
    server_bw = np.array([e.server_bw_mbps for e in envs])
    tims = [e.round_timing(rounds) for e in envs]
    work = np.stack([e.n_batches * e.epochs for e in envs])
    draws = [e.draw_rounds(rounds) for e in envs]
    crashed_all = np.stack([d[0] for d in draws])     # [S, rounds, m]
    cfrac_all = np.stack([d[1] for d in draws])

    v = np.zeros((s_count, m), dtype=int)
    committed_prev = np.ones((s_count, m), bool)
    picked_prev = np.zeros((s_count, m), bool)
    pending = np.zeros((s_count, m))
    wasted = np.zeros(s_count)
    performed = np.zeros(s_count)
    masks = {k: np.zeros((s_count, rounds, m), bool)
             for k in FleetSchedule.MASKS}
    # per-round [S] / [S, m] intermediates; record stats vectorise over
    # rounds after the loop (the loop itself stays O(state-machine) only)
    t_dist_l, quota_met_l, base_v_l = [], [], []

    for t in range(1, rounds + 1):
        gv = t - 1
        staleness = gv - v
        dep = ~committed_prev & (staleness >= lag)
        sync = committed_prev | dep
        wasted += np.sum(np.where(sync, pending * work, 0.0), axis=-1)
        pending = np.where(sync, 0.0, pending)
        v = np.where(sync, gv, v)

        crashed, cfrac = crashed_all[:, t - 1], cfrac_all[:, t - 1]
        remaining = 1.0 - pending
        # per-round [S, m] timing rows (trace/wire-aware; bit-identical to
        # the legacy t_updown * (1 + sync) algebra under constant traces)
        t_up_r = np.stack([tt.t_up[t - 1] for tt in tims])
        t_down_r = np.stack([tt.t_down[t - 1] for tt in tims])
        t_train = remaining * np.stack([tt.full_tt[t - 1] for tt in tims])
        t_dist = sync.sum(axis=-1) * msize * 8.0 / server_bw
        arrival = t_dist[:, None] + (t_up_r + sync * t_down_r) \
            + t_train
        completed = ~crashed
        arrival = np.where(completed, arrival, np.inf)
        performed += np.sum(np.where(completed, remaining,
                                     cfrac * remaining) * work, axis=-1)
        base_versions = v.copy()

        sel = selection.cfcfm_batch(arrival, completed, picked_prev,
                                    fraction, t_lim, quota=quota)
        pending = np.where(crashed,
                           np.minimum(pending + cfrac * remaining, 0.999),
                           pending)
        pending = np.where(sel.committed, 0.0, pending)
        v = np.where(sel.committed, t, v)

        i = t - 1
        masks['sync'][:, i] = sync
        masks['committed'][:, i] = sel.committed
        masks['picked'][:, i] = sel.picked
        masks['undrafted'][:, i] = sel.undrafted
        masks['deprecated'][:, i] = dep
        t_dist_l.append(t_dist)
        quota_met_l.append(sel.quota_met_time)
        base_v_l.append(base_versions)
        committed_prev = sel.committed
        picked_prev = sel.picked

    # bulk-convert stat tensors to python scalars once (.tolist()) rather
    # than casting S*rounds*9 numpy scalars one by one
    t_dist_a = np.stack(t_dist_l, axis=1).tolist()            # [S][rounds]
    round_len = np.minimum(t_lim[:, None],
                           np.stack(quota_met_l, axis=1)).tolist()
    n_picked = masks['picked'].sum(axis=-1).tolist()
    n_committed = masks['committed'].sum(axis=-1).tolist()
    n_crashed = crashed_all.sum(axis=-1).tolist()
    n_sync = masks['sync'].sum(axis=-1).tolist()
    vv = _masked_var(np.stack(base_v_l, axis=1),
                     masks['committed']).tolist()
    records = [[RoundRecord(
        round=i + 1,
        round_len=round_len[s][i],
        t_dist=t_dist_a[s][i],
        eur=n_picked[s][i] / m,
        sr=n_sync[s][i] / m,
        vv=vv[s][i],
        n_picked=n_picked[s][i],
        n_committed=n_committed[s][i],
        n_crashed=n_crashed[s][i],
    ) for i in range(rounds)] for s in range(s_count)]
    return FleetSchedule(records=records,
                         futility=wasted / np.maximum(performed, 1e-9),
                         **masks)


def precompute_sync_fleet_schedule(members, *, rounds: int, fedcs: bool,
                                   sampler: str = 'choice'
                                   ) -> SyncFleetSchedule:
    """FedAvg/FedCS host pass for a whole fleet in one [S, rounds, m] sweep.

    Bit-identical to stacking S ``precompute_sync_schedule`` calls
    (regression-tested) with the per-member Python state loop eliminated:
    FedCS selection is one ``selection.fedcs_select_batch`` rank
    comparison (when no member carries traces the time estimates are
    round-invariant and one [S, m] selection broadcasts over rounds; with
    traces the rounds axis folds into the batch axis — one
    [S*rounds, m] call), FedAvg selections consume each
    member's own rng stream (``selection.fedavg_select_batch``), and the
    timing/crash algebra plus record stats vectorise over the full
    [S, rounds, m] block.  Synchronous protocols carry no cross-round
    state, so there is no per-round loop either — the futility
    accumulators use ``np.cumsum`` to keep the scalar path's sequential
    round-by-round addition order."""
    s_count = len(members)
    envs = [mem.env for mem in members]
    m = envs[0].m
    if any(e.m != m for e in envs):
        raise ValueError('fleet members must share the client count m')
    fraction = np.array([mem.fraction for mem in members], float)
    t_lim = np.array([e.t_lim for e in envs])
    msize = np.array([e._dist_mb() for e in envs])
    server_bw = np.array([e.server_bw_mbps for e in envs])
    work = np.stack([e.n_batches * e.epochs for e in envs])     # [S, m]
    draws = [e.draw_rounds(rounds) for e in envs]
    crashed_all = np.stack([d[0] for d in draws])               # [S, rounds, m]
    cfrac_all = np.stack([d[1] for d in draws])

    tims = [e.round_timing(rounds) for e in envs]
    if any(e.has_traces for e in envs):
        # time-varying timing: full [S, rounds, m] stacks, and FedCS picks
        # per round (estimates move round to round)
        t_up = np.stack([tt.t_up for tt in tims])
        t_down = np.stack([tt.t_down for tt in tims])
        full_tt = np.stack([tt.full_tt for tt in tims])
        if fedcs:
            est = ((t_down + t_up) + full_tt).reshape(s_count * rounds, m)
            sel = selection.fedcs_select_batch(
                est, np.repeat(fraction, rounds), np.repeat(t_lim, rounds))
            selected = sel.reshape(s_count, rounds, m)
    else:
        # round-invariant timing: [S, 1, m] row-0 views broadcast over
        # rounds (legacy memory shape), one FedCS selection for all rounds
        t_up = np.stack([tt.t_up[0] for tt in tims])[:, None]
        t_down = np.stack([tt.t_down[0] for tt in tims])[:, None]
        full_tt = np.stack([tt.full_tt[0] for tt in tims])[:, None]
        if fedcs:
            est = (t_down[:, 0] + t_up[:, 0]) + full_tt[:, 0]   # [S, m]
            sel = selection.fedcs_select_batch(est, fraction, t_lim)
            selected = np.broadcast_to(sel[:, None],
                                       (s_count, rounds, m)).copy()
    if not fedcs:
        rngs = [np.random.default_rng(mem.seed + 1) for mem in members]
        selected = selection.fedavg_select_batch(rngs, m, fraction, rounds,
                                                 sampler=sampler)

    round_len, t_dist = _sync_rounds_common(
        selected, crashed_all, cfrac_all, full_tt,
        t_lim=t_lim[:, None], t_up=t_up, t_down=t_down,
        msize=msize[:, None], server_bw=server_bw[:, None])
    # clients that cannot make the deadline are reckoned crashed (§III-B)
    too_slow = (t_dist[..., None] + (t_down + t_up)
                + full_tt) > t_lim[:, None, None]
    crashed = crashed_all | too_slow
    completed = selected & ~crashed
    performed = np.sum(np.where(selected, np.where(crashed, cfrac_all, 1.0),
                                0.0) * work[:, None], axis=-1)  # [S, rounds]
    wasted = np.sum((selected & crashed) * cfrac_all * work[:, None], axis=-1)
    performed_tot = np.cumsum(performed, axis=1)[:, -1]
    wasted_tot = np.cumsum(wasted, axis=1)[:, -1]

    round_len_l = round_len.tolist()
    t_dist_l = t_dist.tolist()
    n_completed = completed.sum(axis=-1).tolist()
    n_sel = selected.sum(axis=-1).tolist()
    n_crashed = crashed.sum(axis=-1).tolist()
    records = [[RoundRecord(
        round=i + 1, round_len=round_len_l[s][i], t_dist=t_dist_l[s][i],
        eur=n_completed[s][i] / m,
        sr=n_sel[s][i] / m, vv=0.0,
        n_picked=n_completed[s][i], n_committed=n_completed[s][i],
        n_crashed=n_crashed[s][i],
    ) for i in range(rounds)] for s in range(s_count)]
    return SyncFleetSchedule(
        selected=selected, completed=~crashed, records=records,
        futility=wasted_tot / np.maximum(performed_tot, 1e-9))


# ---------------------------------------------------------------------------
# Legacy runner shims (DeprecationWarning; bit-identical to the spec path)
# ---------------------------------------------------------------------------

def _deprecated(name: str, spelling: str):
    # attribute the warning to the first frame OUTSIDE this module, so
    # run_fedcs -> run_fedavg chains still point at the user's call site
    # (and per-call-site warning dedup keeps working)
    level, frame = 3, sys._getframe(2)
    while frame is not None and frame.f_globals.get('__name__') == __name__:
        level += 1
        frame = frame.f_back
    warnings.warn(
        f'federation.{name}() is deprecated; spell it as {spelling} '
        f'(repro.api — see docs/ARCHITECTURE.md, "The API layer")',
        DeprecationWarning, stacklevel=level)


def run_safa(task: Optional[Task], env: FLEnv, *, fraction: float,
             lag_tolerance: int, rounds: int, eval_every: int = 10,
             numeric: bool = True, use_kernel=False,
             quantize_uploads: bool = False, seed: int = 0,
             engine: str = 'scan', wire: str = 'f32') -> History:
    """Deprecated shim over ``api.Experiment(..., SafaSpec(...))``.

    ``wire='int8'`` runs every round on the compressed-wire fast path
    (packed int8 uplink + fused dequant-aggregate kernel, 2 dispatches per
    round); ``quantize_uploads=True`` is the per-leaf reference form of
    the same wire (2 dispatches per leaf per client), kept as the
    bit-identity ground truth — the two are mutually exclusive."""
    _deprecated('run_safa', 'Experiment(task, env, SafaSpec(...), '
                'ExecSpec(...)).compile().run()')
    from repro.core import api
    exp = api.Experiment(
        task, env,
        api.SafaSpec(fraction=fraction, lag_tolerance=lag_tolerance,
                     quantize_uploads=quantize_uploads),
        api.ExecSpec(engine=engine, wire=wire, use_kernel=use_kernel,
                     eval_every=eval_every, numeric=numeric),
        rounds=rounds, seed=seed)
    return exp.compile().run()


def run_fedavg(task: Optional[Task], env: FLEnv, *, fraction: float,
               rounds: int, eval_every: int = 10, numeric: bool = True,
               seed: int = 0, fedcs: bool = False,
               engine: str = 'scan', wire: str = 'f32') -> History:
    """Deprecated shim over ``api.Experiment(..., FedAvgSpec/FedCSSpec)``.

    ``wire='int8'`` ships the uploads through the packed int8 wire
    (cross-protocol comparison against SAFA's compressed fast path)."""
    _deprecated('run_fedcs' if fedcs else 'run_fedavg',
                'Experiment(task, env, FedCSSpec(...) if fedcs else '
                'FedAvgSpec(...), ExecSpec(...)).compile().run()')
    from repro.core import api
    spec_cls = api.FedCSSpec if fedcs else api.FedAvgSpec
    exp = api.Experiment(
        task, env, spec_cls(fraction=fraction),
        api.ExecSpec(engine=engine, wire=wire, eval_every=eval_every,
                     numeric=numeric),
        rounds=rounds, seed=seed)
    return exp.compile().run()


def run_fedcs(task, env, **kw) -> History:
    return run_fedavg(task, env, fedcs=True, **kw)


def run_local(task: Optional[Task], env: FLEnv, *, fraction: float,
              rounds: int, eval_every: int = 10, numeric: bool = True,
              seed: int = 0, engine: str = 'scan', wire: str = 'f32',
              use_kernel=False) -> History:
    """Deprecated shim over ``api.Experiment(..., LocalSpec(...))``.

    Fully-local baseline: C-fraction of clients train each round with no
    aggregation; a weighted aggregation happens at eval points (and after
    the last round) only.  ``wire``/``use_kernel`` are accepted for
    signature parity and rejected by ``api.check_compat`` with the same
    message every surface uses."""
    _deprecated('run_local', 'Experiment(task, env, LocalSpec(...), '
                'ExecSpec(...)).compile().run()')
    from repro.core import api
    exp = api.Experiment(
        task, env, api.LocalSpec(fraction=fraction),
        api.ExecSpec(engine=engine, wire=wire, use_kernel=use_kernel,
                     eval_every=eval_every, numeric=numeric),
        rounds=rounds, seed=seed)
    return exp.compile().run()


def run_fedasync(task: Optional[Task], env: FLEnv, *, fraction: float = 1.0,
                 rounds: int = 100, eval_every: int = 10,
                 numeric: bool = True, alpha: float = 0.6,
                 staleness_exp: float = 0.5, seed: int = 0,
                 engine: str = 'scan', wire: str = 'f32',
                 use_kernel=False) -> History:
    """Deprecated shim over ``api.Experiment(..., FedAsyncSpec(...))``.

    FedAsync baseline (Xie et al. [9], paper §II): every willing client
    trains every round and the server merges each arriving update
    immediately with staleness-polynomial mixing
    alpha_eff = alpha * (1 + staleness)^(-staleness_exp).  ``fraction`` is
    ignored (fully asynchronous); ``wire``/``use_kernel`` are rejected by
    ``api.check_compat`` with the same message every surface uses."""
    del fraction
    _deprecated('run_fedasync', 'Experiment(task, env, FedAsyncSpec(...), '
                'ExecSpec(...)).compile().run()')
    from repro.core import api
    exp = api.Experiment(
        task, env, api.FedAsyncSpec(alpha=alpha, staleness_exp=staleness_exp),
        api.ExecSpec(engine=engine, wire=wire, use_kernel=use_kernel,
                     eval_every=eval_every, numeric=numeric),
        rounds=rounds, seed=seed)
    return exp.compile().run()


def run_sweep(task, members, *, rounds: int,
              proto: str = 'safa', eval_every: int = 10,
              numeric: bool = True, use_kernel=False,
              engine: str = 'fleet', shard: bool = True,
              wire: str = 'f32') -> list:
    """Deprecated shim over ``api.CompiledRunner.run_sweep``.

    Runs S = len(members) simulations of one protocol as a batched fleet
    and returns one ``History`` per member, in order.  ``task`` may also
    be a *list* of per-member Tasks (one per member, padded stacking) —
    the ``api.SweepSpec(members, tasks=...)`` spelling.

    ``use_kernel`` keeps its historical leniency: it only applies when
    ``proto == 'safa'`` and is silently ignored otherwise (the api path
    rejects it instead)."""
    _deprecated('run_sweep', 'Experiment(task, env, spec, ExecSpec(...))'
                '.compile().run_sweep(members)')
    from repro.core import api
    protocol_spec = api.spec(proto)
    if isinstance(task, (list, tuple)):
        sweep = api.SweepSpec(members=tuple(members), tasks=tuple(task))
        task = None
    else:
        sweep = list(members)
    exp = api.Experiment(
        task, members[0].env if members else None, protocol_spec,
        api.ExecSpec(engine=engine, wire=wire,
                     use_kernel=use_kernel if proto == 'safa' else False,
                     shard=shard, eval_every=eval_every, numeric=numeric),
        rounds=rounds)
    return exp.compile().run_sweep(sweep)


RUNNERS = {
    'safa': run_safa,
    'fedavg': run_fedavg,
    'fedcs': run_fedcs,
    'local': run_local,
    'fedasync': run_fedasync,
}

# Backwards-compatible alias (pre-unification name).  NOTE: the *new*
# registry keyed by spec type lives in ``repro.api.PROTOCOLS``.
PROTOCOLS = RUNNERS
