"""Synthetic federated datasets + partitioner.

The container is offline, so Boston/MNIST/KDDCup99 are replaced by synthetic
teacher-generated datasets with matched dimensionality and size (DESIGN.md
§6).  Partition sizes follow the paper's N(mu, 0.3 mu) imbalance model; a
Dirichlet label-skew option provides non-IID splits.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class FederatedData:
    """Stacked per-client batches: x [m, nb, B, ...], y [m, nb, B, ...]."""
    x: np.ndarray
    y: np.ndarray
    test_x: np.ndarray
    test_y: np.ndarray
    partition_sizes: np.ndarray


def make_regression(n=506, d=13, noise=0.3, seed=0):
    """Boston-housing-like regression: y = teacher(x) + noise, positive."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d,)).astype(np.float32)
    y = x @ w + noise * rng.normal(size=(n,)).astype(np.float32)
    y = (y - y.min() + 1.0).astype(np.float32)  # positive targets (house prices)
    return x, y


def make_images(n=4000, side=28, classes=10, seed=0):
    """MNIST-like: class-conditional low-rank Gaussian patterns."""
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(classes, side * side)).astype(np.float32)
    labels = rng.integers(0, classes, size=n)
    x = protos[labels] + 1.2 * rng.normal(size=(n, side * side)).astype(np.float32)
    return x.reshape(n, side, side, 1).astype(np.float32), labels.astype(np.int32)


def make_svm(n=20000, d=35, seed=0, flip=0.02):
    """KDD-like binary classification, labels in {-1, +1}."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=(d,)).astype(np.float32)
    y = np.sign(x @ w + 0.1 * rng.normal(size=(n,))).astype(np.float32)
    y[y == 0] = 1.0
    flips = rng.random(n) < flip
    y[flips] = -y[flips]
    return x, y


def partition(x, y, partition_sizes: np.ndarray, batch_size: int, *,
              test_frac=0.15, dirichlet_alpha: Optional[float] = None,
              seed=0) -> FederatedData:
    """Split (x, y) into per-client stacked batches.

    Every client is padded (wrap-around over its own samples) to the common
    batch count so replicas stack into [m, nb, B, ...]; aggregation weights
    still use the true partition sizes (Eq. 7)."""
    rng = np.random.default_rng(seed + 7)
    n = x.shape[0]
    n_test = int(n * test_frac)
    perm = rng.permutation(n)
    test_idx, pool = perm[:n_test], perm[n_test:]

    m = len(partition_sizes)
    sizes = np.maximum(1, (partition_sizes / partition_sizes.sum()
                           * len(pool)).astype(int))
    if dirichlet_alpha is not None and y.dtype.kind in 'iu':
        # label-skewed split: per-client class mixture ~ Dir(alpha)
        classes = np.unique(y[pool])
        by_class = {c: list(rng.permutation(pool[y[pool] == c])) for c in classes}
        client_idx = []
        for k in range(m):
            mix = rng.dirichlet(dirichlet_alpha * np.ones(len(classes)))
            want = np.maximum(1, (mix * sizes[k]).astype(int))
            got = []
            for c, w in zip(classes, want):
                take = by_class[c][:w]
                by_class[c] = by_class[c][w:]
                got.extend(take)
            if not got:
                got = [pool[rng.integers(len(pool))]]
            client_idx.append(np.array(got))
    else:
        splits = np.cumsum(sizes)[:-1]
        client_idx = np.split(rng.permutation(pool)[:sizes.sum()], splits)

    nb = max(1, int(np.ceil(max(len(ci) for ci in client_idx) / batch_size)))
    xs, ys = [], []
    for ci in client_idx:
        reps = nb * batch_size
        idx = np.resize(ci, reps)  # wrap-around padding
        xs.append(x[idx].reshape((nb, batch_size) + x.shape[1:]))
        ys.append(y[idx].reshape((nb, batch_size) + y.shape[1:]))
    return FederatedData(
        x=np.stack(xs), y=np.stack(ys),
        test_x=x[test_idx], test_y=y[test_idx],
        partition_sizes=np.array([len(ci) for ci in client_idx]))


def make_lm_tokens(n_docs=512, seq_len=128, vocab=512, seed=0):
    """Synthetic token streams from a first-order random Markov teacher
    (for federated LM examples)."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(0.3 * np.ones(vocab), size=vocab)
    toks = np.zeros((n_docs, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n_docs)
    for t in range(1, seq_len + 1):
        p = trans[toks[:, t - 1]]
        toks[:, t] = (p.cumsum(1) > rng.random((n_docs, 1))).argmax(1)
    return toks
