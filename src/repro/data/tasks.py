"""Concrete federated tasks mirroring the paper's three experiments.

Task 1: regression  (Boston-like,   m=5,   linear model, MSE)
Task 2: CNN         (MNIST-like,    m=100, 2x conv5x5 + fc, softmax)
Task 3: SVM         (KDD-like,      m=500, linear SVM, hinge loss)

Each implements ``repro.core.federation.Task``: ``local_train`` vmaps E
epochs of mini-batch SGD (Algorithm 2's client_update) over the stacked
clients dim.
"""
from __future__ import annotations

import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.federation import Task
from repro.data import FederatedData


class SupervisedTask(Task):
    def __init__(self, data: FederatedData, *, init_fn, loss_fn, acc_fn,
                 lr: float, epochs: int):
        self.data = data
        self.init_fn = init_fn
        self.loss_fn = loss_fn          # (params, x, y) -> scalar
        self.acc_fn = acc_fn            # (params, x, y) -> scalar
        self.epochs = epochs
        self.lr = lr
        self.opt = optim.sgd(lr)
        self._x = jnp.asarray(data.x)   # [m, nb, B, ...]
        self._y = jnp.asarray(data.y)
        self._train_jit = jax.jit(self._train_all)
        self._test_x = jnp.asarray(data.test_x)
        self._test_y = jnp.asarray(data.test_y)
        self._eval_jit = jax.jit(
            lambda p, ex, ey: (self.loss_fn(p, ex, ey), self.acc_fn(p, ex, ey)))

    def init_global(self, key):
        return self.init_fn(key)

    # -- client_update (Algorithm 2), vmapped over clients --------------------
    def _train_one(self, params, x, y):
        def epoch(params, _):
            def step(p, batch):
                bx, by = batch
                g = jax.grad(self.loss_fn)(p, bx, by)
                p, _ = self.opt.update(g, (), p)
                return p, None
            params, _ = jax.lax.scan(step, params, (x, y))
            return params, None
        params, _ = jax.lax.scan(epoch, params, None, length=self.epochs)
        return params

    def _train_all(self, stacked_params):
        return jax.vmap(self._train_one)(stacked_params, self._x, self._y)

    def local_train(self, stacked_params, round_idx: int):
        del round_idx  # full-pass SGD; order fixed as in the paper
        return self._train_jit(stacked_params)

    def _train_rows(self, params_rows, rows):
        return jax.vmap(self._train_one)(params_rows, self._x[rows],
                                         self._y[rows])

    def local_train_rows(self, params_rows, rows, round_idx):
        """Sparse-schedule rows-train contract: train only the K replicas
        in ``params_rows`` on clients ``rows``'s data.  Row for row this is
        the same ``_train_one`` trace ``local_train`` vmaps over all m, so
        a trained row is bit-identical to its dense counterpart (sentinel
        rows gather-clamp to real data; the engine discards their output
        via role masks)."""
        del round_idx
        if '_train_rows_jit' not in self.__dict__:
            self._train_rows_jit = jax.jit(self._train_rows)
        return self._train_rows_jit(params_rows, rows)

    def evaluate(self, global_params) -> dict:
        loss, acc = self._eval_jit(global_params, self._test_x, self._test_y)
        return {'loss': float(loss), 'acc': float(acc)}

    def fingerprint(self) -> str:
        """Identity of the training problem (client data + hypers) for
        checkpoint-resume verification — resuming a carry under different
        data would silently mix two runs."""
        if '_fingerprint' not in self.__dict__:
            h = hashlib.sha256()
            for a in (self.data.x, self.data.y, self.data.test_x,
                      self.data.test_y):
                h.update(np.ascontiguousarray(a).tobytes())
            h.update(repr((self.lr, self.epochs)).encode())
            self._fingerprint = \
                f'{type(self).__name__}:{h.hexdigest()[:16]}'
        return self._fingerprint


# ---------------------------------------------------------------------------
# Fleet-stacking: per-member Tasks for batched sweeps
# ---------------------------------------------------------------------------

class StackedSupervisedTask:
    """S ``SupervisedTask``s stacked fleet-major so a sweep whose members
    hold *different client data* (e.g. multi-``seed`` env grids with
    distinct partitions) still runs as one vmapped-scan dispatch.

    Members may disagree on batch count (partition sizes differ), so every
    member's [m, nb_s, B, ...] batch stack is zero-padded to the fleet
    maximum and a per-member [nb_max] validity mask rides along; the
    masked train step passes parameters through unchanged on padding
    batches, which keeps each member bit-identical to its own unpadded
    sequential run.  Members must share the model (leaf shapes), client
    count m, batch size and epoch count — the fleet compiles ONE program.

    This is not a ``Task`` itself: per-member init/eval stay with the
    member tasks; the fleet engines consume ``fleet_ctx()`` (a pytree of
    [S, ...] leaves vmapped alongside the carry) and ``fleet_train``.
    """

    def __init__(self, tasks):
        if not tasks:
            raise ValueError('empty task stack')
        t0 = tasks[0]
        if any(t.epochs != t0.epochs for t in tasks):
            raise ValueError('stacked tasks must share the epoch count')
        # one compiled program trains every member with t0's step, so the
        # steps must BE the same: silently training member s with member
        # 0's lr/loss would break the fleet==sequential bit-identity
        hypers = {(t.lr, t.loss_fn, t.acc_fn) for t in tasks}
        if len(hypers) != 1:
            raise ValueError(
                'stacked tasks must share lr/loss_fn/acc_fn (the fleet '
                'compiles one train step for all members); got '
                f'{len(hypers)} distinct combinations')
        shapes = {t._x.shape[:1] + t._x.shape[3:] for t in tasks}
        if len(shapes) != 1 or len({t._x.shape[2] for t in tasks}) != 1:
            raise ValueError(
                'stacked tasks must share (m, batch_size, features); got '
                f'x shapes {sorted(t._x.shape for t in tasks)}')
        self.tasks = tuple(tasks)
        self._t0 = t0
        nb = np.array([t._x.shape[1] for t in tasks])
        nb_max = int(nb.max())

        def pad(a, n):
            widths = [(0, 0), (0, n - a.shape[1])] + [(0, 0)] * (a.ndim - 2)
            return np.pad(np.asarray(a), widths)

        self._x = jnp.asarray(np.stack([pad(t._x, nb_max) for t in tasks]))
        self._y = jnp.asarray(np.stack([pad(t._y, nb_max) for t in tasks]))
        self._valid = jnp.asarray(np.arange(nb_max)[None, :] < nb[:, None])

    def fleet_ctx(self):
        """[S, ...] train context vmapped with the fleet carry."""
        return {'x': self._x, 'y': self._y, 'valid': self._valid}

    def fleet_train(self, stacked_params, round_idx, ctx):
        """One member's train call (invoked inside the fleet vmap, so
        ``stacked_params`` is [m, ...] and ``ctx`` leaves are that
        member's slices)."""
        del round_idx
        train = lambda p, x, y: self._train_one_masked(p, x, y, ctx['valid'])
        return jax.vmap(train)(stacked_params, ctx['x'], ctx['y'])

    def _train_one_masked(self, params, x, y, valid):
        """``SupervisedTask._train_one`` with a per-batch validity mask:
        padding steps compute and discard, returning the carry unchanged —
        an exact no-op, so the real steps' bits match the unpadded run."""
        t = self._t0

        def epoch(params, _):
            def step(p, batch):
                bx, by, v = batch
                g = jax.grad(t.loss_fn)(p, bx, by)
                p2, _ = t.opt.update(g, (), p)
                return jax.tree.map(lambda a, b: jnp.where(v, a, b), p2, p), \
                    None
            params, _ = jax.lax.scan(step, params, (x, y, valid))
            return params, None

        params, _ = jax.lax.scan(epoch, params, None, length=t.epochs)
        return params


def stack_tasks(tasks) -> StackedSupervisedTask:
    """Stack per-member ``SupervisedTask``s for a per-member-Task sweep
    (``repro.api.SweepSpec(tasks=...)``)."""
    return StackedSupervisedTask(list(tasks))


# ---------------------------------------------------------------------------
# Task 1: regression
# ---------------------------------------------------------------------------

def _reg_init(key, d=13):
    kw, _ = jax.random.split(key)
    return {'w': 0.01 * jax.random.normal(kw, (d,)), 'b': jnp.zeros(())}


def _reg_pred(p, x):
    # elementwise-mul + reduce rather than x @ w: dot_general's CPU lowering
    # re-tiles the contraction as batch dims fold in, so a fleet-vmapped run
    # would drift from single-run bits; this form lowers to a reduction
    # whose accumulation order is batch-size independent (test_fleet asserts
    # per-member bit-identity of safa_run_fleet vs sequential scan runs).
    return jnp.sum(x * p['w'], axis=-1) + p['b']


def _reg_loss(p, x, y):
    return jnp.mean(jnp.square(_reg_pred(p, x) - y))


def _reg_acc(p, x, y):
    """Paper Table III: acc = 1 - mean(|y - yhat| / max(y, yhat))."""
    yh = _reg_pred(p, x)
    return 1.0 - jnp.mean(jnp.abs(y - yh) / jnp.maximum(jnp.maximum(y, yh), 1e-6))


def regression_task(data: FederatedData, lr=1e-4, epochs=3) -> SupervisedTask:
    d = data.x.shape[-1]
    return SupervisedTask(data, init_fn=functools.partial(_reg_init, d=d),
                          loss_fn=_reg_loss, acc_fn=_reg_acc, lr=lr,
                          epochs=epochs)


# ---------------------------------------------------------------------------
# Task 2: CNN (2x conv 5x5 [20, 50 ch] + 2x2 maxpool + fc relu + softmax)
# ---------------------------------------------------------------------------

def _cnn_init(key, side=28, classes=10, c1=20, c2=50, hidden=128):
    ks = jax.random.split(key, 4)
    s = side // 4
    def conv_w(k, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(k, shape) / jnp.sqrt(fan_in)
    return {
        'c1': conv_w(ks[0], (5, 5, 1, c1)), 'b1': jnp.zeros((c1,)),
        'c2': conv_w(ks[1], (5, 5, c1, c2)), 'b2': jnp.zeros((c2,)),
        'f1': jax.random.normal(ks[2], (s * s * c2, hidden)) / jnp.sqrt(s * s * c2),
        'fb1': jnp.zeros((hidden,)),
        'f2': jax.random.normal(ks[3], (hidden, classes)) / jnp.sqrt(hidden),
        'fb2': jnp.zeros((classes,)),
    }


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), 'VALID')


def _cnn_logits(p, x):
    h = jax.lax.conv_general_dilated(x, p['c1'], (1, 1), 'SAME',
                                     dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    h = _maxpool2(jax.nn.relu(h + p['b1']))
    h = jax.lax.conv_general_dilated(h, p['c2'], (1, 1), 'SAME',
                                     dimension_numbers=('NHWC', 'HWIO', 'NHWC'))
    h = _maxpool2(jax.nn.relu(h + p['b2']))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ p['f1'] + p['fb1'])
    return h @ p['f2'] + p['fb2']


def _cnn_loss(p, x, y):
    logits = _cnn_logits(p, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _cnn_acc(p, x, y):
    return jnp.mean((jnp.argmax(_cnn_logits(p, x), -1) == y).astype(jnp.float32))


def cnn_task(data: FederatedData, lr=1e-3, epochs=5) -> SupervisedTask:
    side = data.x.shape[-3]
    classes = int(data.y.max()) + 1
    return SupervisedTask(
        data, init_fn=functools.partial(_cnn_init, side=side, classes=classes),
        loss_fn=_cnn_loss, acc_fn=_cnn_acc, lr=lr, epochs=epochs)


# ---------------------------------------------------------------------------
# Task 3: linear SVM, hinge loss, labels in {-1, +1}
# ---------------------------------------------------------------------------

def _svm_init(key, d=35):
    return {'w': 0.01 * jax.random.normal(key, (d,)), 'b': jnp.zeros(())}


def _svm_margin(p, x):
    # elementwise-mul + reduce for fleet-vmap bit-stability (see _reg_pred)
    return jnp.sum(x * p['w'], axis=-1) + p['b']


def _svm_loss(p, x, y, l2=1e-4):
    hinge = jnp.mean(jnp.maximum(0.0, 1.0 - y * _svm_margin(p, x)))
    return hinge + l2 * jnp.sum(jnp.square(p['w']))


def _svm_acc(p, x, y):
    """Paper Table III: mean(max(0, sign(y * yhat)))."""
    return jnp.mean(jnp.maximum(0.0, jnp.sign(y * _svm_margin(p, x))))


def svm_task(data: FederatedData, lr=1e-2, epochs=5) -> SupervisedTask:
    d = data.x.shape[-1]
    return SupervisedTask(data, init_fn=functools.partial(_svm_init, d=d),
                          loss_fn=_svm_loss, acc_fn=_svm_acc, lr=lr,
                          epochs=epochs)
