"""Fused SAFA discriminative aggregation (Eq. 6 + 7 + 8) as a Pallas TPU
kernel.

The server-side aggregation path is memory-bound: the naive three-step
composition reads the m cache entries three times (pre-update, weighted
reduce, post-update) and materialises two intermediate cache copies in HBM.
The fused kernel performs all three steps in one pass over parameter tiles
held in VMEM: per tile it reads cache/trained once, applies the Eq. 6 masks,
accumulates the Eq. 7 weighted sum, applies the Eq. 8 bypass write, and
emits the new global tile + new cache tile.  HBM traffic drops from
~5 model-sized reads + 3 writes to 2 reads + 2 writes (measured by
``benchmarks/kernels_bench.py``).

Layout: parameters are flattened to [m, N] (m = clients).  Grid is over N
tiles; each program instance sees the full clients column for its tile —
VMEM footprint = 2 * m * TILE * 4B (+ masks), e.g. m=32, TILE=2048 -> 512 KiB.

Two entry points share the kernel body:

* ``safa_aggregate`` — one [m, N] matrix (the leaf-wise path pads and
  launches this once per pytree leaf);
* ``safa_aggregate_packed`` — a pre-padded [m, N] buffer holding the whole
  model (see ``ops.pack_stacked``), launched exactly once per round with
  ``input_output_aliases`` donating the cache buffer to the new-cache
  output, so the server never holds two full cache copies.

The compressed-wire fast path adds ``safa_aggregate_packed_q8`` (+ fleet
variant): the trained operand arrives as the int8 wire format
(q [m, N] + per-QBLOCK f32 scales, see ``comm_quant.quantize_packed``)
and is dequantised *in-register* inside the same kernel body that applies
Eq. 6-8 — the f32 [m, N] client-update matrix is never materialised in
HBM on the aggregation input, and a fully compressed round is exactly two
dispatches (quantize + this kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# CPU containers run the kernel body in interpret mode; on TPU it compiles.
from repro.kernels.backend import INTERPRET
from repro.kernels.comm_quant import QBLOCK

DEFAULT_TILE = 2048

#: Static alias inventory: kernel body name -> the admissible
#: ``input_output_aliases`` forms its ``pallas_call`` sites declare, each
#: form a tuple of (input_index, output_index) pairs in flattened call
#: order.  ``repro.analysis`` cross-checks this dict against the call
#: sites in this module (REP005) and against the lowered jaxpr of every
#: registered engine cell (JAX003), so an alias that is dropped — or
#: silently added — fails CI.  Keep in lock-step with the pallas_call
#: sites below.  ``_kernel`` admits two forms because ``_launch`` serves
#: both the leaf-wise path (fresh cache output) and the packed path
#: (cache donated in place).
ALIAS_CONTRACTS = {
    '_kernel': ((), ((0, 1),)),          # cache -> new_cache when packed
    '_fleet_kernel': (((0, 1),),),       # cache -> new_cache
    '_q8_kernel': (((3, 1),),),          # cache -> new_cache
    '_q8_fleet_kernel': (((3, 1),),),
    '_rows_kernel': ((),),               # rows paths scatter via ops.py
    '_q8_rows_kernel': ((),),
    '_rows_fleet_kernel': ((),),
    '_q8_rows_fleet_kernel': ((),),
    '_tier_rows_kernel': (((2, 2),),),   # value buffer updated in place
    '_q8_tier_rows_kernel': (((5, 2),),),
}


def _agg_math(cache, trained, g, picked, undrafted, deprecated, w):
    """Eq. 6-8 on one [m, T] tile; returns (new_global [1, T], new_cache)."""
    # Eq. 6: pre-aggregation cache update
    c1 = jnp.where(deprecated & ~picked, g, cache)
    c1 = jnp.where(picked, trained, c1)
    # Eq. 7: weighted aggregation
    new_global = jnp.sum(c1.astype(jnp.float32) * w, axis=0,
                         keepdims=True).astype(cache.dtype)
    # Eq. 8: post-aggregation (bypass) cache update
    return new_global, jnp.where(undrafted, trained, c1)


def _kernel(cache_ref, trained_ref, global_ref, picked_ref, undrafted_ref,
            deprecated_ref, weights_ref, new_global_ref, new_cache_ref):
    new_global_ref[...], new_cache_ref[...] = _agg_math(
        cache_ref[...],                 # [m, T]
        trained_ref[...],               # [m, T]
        global_ref[...],                # [1, T]
        picked_ref[...] != 0,           # [m, 1]
        undrafted_ref[...] != 0,
        deprecated_ref[...] != 0,
        weights_ref[...])               # [m, 1] float32


def _fleet_kernel(cache_ref, trained_ref, global_ref, picked_ref,
                  undrafted_ref, deprecated_ref, weights_ref, new_global_ref,
                  new_cache_ref):
    """Fleet-batched body: each grid point (s, i) sees fleet member s's
    [1, m, T] tile; the leading fleet-block dim is squeezed so the math is
    exactly the single-run kernel's."""
    ng, nc = _agg_math(
        cache_ref[...][0],              # [m, T]
        trained_ref[...][0],
        global_ref[...][0],             # [1, T]
        picked_ref[...][0] != 0,        # [m, 1]
        undrafted_ref[...][0] != 0,
        deprecated_ref[...][0] != 0,
        weights_ref[...][0])
    new_global_ref[...] = ng[None]
    new_cache_ref[...] = nc[None]


def _launch(cache, trained, global_row, picked, undrafted, deprecated,
            weights, *, tile: int, alias_cache: bool):
    """Single fused dispatch over padded [m, N] operands (N % tile == 0)."""
    m, np_ = cache.shape
    grid = (np_ // tile,)
    col = lambda arr: arr.reshape(m, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile), lambda i: (0, i)),      # cache
            pl.BlockSpec((m, tile), lambda i: (0, i)),      # trained
            pl.BlockSpec((1, tile), lambda i: (0, i)),      # global
            pl.BlockSpec((m, 1), lambda i: (0, 0)),         # picked
            pl.BlockSpec((m, 1), lambda i: (0, 0)),         # undrafted
            pl.BlockSpec((m, 1), lambda i: (0, 0)),         # deprecated
            pl.BlockSpec((m, 1), lambda i: (0, 0)),         # weights
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((m, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), cache.dtype),
            jax.ShapeDtypeStruct((m, np_), cache.dtype),
        ],
        # the cache buffer is dead after the call: write new_cache in place
        input_output_aliases={0: 1} if alias_cache else {},
        interpret=INTERPRET,
    )(cache, trained, global_row, col(picked.astype(jnp.int32)),
      col(undrafted.astype(jnp.int32)), col(deprecated.astype(jnp.int32)),
      col(weights.astype(jnp.float32)))


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate(cache, trained, global_prev, picked, undrafted, deprecated,
                   weights, *, tile: int = DEFAULT_TILE):
    """cache/trained: [m, N]; global_prev: [N]; masks: [m] bool;
    weights: [m] f32.  Returns (new_global [N], new_cache [m, N])."""
    m, n = cache.shape
    pad = (-n) % tile
    if pad:
        cache = jnp.pad(cache, ((0, 0), (0, pad)))
        trained = jnp.pad(trained, ((0, 0), (0, pad)))
        global_prev = jnp.pad(global_prev, (0, pad))
    new_global, new_cache = _launch(
        cache, trained, global_prev.reshape(1, -1), picked, undrafted,
        deprecated, weights, tile=tile, alias_cache=False)
    return new_global[0, :n], new_cache[:, :n]


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed(cache, trained, global_prev, picked, undrafted,
                          deprecated, weights, *, tile: int = DEFAULT_TILE):
    """Whole-model variant: operands are pre-padded pack buffers
    (cache/trained: [m, N], global_prev: [N], N % tile == 0; see
    ``ops.pack_stacked``).  One kernel dispatch regardless of how many
    pytree leaves the model has; the cache input is aliased to the
    new-cache output.  Returns (new_global [N], new_cache [m, N])."""
    if cache.shape[1] % tile:
        raise ValueError(
            f'packed buffer width {cache.shape[1]} not a multiple of '
            f'tile={tile}; pack with pad_to=tile')
    new_global, new_cache = _launch(
        cache, trained, global_prev.reshape(1, -1), picked, undrafted,
        deprecated, weights, tile=tile, alias_cache=True)
    return new_global[0], new_cache


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_fleet(cache, trained, global_prev, picked,
                                undrafted, deprecated, weights, *,
                                tile: int = DEFAULT_TILE):
    """Fleet variant of ``safa_aggregate_packed``: the pack gains a leading
    fleet axis and the grid gains a fleet dimension.

    cache/trained: [S, m, N] pre-padded pack buffers (N % tile == 0);
    global_prev: [S, N]; masks/weights: [S, m].  One kernel dispatch runs
    Eq. 6-8 for all S independent servers over a (S, N // tile) grid, with
    the [S, m, N] cache buffer aliased to the new-cache output.  Returns
    (new_global [S, N], new_cache [S, m, N]).
    """
    s, m, np_ = cache.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid = (s, np_ // tile)
    col = lambda arr: arr.reshape(s, m, 1)
    new_global, new_cache = pl.pallas_call(
        _fleet_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),  # cache
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),  # trained
            pl.BlockSpec((1, 1, tile), lambda s, i: (s, 0, i)),  # global
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),     # picked
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),     # undrafted
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),     # deprecated
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),     # weights
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile), lambda s, i: (s, 0, i)),
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, 1, np_), cache.dtype),
            jax.ShapeDtypeStruct((s, m, np_), cache.dtype),
        ],
        input_output_aliases={0: 1},
        interpret=INTERPRET,
    )(cache, trained, global_prev.reshape(s, 1, np_),
      col(picked.astype(jnp.int32)), col(undrafted.astype(jnp.int32)),
      col(deprecated.astype(jnp.int32)),
      col(weights.astype(jnp.float32)))
    return new_global[:, 0], new_cache


# ---------------------------------------------------------------------------
# Compressed-wire fast path: fused int8 dequant -> Eq. 6-8
# ---------------------------------------------------------------------------

def _q8_math(q, scales, base, cache, global_row, picked, undrafted,
             deprecated, completed, weights):
    """Dequantise the int8 client rows in-register, substitute the base
    model for crashed clients (they upload nothing), then the shared
    Eq. 6-8 body.  Returns (new_global [1, T], new_cache, new_local):
    new_local is the post-wire trained matrix (base where crashed) — the
    clients' own view of the round, emitted so the caller never needs a
    separate dequantise dispatch."""
    m, t = q.shape
    deq = (q.astype(jnp.float32).reshape(m, t // QBLOCK, QBLOCK)
           * scales[:, :, None]).reshape(m, t)
    trained = jnp.where(completed, deq, base)
    ng, nc = _agg_math(cache, trained, global_row, picked, undrafted,
                       deprecated, weights)
    return ng, nc, trained


def _q8_kernel(q_ref, scale_ref, base_ref, cache_ref, global_ref, picked_ref,
               undrafted_ref, deprecated_ref, completed_ref, weights_ref,
               new_global_ref, new_cache_ref, new_local_ref):
    new_global_ref[...], new_cache_ref[...], new_local_ref[...] = _q8_math(
        q_ref[...],                     # [m, T] int8
        scale_ref[...],                 # [m, T/QBLOCK] f32
        base_ref[...],                  # [m, T]
        cache_ref[...],                 # [m, T]
        global_ref[...],                # [1, T]
        picked_ref[...] != 0,           # [m, 1]
        undrafted_ref[...] != 0,
        deprecated_ref[...] != 0,
        completed_ref[...] != 0,
        weights_ref[...])               # [m, 1] float32


def _q8_fleet_kernel(q_ref, scale_ref, base_ref, cache_ref, global_ref,
                     picked_ref, undrafted_ref, deprecated_ref, completed_ref,
                     weights_ref, new_global_ref, new_cache_ref,
                     new_local_ref):
    ng, nc, nl = _q8_math(
        q_ref[...][0], scale_ref[...][0], base_ref[...][0], cache_ref[...][0],
        global_ref[...][0], picked_ref[...][0] != 0,
        undrafted_ref[...][0] != 0, deprecated_ref[...][0] != 0,
        completed_ref[...][0] != 0, weights_ref[...][0])
    new_global_ref[...] = ng[None]
    new_cache_ref[...] = nc[None]
    new_local_ref[...] = nl[None]


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_q8(q, scales, base, cache, global_prev, picked,
                             undrafted, deprecated, completed, weights, *,
                             tile: int = DEFAULT_TILE):
    """Fused int8-wire Eq. 6-8: dequantise + aggregate in ONE dispatch.

    q: [m, N] int8 wire buffer; scales: [m, N/QBLOCK] f32 (both from
    ``comm_quant.quantize_packed`` on a QBLOCK-aligned pack — see
    ``ops.pack_spec(align=QBLOCK)``); base/cache: [m, N] f32 pack buffers
    (N % tile == 0); global_prev: [N]; picked/undrafted/deprecated/
    completed: [m] bool; weights: [m] f32.

    The kernel body dequantises each client tile in-register, replaces
    crashed clients' rows with their base model (no upload arrived), and
    applies the shared ``_agg_math``; the cache input is aliased to the
    new-cache output.  Returns (new_global [N], new_cache [m, N],
    new_local [m, N]) — new_local is the dequantised trained matrix with
    base rows for crashed clients, i.e. what every client locally holds
    after the round.
    """
    m, np_ = cache.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid = (np_ // tile,)
    col = lambda arr: arr.reshape(m, 1)
    new_global, new_cache, new_local = pl.pallas_call(
        _q8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile), lambda i: (0, i)),              # q
            pl.BlockSpec((m, tile // QBLOCK), lambda i: (0, i)),    # scales
            pl.BlockSpec((m, tile), lambda i: (0, i)),              # base
            pl.BlockSpec((m, tile), lambda i: (0, i)),              # cache
            pl.BlockSpec((1, tile), lambda i: (0, i)),              # global
            pl.BlockSpec((m, 1), lambda i: (0, 0)),                 # picked
            pl.BlockSpec((m, 1), lambda i: (0, 0)),                 # undrafted
            pl.BlockSpec((m, 1), lambda i: (0, 0)),                 # deprecated
            pl.BlockSpec((m, 1), lambda i: (0, 0)),                 # completed
            pl.BlockSpec((m, 1), lambda i: (0, 0)),                 # weights
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((m, tile), lambda i: (0, i)),
            pl.BlockSpec((m, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), cache.dtype),
            jax.ShapeDtypeStruct((m, np_), cache.dtype),
            jax.ShapeDtypeStruct((m, np_), cache.dtype),
        ],
        # the cache buffer is dead after the call: write new_cache in place
        input_output_aliases={3: 1},
        interpret=INTERPRET,
    )(q, scales, base, cache, global_prev.reshape(1, -1),
      col(picked.astype(jnp.int32)), col(undrafted.astype(jnp.int32)),
      col(deprecated.astype(jnp.int32)), col(completed.astype(jnp.int32)),
      col(weights.astype(jnp.float32)))
    return new_global[0], new_cache, new_local


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_q8_fleet(q, scales, base, cache, global_prev,
                                   picked, undrafted, deprecated, completed,
                                   weights, *, tile: int = DEFAULT_TILE):
    """Fleet variant of ``safa_aggregate_packed_q8``: every operand gains a
    leading fleet axis (q/scales/base/cache [S, m, ...], global_prev
    [S, N], masks/weights [S, m]) and the grid a fleet dimension — S
    compressed server aggregations in one dispatch, cache aliased.
    Returns (new_global [S, N], new_cache [S, m, N], new_local [S, m, N]).
    """
    s, m, np_ = cache.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid = (s, np_ // tile)
    col = lambda arr: arr.reshape(s, m, 1)
    new_global, new_cache, new_local = pl.pallas_call(
        _q8_fleet_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),     # q
            pl.BlockSpec((1, m, tile // QBLOCK),
                         lambda s, i: (s, 0, i)),                   # scales
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),     # base
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),     # cache
            pl.BlockSpec((1, 1, tile), lambda s, i: (s, 0, i)),     # global
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),        # picked
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),        # undrafted
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),        # deprecated
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),        # completed
            pl.BlockSpec((1, m, 1), lambda s, i: (s, 0, 0)),        # weights
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile), lambda s, i: (s, 0, i)),
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),
            pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, 1, np_), cache.dtype),
            jax.ShapeDtypeStruct((s, m, np_), cache.dtype),
            jax.ShapeDtypeStruct((s, m, np_), cache.dtype),
        ],
        input_output_aliases={3: 1},
        interpret=INTERPRET,
    )(q, scales, base, cache, global_prev.reshape(s, 1, np_),
      col(picked.astype(jnp.int32)), col(undrafted.astype(jnp.int32)),
      col(deprecated.astype(jnp.int32)), col(completed.astype(jnp.int32)),
      col(weights.astype(jnp.float32)))
    return new_global[:, 0], new_cache, new_local


# ---------------------------------------------------------------------------
# Sparse active-set path: rows-indexed Eq. 6-8 deltas
# ---------------------------------------------------------------------------
#
# At production scale only K = O(quota) of the m cache rows change per
# round.  The rows kernels take the active rows' indices as a *scalar-
# prefetched* operand (pltpu.PrefetchScalarGridSpec): the grid runs over
# (N // tile, K) with the slot dim innermost, each program instance
# gathers its cache row via the index map ``rows[k]`` — only [K, N] of the
# [m, N] cache ever streams through the kernel — and the Eq. 7 aggregate
# is maintained as a *delta* on the carried running sum
# ``agg = sum_k w_k cache_k``:
#
#     new_global = agg + sum_k w_k (c1_k - cache_k)     (Eq. 6+7)
#     new_agg    = new_global + sum_k w_k (c2_k - c1_k) (Eq. 8)
#
# The new-global/new-agg output blocks are revisited across the inner k
# iterations (initialised from agg at k == 0, accumulated after), which is
# the TPU-friendly consecutive-revisit pattern.  Sentinel slots point at
# the scratch row of an [m+1, N] buffer (see ``ops.gather_rows``) and
# carry zero weight, so padding is numerically inert.


def _rows_kernel(rows_ref, cache_ref, trained_ref, global_ref, agg_ref,
                 picked_ref, undrafted_ref, deprecated_ref, weights_ref,
                 new_global_ref, new_agg_ref, c2_ref):
    del rows_ref  # consumed by the index maps
    k = pl.program_id(1)
    c0 = cache_ref[...].astype(jnp.float32)     # [1, T] — gathered row
    tr = trained_ref[...].astype(jnp.float32)
    g = global_ref[...].astype(jnp.float32)
    p = picked_ref[...] != 0                    # [1, 1]
    u = undrafted_ref[...] != 0
    d = deprecated_ref[...] != 0
    w = weights_ref[...].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)               # Eq. 6
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)                   # Eq. 8
    c2_ref[...] = c2.astype(c2_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += w * (c1 - c0)        # Eq. 7 as a delta
    new_agg_ref[...] += w * (c2 - c0)


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_rows(cache, trained_rows, global_prev, agg, rows,
                               picked_r, undrafted_r, deprecated_r, w_rows,
                               *, tile: int = DEFAULT_TILE):
    """Rows-indexed Eq. 6-8: one dispatch touching only the K active rows.

    cache: [R, N] pack buffer (R = m, or m+1 with a trailing scratch row
    when ``rows`` uses the sentinel index m); trained_rows: [K, N] (the
    committed rows' post-wire uploads, base rows elsewhere); global_prev,
    agg: [N] (agg = the running Eq. 7 sum, f32); rows: [K] int32 < R;
    picked_r/undrafted_r/deprecated_r: [K] bool per-slot roles; w_rows:
    [K] f32 aggregation weights (0 at padding slots).

    Returns (new_global [N] f32, new_agg [N] f32, c2_rows [K, N]) — the
    caller scatters ``c2_rows`` back with ``ops.scatter_rows`` (the
    untouched cache rows are untouched by construction).
    """
    r, np_ = cache.shape
    k, _ = trained_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(np_ // tile, k),      # k innermost: agg blocks revisit
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j, rows: (rows[j], i)),  # cache
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),    # trained
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),    # global
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),    # agg
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # picked
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # undrafted
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # deprecated
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # weights
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),
        ])
    new_global, new_agg, c2 = pl.pallas_call(
        _rows_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((k, np_), cache.dtype),
        ],
        interpret=INTERPRET,
    )(rows.astype(jnp.int32), cache, trained_rows,
      global_prev.reshape(1, -1).astype(jnp.float32),
      agg.reshape(1, -1).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(w_rows.astype(jnp.float32)))
    return new_global[0], new_agg[0], c2


def _q8_rows_kernel(rows_ref, q_ref, scale_ref, base_ref, cache_ref,
                    global_ref, agg_ref, picked_ref, undrafted_ref,
                    deprecated_ref, completed_ref, weights_ref,
                    new_global_ref, new_agg_ref, c2_ref, local_ref):
    del rows_ref
    k = pl.program_id(1)
    _, t = q_ref.shape
    deq = (q_ref[...].astype(jnp.float32).reshape(1, t // QBLOCK, QBLOCK)
           * scale_ref[...][:, :, None]).reshape(1, t)
    tr = jnp.where(completed_ref[...] != 0, deq,
                   base_ref[...].astype(jnp.float32))
    local_ref[...] = tr.astype(local_ref.dtype)
    c0 = cache_ref[...].astype(jnp.float32)
    g = global_ref[...].astype(jnp.float32)
    p = picked_ref[...] != 0
    u = undrafted_ref[...] != 0
    d = deprecated_ref[...] != 0
    w = weights_ref[...].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)
    c2_ref[...] = c2.astype(c2_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += w * (c1 - c0)
    new_agg_ref[...] += w * (c2 - c0)


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_q8_rows(q_rows, scales_rows, base_rows, cache,
                                  global_prev, agg, rows, picked_r,
                                  undrafted_r, deprecated_r, completed_r,
                                  w_rows, *, tile: int = DEFAULT_TILE):
    """int8-wire variant of ``safa_aggregate_packed_rows``: the K active
    rows' uploads arrive as the wire format (q_rows [K, N] int8 +
    scales_rows [K, N/QBLOCK] f32) and are dequantised in-register;
    crashed slots (completed_r False) fall back to base_rows.  Returns
    (new_global [N] f32, new_agg [N] f32, c2_rows [K, N], local_rows
    [K, N]) — local_rows is each active client's post-round local model,
    for the caller to scatter into the local stack."""
    r, np_ = cache.shape
    k, _ = q_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(np_ // tile, k),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),    # q
            pl.BlockSpec((1, tile // QBLOCK),
                         lambda i, j, rows: (j, i)),               # scales
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),    # base
            pl.BlockSpec((1, tile), lambda i, j, rows: (rows[j], i)),  # cache
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),    # global
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),    # agg
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # picked
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # undrafted
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # deprecated
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # completed
            pl.BlockSpec((1, 1), lambda i, j, rows: (j, 0)),       # weights
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, rows: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),
            pl.BlockSpec((1, tile), lambda i, j, rows: (j, i)),
        ])
    new_global, new_agg, c2, local = pl.pallas_call(
        _q8_rows_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((k, np_), cache.dtype),
            jax.ShapeDtypeStruct((k, np_), cache.dtype),
        ],
        interpret=INTERPRET,
    )(rows.astype(jnp.int32), q_rows, scales_rows, base_rows, cache,
      global_prev.reshape(1, -1).astype(jnp.float32),
      agg.reshape(1, -1).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(completed_r.astype(jnp.int32)),
      col(w_rows.astype(jnp.float32)))
    return new_global[0], new_agg[0], c2, local


def _rows_fleet_kernel(rows_ref, cache_ref, trained_ref, global_ref, agg_ref,
                       picked_ref, undrafted_ref, deprecated_ref, weights_ref,
                       new_global_ref, new_agg_ref, c2_ref):
    del rows_ref
    k = pl.program_id(2)
    c0 = cache_ref[...][0].astype(jnp.float32)
    tr = trained_ref[...][0].astype(jnp.float32)
    g = global_ref[...][0].astype(jnp.float32)
    p = picked_ref[...][0] != 0
    u = undrafted_ref[...][0] != 0
    d = deprecated_ref[...][0] != 0
    w = weights_ref[...][0].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)
    c2_ref[...] = c2[None].astype(c2_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += (w * (c1 - c0))[None]
    new_agg_ref[...] += (w * (c2 - c0))[None]


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_rows_fleet(cache, trained_rows, global_prev, agg,
                                     rows, picked_r, undrafted_r,
                                     deprecated_r, w_rows, *,
                                     tile: int = DEFAULT_TILE):
    """Fleet variant of ``safa_aggregate_packed_rows``: cache [S, R, N],
    trained_rows [S, K, N], global_prev/agg [S, N], rows [S, K], roles/
    weights [S, K]; grid (S, N // tile, K).  Returns (new_global [S, N]
    f32, new_agg [S, N] f32, c2_rows [S, K, N])."""
    s, r, np_ = cache.shape
    _, k, _ = trained_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(s, k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, np_ // tile, k),
        in_specs=[
            pl.BlockSpec((1, 1, tile),
                         lambda b, i, j, rows: (b, rows[b, j], i)),  # cache
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
        ])
    new_global, new_agg, c2 = pl.pallas_call(
        _rows_fleet_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, 1, np_), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, np_), jnp.float32),
            jax.ShapeDtypeStruct((s, k, np_), cache.dtype),
        ],
        interpret=INTERPRET,
    )(rows.astype(jnp.int32), cache, trained_rows,
      global_prev.reshape(s, 1, np_).astype(jnp.float32),
      agg.reshape(s, 1, np_).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(w_rows.astype(jnp.float32)))
    return new_global[:, 0], new_agg[:, 0], c2


def _q8_rows_fleet_kernel(rows_ref, q_ref, scale_ref, base_ref, cache_ref,
                          global_ref, agg_ref, picked_ref, undrafted_ref,
                          deprecated_ref, completed_ref, weights_ref,
                          new_global_ref, new_agg_ref, c2_ref, local_ref):
    del rows_ref
    k = pl.program_id(2)
    _, _, t = q_ref.shape
    deq = (q_ref[...][0].astype(jnp.float32).reshape(1, t // QBLOCK, QBLOCK)
           * scale_ref[...][0][:, :, None]).reshape(1, t)
    tr = jnp.where(completed_ref[...][0] != 0, deq,
                   base_ref[...][0].astype(jnp.float32))
    local_ref[...] = tr[None].astype(local_ref.dtype)
    c0 = cache_ref[...][0].astype(jnp.float32)
    g = global_ref[...][0].astype(jnp.float32)
    p = picked_ref[...][0] != 0
    u = undrafted_ref[...][0] != 0
    d = deprecated_ref[...][0] != 0
    w = weights_ref[...][0].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)
    c2_ref[...] = c2[None].astype(c2_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += (w * (c1 - c0))[None]
    new_agg_ref[...] += (w * (c2 - c0))[None]


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_q8_rows_fleet(q_rows, scales_rows, base_rows, cache,
                                        global_prev, agg, rows, picked_r,
                                        undrafted_r, deprecated_r,
                                        completed_r, w_rows, *,
                                        tile: int = DEFAULT_TILE):
    """Fleet variant of ``safa_aggregate_packed_q8_rows`` (operands gain a
    leading fleet axis, grid (S, N // tile, K)).  Returns (new_global
    [S, N] f32, new_agg [S, N] f32, c2_rows [S, K, N], local_rows
    [S, K, N])."""
    s, r, np_ = cache.shape
    _, k, _ = q_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(s, k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, np_ // tile, k),
        in_specs=[
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
            pl.BlockSpec((1, 1, tile // QBLOCK),
                         lambda b, i, j, rows: (b, j, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
            pl.BlockSpec((1, 1, tile),
                         lambda b, i, j, rows: (b, rows[b, j], i)),  # cache
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, i, j, rows: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, 0, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
            pl.BlockSpec((1, 1, tile), lambda b, i, j, rows: (b, j, i)),
        ])
    new_global, new_agg, c2, local = pl.pallas_call(
        _q8_rows_fleet_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((s, 1, np_), jnp.float32),
            jax.ShapeDtypeStruct((s, 1, np_), jnp.float32),
            jax.ShapeDtypeStruct((s, k, np_), cache.dtype),
            jax.ShapeDtypeStruct((s, k, np_), cache.dtype),
        ],
        interpret=INTERPRET,
    )(rows.astype(jnp.int32), q_rows, scales_rows, base_rows, cache,
      global_prev.reshape(s, 1, np_).astype(jnp.float32),
      agg.reshape(s, 1, np_).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(completed_r.astype(jnp.int32)),
      col(w_rows.astype(jnp.float32)))
    return new_global[:, 0], new_agg[:, 0], c2, local


# ---------------------------------------------------------------------------
# Lag-tier path: slot-indirected rows kernels over the tier value buffer
# ---------------------------------------------------------------------------
#
# The tier engines (protocol.safa_round_sparse_tier_packed) carry one
# [capacity+1, N] value buffer instead of [m, N] local/cache stacks; the
# host schedule names each slot's cache-read slot (``srcs``) and cache-
# write slot (``dsts``), both scalar-prefetched.  The kernels below are the
# rows kernels with TWO prefetch operands and the c2 scatter folded in: the
# c2 output block lands directly at (dsts[j], i) and the buffer input is
# aliased to it, so one dispatch does Eq. 6-8, both delta sums, AND the
# cache write-back in place.  Sound because the host allocator guarantees
# per-round src/dst slot disjointness (a value written in round t is first
# read strictly later); the shared scratch slot (read AND written by inert
# slots) carries only zero-weight contributions, so its value never
# matters.  Dst-duplicate scratch writes resolve last-wins over the
# innermost grid dim, exactly like ``ops.scatter_rows``.


def _tier_rows_kernel(srcs_ref, dsts_ref, buf_ref, trained_ref, global_ref,
                      agg_ref, picked_ref, undrafted_ref, deprecated_ref,
                      weights_ref, new_global_ref, new_agg_ref, newbuf_ref):
    del srcs_ref, dsts_ref  # consumed by the index maps
    k = pl.program_id(1)
    c0 = buf_ref[...].astype(jnp.float32)       # [1, T] — slot-gathered row
    tr = trained_ref[...].astype(jnp.float32)
    g = global_ref[...].astype(jnp.float32)
    p = picked_ref[...] != 0                    # [1, 1]
    u = undrafted_ref[...] != 0
    d = deprecated_ref[...] != 0
    w = weights_ref[...].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)               # Eq. 6
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)                   # Eq. 8
    newbuf_ref[...] = c2.astype(newbuf_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += w * (c1 - c0)        # Eq. 7 as a delta
    new_agg_ref[...] += w * (c2 - c0)


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_tier_rows(buf, trained_rows, global_prev, agg,
                                    srcs, dsts, picked_r, undrafted_r,
                                    deprecated_r, w_rows, *,
                                    tile: int = DEFAULT_TILE):
    """Slot-indirected Eq. 6-8 with the cache write-back fused in place.

    buf: [capacity+1, N] tier value buffer (trailing scratch row);
    trained_rows: [K, N] post-wire uploads (base rows where not
    committed); global_prev, agg: [N]; srcs/dsts: [K] int32 slot ids
    (cache-read / cache-write, scratch == discard); roles/weights as in
    ``safa_aggregate_packed_rows``.  The buffer input aliases the new-
    buffer output, so untouched slots persist with zero traffic.  Returns
    (new_global [N] f32, new_agg [N] f32, new_buf [capacity+1, N])."""
    r, np_ = buf.shape
    k, _ = trained_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(np_ // tile, k),      # k innermost: agg blocks revisit
        in_specs=[
            pl.BlockSpec((1, tile),
                         lambda i, j, srcs, dsts: (srcs[j], i)),   # buf
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (j, i)),
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile),
                         lambda i, j, srcs, dsts: (dsts[j], i)),   # new buf
        ])
    new_global, new_agg, new_buf = pl.pallas_call(
        _tier_rows_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((r, np_), buf.dtype),
        ],
        # operands 0/1 are the prefetched slot ids, so buf is input index 2;
        # it aliases the new-buffer output (index 2) for the in-place write
        input_output_aliases={2: 2},
        interpret=INTERPRET,
    )(srcs.astype(jnp.int32), dsts.astype(jnp.int32), buf, trained_rows,
      global_prev.reshape(1, -1).astype(jnp.float32),
      agg.reshape(1, -1).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(w_rows.astype(jnp.float32)))
    return new_global[0], new_agg[0], new_buf


def _q8_tier_rows_kernel(srcs_ref, dsts_ref, q_ref, scale_ref, base_ref,
                         buf_ref, global_ref, agg_ref, picked_ref,
                         undrafted_ref, deprecated_ref, completed_ref,
                         weights_ref, new_global_ref, new_agg_ref,
                         newbuf_ref):
    del srcs_ref, dsts_ref
    k = pl.program_id(1)
    _, t = q_ref.shape
    deq = (q_ref[...].astype(jnp.float32).reshape(1, t // QBLOCK, QBLOCK)
           * scale_ref[...][:, :, None]).reshape(1, t)
    tr = jnp.where(completed_ref[...] != 0, deq,
                   base_ref[...].astype(jnp.float32))
    c0 = buf_ref[...].astype(jnp.float32)
    g = global_ref[...].astype(jnp.float32)
    p = picked_ref[...] != 0
    u = undrafted_ref[...] != 0
    d = deprecated_ref[...] != 0
    w = weights_ref[...].astype(jnp.float32)
    c1 = jnp.where(d & ~p, g, c0)
    c1 = jnp.where(p, tr, c1)
    c2 = jnp.where(u, tr, c1)
    newbuf_ref[...] = c2.astype(newbuf_ref.dtype)

    @pl.when(k == 0)
    def _():
        new_global_ref[...] = agg_ref[...]
        new_agg_ref[...] = agg_ref[...]

    new_global_ref[...] += w * (c1 - c0)
    new_agg_ref[...] += w * (c2 - c0)


@functools.partial(jax.jit, static_argnames=('tile',))
def safa_aggregate_packed_q8_tier_rows(q_rows, scales_rows, base_rows, buf,
                                       global_prev, agg, srcs, dsts,
                                       picked_r, undrafted_r, deprecated_r,
                                       completed_r, w_rows, *,
                                       tile: int = DEFAULT_TILE):
    """int8-wire variant of ``safa_aggregate_packed_tier_rows``: uploads
    arrive as the wire format and dequantise in-register; crashed slots
    fall back to base_rows.  No local output exists — tier local state is
    virtual (base rows are always version snapshots).  Returns
    (new_global [N] f32, new_agg [N] f32, new_buf [capacity+1, N])."""
    r, np_ = buf.shape
    k, _ = q_rows.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    col = lambda arr: arr.reshape(k, 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(np_ // tile, k),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (j, i)),   # q
            pl.BlockSpec((1, tile // QBLOCK),
                         lambda i, j, srcs, dsts: (j, i)),          # scales
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (j, i)),  # base
            pl.BlockSpec((1, tile),
                         lambda i, j, srcs, dsts: (srcs[j], i)),    # buf
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, srcs, dsts: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile), lambda i, j, srcs, dsts: (0, i)),
            pl.BlockSpec((1, tile),
                         lambda i, j, srcs, dsts: (dsts[j], i)),
        ])
    new_global, new_agg, new_buf = pl.pallas_call(
        _q8_tier_rows_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((r, np_), buf.dtype),
        ],
        # operands 0/1 are prefetched slot ids, so buf is input index 5;
        # it aliases the new-buffer output (index 2)
        input_output_aliases={5: 2},
        interpret=INTERPRET,
    )(srcs.astype(jnp.int32), dsts.astype(jnp.int32), q_rows, scales_rows,
      base_rows, buf,
      global_prev.reshape(1, -1).astype(jnp.float32),
      agg.reshape(1, -1).astype(jnp.float32),
      col(picked_r.astype(jnp.int32)), col(undrafted_r.astype(jnp.int32)),
      col(deprecated_r.astype(jnp.int32)), col(completed_r.astype(jnp.int32)),
      col(w_rows.astype(jnp.float32)))
    return new_global[0], new_agg[0], new_buf
