"""Int8 symmetric per-block quantisation for model transfer compression.

The paper assumes "models are usually compressed before transmission"
(§IV-A, model_size = 10MB after compression).  We make compression a
first-class, kernel-backed feature: client uploads / server distribution can
be quantised to int8 with one fp32 scale per QBLOCK values (4.03 bits/value
of overhead at QBLOCK=128... 0.25 extra bytes per 128), cutting uplink bytes
~3.97x vs f32.  Both directions run as single-pass Pallas kernels.

Two granularities are exposed:

* ``quantize`` / ``dequantize`` — one flat [N] vector per call (the
  per-leaf reference path: 2 dispatches per pytree leaf);
* ``quantize_packed`` / ``dequantize_packed`` (+ ``quantize_packed_fleet``)
  — a whole packed [m, N] (or [S, m, N]) upload buffer in ONE grid
  dispatch, each client row block-quantised independently.  This is the
  wire format of the compressed fast path: the simulated uplink carries
  the int8 buffer plus the [m, N/QBLOCK] f32 scale rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import INTERPRET

QBLOCK = 128
DEFAULT_TILE = 2048  # values per program instance; must be multiple of QBLOCK

#: Static alias inventory (see ``safa_aggregate.ALIAS_CONTRACTS`` for the
#: format): the quantisation kernels change width/dtype between input and
#: output, so none of them can — or do — alias.  ``repro.analysis`` holds
#: the lowered cells to exactly this (JAX003/REP005); a pallas kernel
#: added here without an entry fails the inventory check.
ALIAS_CONTRACTS = {
    '_quant_kernel': ((),),
    '_dequant_kernel': ((),),
    '_quant_packed_kernel': ((),),
    '_dequant_packed_kernel': ((),),
    '_quant_fleet_kernel': ((),),
}


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)              # [1, T]
    xb = x.reshape(-1, QBLOCK)                      # [T/QB, QB]
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(1, -1)
    scale_ref[...] = scale.reshape(1, -1)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32).reshape(-1, QBLOCK)
    scale = scale_ref[...].reshape(-1, 1)
    x_ref[...] = (q * scale).reshape(1, -1)


@functools.partial(jax.jit, static_argnames=('tile',))
def quantize(x, *, tile: int = DEFAULT_TILE):
    """x: [N] float -> (q [N] int8, scales [N/QBLOCK] f32).  N padded
    internally to a tile multiple."""
    n = x.shape[0]
    pad = (-n) % tile
    xp = jnp.pad(x, (0, pad)).reshape(1, -1)
    np_ = xp.shape[1]
    grid = (np_ // tile,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                   pl.BlockSpec((1, tile // QBLOCK), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int8),
                   jax.ShapeDtypeStruct((1, np_ // QBLOCK), jnp.float32)],
        interpret=INTERPRET,
    )(xp)
    n_scales = -(-n // QBLOCK)
    return q[0, :n], s[0, :n_scales]


@functools.partial(jax.jit, static_argnames=('tile', 'n'))
def dequantize(q, scales, *, n: int, tile: int = DEFAULT_TILE):
    """Inverse of ``quantize``; ``n`` = original length."""
    pad = (-n) % tile
    qp = jnp.pad(q, (0, pad)).reshape(1, -1)
    np_ = qp.shape[1]
    sp = jnp.pad(scales, (0, np_ // QBLOCK - scales.shape[0]),
                 constant_values=1.0).reshape(1, -1)
    grid = (np_ // tile,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                  pl.BlockSpec((1, tile // QBLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=INTERPRET,
    )(qp, sp)
    return x[0, :n]


# ---------------------------------------------------------------------------
# Packed wire format: whole [m, N] upload buffer, one dispatch
# ---------------------------------------------------------------------------

def _quant_packed_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)              # [m, T]
    m, t = x.shape
    xb = x.reshape(m, t // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(m, t)
    scale_ref[...] = scale.reshape(m, -1)


def _dequant_packed_kernel(q_ref, scale_ref, x_ref):
    m, t = x_ref.shape
    q = q_ref[...].astype(jnp.float32).reshape(m, t // QBLOCK, QBLOCK)
    x_ref[...] = (q * scale_ref[...][:, :, None]).reshape(m, t)


def _quant_fleet_kernel(x_ref, q_ref, scale_ref):
    """Fleet body: squeeze the leading [1, m, T] fleet-block dim so the
    math is exactly the single-buffer kernel's."""
    x = x_ref[...][0].astype(jnp.float32)           # [m, T]
    m, t = x.shape
    xb = x.reshape(m, t // QBLOCK, QBLOCK)
    amax = jnp.max(jnp.abs(xb), axis=2, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(m, t)[None]
    scale_ref[...] = scale.reshape(m, -1)[None]


def _check_packed(n: int, tile: int):
    if tile % QBLOCK:
        raise ValueError(f'tile={tile} not a multiple of QBLOCK={QBLOCK}')
    if n % tile:
        raise ValueError(
            f'packed buffer width {n} not a multiple of tile={tile}; pack '
            f'with pad_to=tile (see ops.pack_spec)')


@functools.partial(jax.jit, static_argnames=('tile',))
def quantize_packed(x, *, tile: int = DEFAULT_TILE):
    """Block-quantise a whole packed upload buffer in ONE grid dispatch.

    x: [m, N] f32 pack buffer (N % tile == 0; see ``ops.pack_spec``) ->
    (q [m, N] int8, scales [m, N/QBLOCK] f32).  Each client row is
    quantised independently — exactly what m per-client ``quantize``
    calls on QBLOCK-aligned leaves produce, in 1 dispatch instead of
    2 per leaf per client.
    """
    m, n = x.shape
    _check_packed(n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        _quant_packed_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((m, tile), lambda i: (0, i)),
                   pl.BlockSpec((m, tile // QBLOCK), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct((m, n // QBLOCK), jnp.float32)],
        interpret=INTERPRET,
    )(x)


@functools.partial(jax.jit, static_argnames=('tile',))
def dequantize_packed(q, scales, *, tile: int = DEFAULT_TILE):
    """Inverse of ``quantize_packed``: (q [m, N], scales [m, N/QBLOCK]) ->
    x [m, N] f32, one grid dispatch."""
    m, n = q.shape
    _check_packed(n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        _dequant_packed_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, tile), lambda i: (0, i)),
                  pl.BlockSpec((m, tile // QBLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(q, scales)


@functools.partial(jax.jit, static_argnames=('tile',))
def quantize_packed_fleet(x, *, tile: int = DEFAULT_TILE):
    """Fleet variant of ``quantize_packed``: x [S, m, N] -> (q [S, m, N],
    scales [S, m, N/QBLOCK]) over an explicit (S, N // tile) grid — all S
    servers' upload buffers quantised in one dispatch."""
    s, m, n = x.shape
    _check_packed(n, tile)
    grid = (s, n // tile)
    return pl.pallas_call(
        _quant_fleet_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i))],
        out_specs=[pl.BlockSpec((1, m, tile), lambda s, i: (s, 0, i)),
                   pl.BlockSpec((1, m, tile // QBLOCK),
                                lambda s, i: (s, 0, i))],
        out_shape=[jax.ShapeDtypeStruct((s, m, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, m, n // QBLOCK), jnp.float32)],
        interpret=INTERPRET,
    )(x)
