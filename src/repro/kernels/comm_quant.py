"""Int8 symmetric per-block quantisation for model transfer compression.

The paper assumes "models are usually compressed before transmission"
(§IV-A, model_size = 10MB after compression).  We make compression a
first-class, kernel-backed feature: client uploads / server distribution can
be quantised to int8 with one fp32 scale per QBLOCK values (4.03 bits/value
of overhead at QBLOCK=128... 0.25 extra bytes per 128), cutting uplink bytes
~3.97x vs f32.  Both directions run as single-pass Pallas kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128
DEFAULT_TILE = 2048  # values per program instance; must be multiple of QBLOCK
INTERPRET = jax.default_backend() != 'tpu'


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)              # [1, T]
    xb = x.reshape(-1, QBLOCK)                      # [T/QB, QB]
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    q_ref[...] = q.reshape(1, -1)
    scale_ref[...] = scale.reshape(1, -1)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32).reshape(-1, QBLOCK)
    scale = scale_ref[...].reshape(-1, 1)
    x_ref[...] = (q * scale).reshape(1, -1)


@functools.partial(jax.jit, static_argnames=('tile',))
def quantize(x, *, tile: int = DEFAULT_TILE):
    """x: [N] float -> (q [N] int8, scales [N/QBLOCK] f32).  N padded
    internally to a tile multiple."""
    n = x.shape[0]
    pad = (-n) % tile
    xp = jnp.pad(x, (0, pad)).reshape(1, -1)
    np_ = xp.shape[1]
    grid = (np_ // tile,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                   pl.BlockSpec((1, tile // QBLOCK), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, np_), jnp.int8),
                   jax.ShapeDtypeStruct((1, np_ // QBLOCK), jnp.float32)],
        interpret=INTERPRET,
    )(xp)
    n_scales = -(-n // QBLOCK)
    return q[0, :n], s[0, :n_scales]


@functools.partial(jax.jit, static_argnames=('tile', 'n'))
def dequantize(q, scales, *, n: int, tile: int = DEFAULT_TILE):
    """Inverse of ``quantize``; ``n`` = original length."""
    pad = (-n) % tile
    qp = jnp.pad(q, (0, pad)).reshape(1, -1)
    np_ = qp.shape[1]
    sp = jnp.pad(scales, (0, np_ // QBLOCK - scales.shape[0]),
                 constant_values=1.0).reshape(1, -1)
    grid = (np_ // tile,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i)),
                  pl.BlockSpec((1, tile // QBLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=INTERPRET,
    )(qp, sp)
    return x[0, :n]
