"""Pure-jnp oracles for every Pallas kernel (the test ground truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import attention as attn_mod


def safa_aggregate_ref(cache, trained, global_prev, picked, undrafted,
                       deprecated, weights):
    """Three-step discriminative aggregation on [m, N] matrices (Eq. 6-8)."""
    picked = picked[:, None]
    undrafted = undrafted[:, None]
    deprecated = deprecated[:, None]
    c1 = jnp.where(deprecated & ~picked, global_prev[None, :], cache)
    c1 = jnp.where(picked, trained, c1)
    new_global = jnp.sum(c1.astype(jnp.float32) * weights[:, None], axis=0)
    c2 = jnp.where(undrafted, trained, c1)
    return new_global.astype(cache.dtype), c2


def quantize_ref(x, qblock=128):
    n = x.shape[0]
    pad = (-n) % qblock
    xp = jnp.pad(x, (0, pad)).astype(jnp.float32).reshape(-1, qblock)
    amax = jnp.max(jnp.abs(xp), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:n], scale[:, 0]


def dequantize_ref(q, scales, n, qblock=128):
    pad = (-q.shape[0]) % qblock
    qp = jnp.pad(q, (0, pad)).astype(jnp.float32).reshape(-1, qblock)
    return (qp * scales[:, None]).reshape(-1)[:n]


def quantize_packed_ref(x, qblock=128):
    """Row-wise oracle for ``quantize_packed``: each client row of a
    [m, N] pack buffer block-quantised independently via ``quantize_ref``.
    N must already be a qblock multiple (pack buffers are)."""
    rows = [quantize_ref(row, qblock) for row in x]
    return (jnp.stack([q for q, _ in rows]),
            jnp.stack([s for _, s in rows]))


def dequantize_packed_ref(q, scales, qblock=128):
    """Row-wise oracle for ``dequantize_packed``."""
    n = q.shape[1]
    return jnp.stack([dequantize_ref(qr, sr, n, qblock)
                      for qr, sr in zip(q, scales)])


def safa_aggregate_q8_ref(q, scales, base, cache, global_prev, picked,
                          undrafted, deprecated, completed, weights):
    """Composition oracle for the fused int8 kernel: dequantise the wire
    rows, substitute base for crashed clients, then Eq. 6-8; also returns
    the post-wire trained matrix (the kernel's new_local output)."""
    trained = jnp.where(completed[:, None], dequantize_packed_ref(q, scales),
                        base)
    ng, nc = safa_aggregate_ref(cache, trained, global_prev, picked,
                                undrafted, deprecated, weights)
    return ng, nc, trained


def swa_attention_ref(q, k, v, *, window=None):
    """Causal (+window) attention oracle — the naive O(S^2) path."""
    return attn_mod.attention_ref(q, k, v, causal=True, window=window)
