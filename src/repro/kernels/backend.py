"""Shared Pallas backend detection for every kernel module.

Pallas kernels compile only on TPU; everywhere else (CPU containers, GPU
dev boxes) they execute through the interpreter for structural
validation.  Every kernel module used to carry its own copy of the
detection constant — this is the single home for it.
"""
from __future__ import annotations

import os

import jax


def use_interpret() -> bool:
    """True when pallas_call should run in interpret mode.

    ``REPRO_FORCE_INTERPRET`` overrides the backend detection for tests:
    ``1``/``true`` forces interpret mode even on TPU, ``0``/``false``
    forces compilation even off-TPU (useful only for asserting that the
    override plumbing itself works); unset or empty falls back to the
    backend detection."""
    env = os.environ.get('REPRO_FORCE_INTERPRET')
    if env:
        return env.lower() not in ('0', 'false')
    return jax.default_backend() != 'tpu'


# Captured once at import, like the per-module constants it replaces: a
# process runs all kernels on one backend.
INTERPRET = use_interpret()
