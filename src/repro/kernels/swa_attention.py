"""Flash-style sliding-window attention (Pallas TPU kernel).

Causal attention with an optional window: key j is visible to query i iff
0 <= i - j < window.  Online-softmax accumulation over KV blocks keeps the
working set at [block_q, block_k] in VMEM; out-of-band blocks (fully masked
by causality or the window) are skipped via ``pl.when``, so compute is
O(S * window) instead of O(S^2) — the TPU-native realisation of the
sliding-window attention used by h2o-danube3 (and the hybrid shared-attn
block).

Grid: (batch, head, num_q_blocks, num_kv_blocks); the KV-block axis is the
innermost (sequential accumulation into VMEM scratch).  GQA is handled by
mapping query head h to KV head h // (H // KH) in the K/V index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import INTERPRET

NEG_INF = -1e30

#: Static alias inventory (see ``safa_aggregate.ALIAS_CONTRACTS`` for the
#: format): the attention output is a fresh buffer — no operand aliasing.
ALIAS_CONTRACTS = {
    '_kernel': ((),),
}


def _compiler_params():
    """dimension_semantics: KV-block axis is sequential ('arbitrary')."""
    cls = getattr(pltpu, 'CompilerParams', None) or getattr(
        pltpu, 'TPUCompilerParams', None)
    if cls is None:
        return None
    return cls(dimension_semantics=('parallel', 'parallel', 'parallel',
                                    'arbitrary'))


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
            window, block_q, block_k, n_kv_blocks, seq_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal: block relevant iff k_start <= q_end; window: k_end >= q_start - window + 1
    relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant &= (k_start + block_k - 1) >= (q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (q_pos >= k_pos) & (k_pos < seq_len)
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=('window', 'block_q', 'block_k'))
def swa_attention(q, k, v, *, window=None, block_q: int = 128,
                  block_k: int = 128):
    """q: [B, S, H, D]; k, v: [B, S, KH, D] (H % KH == 0).  Causal, with an
    optional sliding window.  Returns [B, S, H, D]."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = D ** -0.5

    pad = (-S) % max(block_q, block_k)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    Sp = qp.shape[1]
    nq, nk = Sp // block_q, Sp // block_k

    kernel = functools.partial(
        _kernel, scale=scale, window=window, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=INTERPRET,
    )(qp, kp, vp)
    return out[:, :S]
