"""Jit'd public wrappers for the Pallas kernels, including pytree plumbing
so the protocol layer can call the fused aggregation on whole model trees.

Two tree-level aggregation paths are exposed:

* ``safa_aggregate_tree``        — one kernel dispatch per pytree leaf;
* ``safa_aggregate_tree_packed`` — the model is flattened once into a single
  [m, N_total] buffer (ragged leaves laid out at per-leaf offsets, padded
  once at the end to a tile multiple), so Eq. 6-8 runs as exactly one
  ``pallas_call`` per round regardless of model depth.

For fleet-major callers the pack gains a leading fleet axis:
``safa_aggregate_tree_packed_fleet`` flattens [S, m, ...] stacked trees into
one [S, m, N_total] buffer and aggregates all S independent servers in a
single explicit fleet-grid dispatch (``safa_aggregate_packed_fleet``).
Note the vmapped fleet *engine* does not call this entry point: inside
``protocol.safa_run_fleet`` the per-round ``safa_aggregate_packed`` call is
batched by JAX's vmap rule into an equivalent batched-grid launch.  Both
kernels share one Eq. 6-8 body (``safa_aggregate._agg_math``) and are
regression-tested against each other.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.protocol import AggregationResult
from repro.kernels.backend import INTERPRET
from repro.kernels.comm_quant import (QBLOCK, dequantize, dequantize_packed,
                                      quantize, quantize_packed,
                                      quantize_packed_fleet)
from repro.kernels.safa_aggregate import (DEFAULT_TILE, safa_aggregate,
                                          safa_aggregate_packed,
                                          safa_aggregate_packed_fleet,
                                          safa_aggregate_packed_q8,
                                          safa_aggregate_packed_q8_fleet,
                                          safa_aggregate_packed_q8_rows,
                                          safa_aggregate_packed_q8_rows_fleet,
                                          safa_aggregate_packed_q8_tier_rows,
                                          safa_aggregate_packed_rows,
                                          safa_aggregate_packed_rows_fleet,
                                          safa_aggregate_packed_tier_rows)
from repro.kernels.swa_attention import swa_attention

__all__ = ['safa_aggregate', 'safa_aggregate_packed',
           'safa_aggregate_packed_fleet', 'safa_aggregate_tree',
           'safa_aggregate_tree_packed', 'safa_aggregate_tree_packed_fleet',
           'safa_aggregate_packed_q8', 'safa_aggregate_packed_q8_fleet',
           'safa_aggregate_packed_rows', 'safa_aggregate_packed_rows_fleet',
           'safa_aggregate_packed_q8_rows',
           'safa_aggregate_packed_q8_rows_fleet',
           'safa_aggregate_packed_tier_rows',
           'safa_aggregate_packed_q8_tier_rows',
           'gather_rows', 'scatter_rows', 'gather_rows_fleet',
           'scatter_rows_fleet',
           'quantize', 'dequantize', 'quantize_packed', 'dequantize_packed',
           'quantize_packed_fleet', 'safa_compressed_update',
           'weighted_merge_packed', 'weighted_merge_tree_packed',
           'wire_roundtrip_packed', 'wire_spec',
           'swa_attention', 'quantize_tree', 'dequantize_tree',
           'PackSpec', 'pack_spec', 'pack_stacked', 'pack_global',
           'pack_fleet', 'unpack_fleet',
           'unpack_stacked', 'unpack_global', 'comm_bytes',
           'count_pallas_calls']


def count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a jaxpr — the number of kernel
    dispatches one execution of the traced function will issue (used by the
    dispatch-count benchmark and its regression test).  Descends into
    nested jaxprs held directly, as ClosedJaxprs, or in tuple params
    (e.g. lax.cond ``branches``)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == 'pallas_call':
            n += 1
        for p in eqn.params.values():
            for v in (p if isinstance(p, (tuple, list)) else (p,)):
                if hasattr(v, 'eqns'):                       # Jaxpr
                    n += count_pallas_calls(v)
                elif hasattr(getattr(v, 'jaxpr', None), 'eqns'):  # ClosedJaxpr
                    n += count_pallas_calls(v.jaxpr)
    return n


def safa_aggregate_tree(cache, trained, global_prev, *, picked, undrafted,
                        deprecated, weights) -> AggregationResult:
    """Apply the fused Eq. 6-8 kernel leaf-by-leaf over stacked pytrees.

    cache/trained: pytrees with leading clients dim m; global_prev: pytree.
    """
    def one(c, t, g):
        m = c.shape[0]
        ng, nc = safa_aggregate(
            c.reshape(m, -1), t.reshape(m, -1), g.reshape(-1).astype(c.dtype),
            picked, undrafted, deprecated, weights)
        return ng.reshape(g.shape).astype(g.dtype), nc.reshape(c.shape)

    flat_c, treedef = jax.tree_util.tree_flatten(cache)
    flat_t = jax.tree_util.tree_flatten(trained)[0]
    flat_g = jax.tree_util.tree_flatten(global_prev)[0]
    outs = [one(c, t, g) for c, t, g in zip(flat_c, flat_t, flat_g)]
    new_global = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_cache = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return AggregationResult(new_global, new_cache)


# ---------------------------------------------------------------------------
# Packed layout: whole model as one [*, N_total] buffer
# ---------------------------------------------------------------------------

class PackSpec(NamedTuple):
    """Static layout of a model pytree inside a flat pack buffer.

    ``offsets[i]:offsets[i] + sizes[i]`` holds leaf i (global shapes, i.e.
    without the clients dim); each leaf's slot is zero-padded up to the
    next leaf's offset (slots only exceed sizes under ``align > 1``);
    ``n_padded`` is the laid-out total rounded up to a tile multiple so
    kernels never re-pad per call."""
    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple
    offsets: tuple
    n_total: int
    n_padded: int

    def slot(self, i: int) -> int:
        """Width of leaf i's slot (its size plus alignment padding)."""
        nxt = self.offsets[i + 1] if i + 1 < len(self.offsets) \
            else self.n_total
        return nxt - self.offsets[i]


def pack_spec(global_tree, *, pad_to: int = DEFAULT_TILE,
              align: int = 1) -> PackSpec:
    """Build the layout from a *global* (unstacked) model pytree.

    ``align > 1`` rounds every leaf's slot up to an ``align`` multiple so
    leaf boundaries never share a block — the quantized wire format uses
    ``align=QBLOCK`` so packed per-QBLOCK scales match per-leaf
    quantisation bit for bit (see ``wire_spec``).

    ``pad_to`` must be a multiple of ``align``: the final tile padding is
    itself a run of alignment blocks, so a non-multiple would leave the
    last quantisation block straddling the buffer end (scales row shorter
    than the data row) and the kernels' ``n_padded // align`` reshapes
    would silently misalign."""
    if pad_to < 1 or align < 1:
        raise ValueError(
            f'pack_spec needs pad_to >= 1 and align >= 1, got '
            f'pad_to={pad_to}, align={align}')
    if pad_to % align:
        raise ValueError(
            f'pad_to={pad_to} is not a multiple of align={align}: the tile '
            'padding must consist of whole alignment blocks (pick pad_to as '
            'a multiple of align, or drop the alignment)')
    leaves, treedef = jax.tree_util.tree_flatten(global_tree)
    shapes = tuple(l.shape for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(l.size) for l in leaves)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s + ((-s) % align)
    n_padded = off + ((-off) % pad_to)
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, offsets=tuple(offsets), n_total=off,
                    n_padded=n_padded)


def wire_spec(global_tree, *, pad_to: int = DEFAULT_TILE) -> PackSpec:
    """The pack layout of the int8 wire format: QBLOCK-aligned leaf slots,
    so every quantisation block lies inside exactly one leaf of exactly one
    client row."""
    return pack_spec(global_tree, pad_to=pad_to, align=QBLOCK)


def _pack(leaves, lead_shape, spec: PackSpec, compute_dtype):
    flat = []
    for i, (l, size) in enumerate(zip(leaves, spec.sizes)):
        x = l.astype(compute_dtype).reshape(lead_shape + (-1,))
        gap = spec.slot(i) - size
        if gap:
            x = jnp.pad(x, [(0, 0)] * len(lead_shape) + [(0, gap)])
        flat.append(x)
    pad = spec.n_padded - spec.n_total
    if pad:
        flat.append(jnp.zeros(lead_shape + (pad,), compute_dtype))
    return jnp.concatenate(flat, axis=-1)


def pack_stacked(tree, spec: PackSpec, *, dtype=jnp.float32):
    """Stacked pytree ([m, ...] leaves) -> [m, n_padded] buffer."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    return _pack(leaves, (m,), spec, dtype)


def pack_global(tree, spec: PackSpec, *, dtype=jnp.float32):
    """Global pytree -> [n_padded] buffer."""
    return _pack(jax.tree_util.tree_leaves(tree), (), spec, dtype)


def _unpack(buf, spec: PackSpec, lead_shape):
    outs = []
    for shape, dt, size, off in zip(spec.shapes, spec.dtypes, spec.sizes,
                                    spec.offsets):
        leaf = buf[..., off:off + size].reshape(lead_shape + shape)
        outs.append(leaf.astype(dt))
    return jax.tree_util.tree_unflatten(spec.treedef, outs)


def unpack_stacked(buf, spec: PackSpec):
    """[m, n_padded] buffer -> stacked pytree."""
    return _unpack(buf, spec, (buf.shape[0],))


def unpack_global(buf, spec: PackSpec):
    """[n_padded] buffer -> global pytree."""
    return _unpack(buf, spec, ())


def pack_fleet(tree, spec: PackSpec, *, dtype=jnp.float32):
    """Fleet-stacked pytree ([S, m, ...] leaves) -> [S, m, n_padded] buffer.

    Fleet-stacked *global* trees ([S, ...] leaves) pack with
    ``pack_stacked`` — the leading axis is just S instead of m."""
    leaves = jax.tree_util.tree_leaves(tree)
    return _pack(leaves, leaves[0].shape[:2], spec, dtype)


def unpack_fleet(buf, spec: PackSpec):
    """[S, m, n_padded] buffer -> fleet-stacked pytree."""
    return _unpack(buf, spec, buf.shape[:2])


# ---------------------------------------------------------------------------
# Rows gather/scatter: the train-side pack path of sparse schedules
# ---------------------------------------------------------------------------
#
# Sparse engines keep the per-client state as one resident [m+1, n_padded]
# pack buffer (the trailing scratch row absorbs sentinel slots, idx == m)
# and move only the K = O(quota) active rows per round: ``gather_rows``
# pulls them out for local training, ``scatter_rows`` writes results back
# in place (the buffer is aliased to the output, so untouched rows are
# never copied).  Both use the same scalar-prefetch indexing as the
# rows-aggregation kernels in ``safa_aggregate``.


#: Static alias inventory for this module's pallas kernels (see
#: ``safa_aggregate.ALIAS_CONTRACTS`` for the format): the scatter
#: kernels alias the row buffer to the output — untouched rows never
#: move — and everything else is copy-out.  ``repro.analysis`` checks
#: this dict against the call sites (REP005) and lowered cells (JAX003).
ALIAS_CONTRACTS = {
    '_copy_kernel': ((),),
    '_scatter_kernel': (((2, 0),),),        # buf -> out (rows prefetched)
    '_copy_fleet_kernel': ((),),
    '_scatter_fleet_kernel': (((2, 0),),),
    '_weighted_merge_kernel': ((),),
}


def _copy_kernel(rows_ref, src_ref, dst_ref):
    del rows_ref  # consumed by the index maps
    dst_ref[...] = src_ref[...]


def _scatter_kernel(rows_ref, vals_ref, buf_ref, out_ref):
    del rows_ref, buf_ref  # buf only feeds the output via aliasing
    out_ref[...] = vals_ref[...]


@functools.partial(jax.jit, static_argnames=('tile',))
def gather_rows(buf, rows, *, tile: int = DEFAULT_TILE):
    """buf [R, N], rows [K] int32 < R -> [K, N] gathered rows (one
    dispatch; only K·N elements stream through)."""
    r, n = buf.shape
    k = rows.shape[0]
    if n % tile:
        raise ValueError(
            f'packed buffer width {n} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, n // tile),
        in_specs=[pl.BlockSpec((1, tile), lambda j, i, rows: (rows[j], i))],
        out_specs=pl.BlockSpec((1, tile), lambda j, i, rows: (j, i)))
    return pl.pallas_call(
        _copy_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, n), buf.dtype),
        interpret=INTERPRET)(rows.astype(jnp.int32), buf)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=('tile',))
def scatter_rows(buf, rows, vals, *, tile: int = DEFAULT_TILE):
    """Write vals [K, N] into buf [R, N] at ``rows`` and return the buffer
    (donated + aliased: untouched rows stay in place, no [R, N] copy).

    Duplicate row indices write in slot order (last wins); sentinel slots
    should point at a scratch row (R = m + 1, idx = m) so padding writes
    land harmlessly."""
    r, n = buf.shape
    k = rows.shape[0]
    if vals.shape != (k, n):
        raise ValueError(
            f'vals shape {vals.shape} does not match (K={k}, N={n})')
    if n % tile:
        raise ValueError(
            f'packed buffer width {n} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k, n // tile),
        in_specs=[pl.BlockSpec((1, tile), lambda j, i, rows: (j, i)),
                  pl.BlockSpec((1, tile), lambda j, i, rows: (rows[j], i))],
        out_specs=pl.BlockSpec((1, tile), lambda j, i, rows: (rows[j], i)))
    return pl.pallas_call(
        _scatter_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, n), buf.dtype),
        # operand 0 is the prefetched rows, so buf is input index 2
        input_output_aliases={2: 0},
        interpret=INTERPRET)(rows.astype(jnp.int32), vals, buf)


def _copy_fleet_kernel(rows_ref, src_ref, dst_ref):
    del rows_ref
    dst_ref[...] = src_ref[...]


def _scatter_fleet_kernel(rows_ref, vals_ref, buf_ref, out_ref):
    del rows_ref, buf_ref
    out_ref[...] = vals_ref[...]


@functools.partial(jax.jit, static_argnames=('tile',))
def gather_rows_fleet(buf, rows, *, tile: int = DEFAULT_TILE):
    """Fleet variant: buf [S, R, N], rows [S, K] -> [S, K, N]."""
    s, r, n = buf.shape
    k = rows.shape[1]
    if n % tile:
        raise ValueError(
            f'packed buffer width {n} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, k, n // tile),
        in_specs=[pl.BlockSpec((1, 1, tile),
                               lambda b, j, i, rows: (b, rows[b, j], i))],
        out_specs=pl.BlockSpec((1, 1, tile), lambda b, j, i, rows: (b, j, i)))
    return pl.pallas_call(
        _copy_fleet_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, k, n), buf.dtype),
        interpret=INTERPRET)(rows.astype(jnp.int32), buf)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=('tile',))
def scatter_rows_fleet(buf, rows, vals, *, tile: int = DEFAULT_TILE):
    """Fleet variant: write vals [S, K, N] into buf [S, R, N] at per-member
    ``rows`` [S, K] (donated + aliased, like ``scatter_rows``)."""
    s, r, n = buf.shape
    k = rows.shape[1]
    if vals.shape != (s, k, n):
        raise ValueError(
            f'vals shape {vals.shape} does not match (S={s}, K={k}, N={n})')
    if n % tile:
        raise ValueError(
            f'packed buffer width {n} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s, k, n // tile),
        in_specs=[pl.BlockSpec((1, 1, tile), lambda b, j, i, rows: (b, j, i)),
                  pl.BlockSpec((1, 1, tile),
                               lambda b, j, i, rows: (b, rows[b, j], i))],
        out_specs=pl.BlockSpec((1, 1, tile),
                               lambda b, j, i, rows: (b, rows[b, j], i)))
    return pl.pallas_call(
        _scatter_fleet_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, r, n), buf.dtype),
        input_output_aliases={2: 0},
        interpret=INTERPRET)(rows.astype(jnp.int32), vals, buf)


def safa_aggregate_tree_packed(cache, trained, global_prev, *, picked,
                               undrafted, deprecated, weights,
                               spec: PackSpec = None) -> AggregationResult:
    """Single-dispatch Eq. 6-8 over a whole model pytree.

    Flattens the three operand trees into pack buffers (a fusion-friendly
    concat, no kernel launches), runs ``safa_aggregate_packed`` exactly
    once, and unpacks the results.  ``spec`` may be precomputed by callers
    that aggregate every round (the layout only depends on the model).

    The pack buffer computes in float32, so only float32 models are
    accepted — other dtypes would silently diverge from the leaf-wise
    path (which computes in each leaf's own dtype); use
    ``safa_aggregate_tree`` for those."""
    if spec is None:
        spec = pack_spec(global_prev)
    _require_f32(spec)
    pc = pack_stacked(cache, spec)
    pt = pack_stacked(trained, spec)
    pg = pack_global(global_prev, spec)
    ng, nc = safa_aggregate_packed(pc, pt, pg, picked, undrafted, deprecated,
                                   weights)
    return AggregationResult(unpack_global(ng, spec), unpack_stacked(nc, spec))


def _require_f32(spec: PackSpec):
    bad = [str(d) for d in spec.dtypes if d != jnp.float32]
    if bad:
        raise TypeError(
            f'packed aggregation requires float32 leaves, got {bad}; use '
            'the leaf-wise safa_aggregate_tree for mixed/low-precision '
            'models')


def safa_aggregate_tree_packed_fleet(cache, trained, global_prev, *, picked,
                                     undrafted, deprecated, weights,
                                     spec: PackSpec = None
                                     ) -> AggregationResult:
    """Fleet-batched single-dispatch Eq. 6-8 over fleet-stacked pytrees.

    cache/trained: pytrees with [S, m, ...] leaves; global_prev: [S, ...]
    leaves; picked/undrafted/deprecated/weights: [S, m].  All S independent
    server aggregations run in ONE ``pallas_call`` over a (S, tiles) grid.
    ``spec`` is the per-member layout (built from one member's global
    tree); float32-only, like the single-run packed path.
    """
    if spec is None:
        spec = pack_spec(jax.tree.map(lambda g: g[0], global_prev))
    _require_f32(spec)
    pc = pack_fleet(cache, spec)
    pt = pack_fleet(trained, spec)
    pg = pack_stacked(global_prev, spec)        # [S, n_padded]
    ng, nc = safa_aggregate_packed_fleet(pc, pt, pg, picked, undrafted,
                                         deprecated, weights)
    return AggregationResult(unpack_stacked(ng, spec), unpack_fleet(nc, spec))


# ---------------------------------------------------------------------------
# Weighted-merge kernel: the staleness-adaptive aggregation family's
# server step as one fused dispatch
# ---------------------------------------------------------------------------

def _weighted_merge_kernel(trained_ref, global_ref, w_ref, out_ref):
    """One [m, T] tile of  (1 - sum(w)) * g + sum_k w_k * t_k.

    ``w`` carries the whole aggregation scheme: SEAFL's adaptive
    staleness weights arrive pre-normalised, and CSAFL's per-cluster
    sub-aggregates arrive pre-folded (w_k = alpha_g * what_k, zero off
    the cluster's committed set) — the masked cluster reduction happens
    implicitly through the zeros, so one operand serves every scheme."""
    g = global_ref[...]                               # [1, T]
    w = w_ref[...].astype(jnp.float32)                # [m, 1]
    residual = 1.0 - jnp.sum(w)
    agg = jnp.sum(trained_ref[...].astype(jnp.float32) * w, axis=0,
                  keepdims=True)
    out_ref[...] = (residual * g.astype(jnp.float32) + agg).astype(g.dtype)


@functools.partial(jax.jit, static_argnames=('tile',))
def weighted_merge_packed(trained, global_prev, wrow, *,
                          tile: int = DEFAULT_TILE):
    """Single fused weighted-merge dispatch on pre-padded pack buffers.

    trained: [m, N] packed client uploads (N % tile == 0, see
    ``pack_stacked``); global_prev: [N]; wrow: [m] f32 effective merge
    weights (0 for non-commits, sum <= 1).  One ``pallas_call`` over the
    N // tile grid computes ``(1 - sum(wrow)) * global + wrow @ trained``
    regardless of model depth; under the fleet engine's vmap the launch
    batches into an (S, tiles) grid.  Returns the new global row [N]."""
    m, np_ = trained.shape
    if np_ % tile:
        raise ValueError(
            f'packed buffer width {np_} not a multiple of tile={tile}; '
            f'pack with pad_to=tile')
    out = pl.pallas_call(
        _weighted_merge_kernel,
        grid=(np_ // tile,),
        in_specs=[
            pl.BlockSpec((m, tile), lambda i: (0, i)),      # trained
            pl.BlockSpec((1, tile), lambda i: (0, i)),      # global
            pl.BlockSpec((m, 1), lambda i: (0, 0)),         # wrow
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), trained.dtype),
        interpret=INTERPRET,
    )(trained, global_prev.reshape(1, -1),
      wrow.astype(jnp.float32).reshape(m, 1))
    return out[0]


def weighted_merge_tree_packed(trained, global_prev, *, wrow,
                               spec: PackSpec = None):
    """Single-dispatch weighted merge over a whole model pytree.

    Flattens the trained stack and the global tree into pack buffers (a
    fusion-friendly concat, no kernel launches), runs
    ``weighted_merge_packed`` exactly once, and unpacks the new global.
    ``spec`` may be precomputed by callers that merge every round (the
    layout only depends on the model).  Float32-only, like the other
    packed paths."""
    if spec is None:
        spec = pack_spec(global_prev)
    _require_f32(spec)
    pt = pack_stacked(trained, spec)
    pg = pack_global(global_prev, spec)
    return unpack_global(weighted_merge_packed(pt, pg, wrow), spec)


def quantize_tree(tree):
    """Quantise every leaf (for communication-compressed uploads)."""
    return jax.tree.map(lambda x: quantize(x.reshape(-1)), tree)


def dequantize_tree(qtree, like):
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    # flatten qtree only down to ``like``'s structure so each (q, scales)
    # pair stays intact — robust even when ``like`` itself contains tuples
    flat_q = treedef.flatten_up_to(qtree)
    outs = [dequantize(q, s, n=l.size).reshape(l.shape).astype(l.dtype)
            for (q, s), l in zip(flat_q, flat_l)]
    return jax.tree_util.tree_unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# Compressed wire path: packed int8 uplink in 2 dispatches total
# ---------------------------------------------------------------------------

def safa_compressed_update(base, trained, cache, global_prev, *, picked,
                           undrafted, deprecated, completed, weights,
                           spec: PackSpec = None):
    """One SAFA server step on the int8 wire: quantize + fused
    dequant-aggregate, exactly TWO kernel dispatches for any model depth.

    base/trained/cache: stacked pytrees ([m, ...] leaves); global_prev:
    global pytree; picked/undrafted/deprecated/completed: [m] bool;
    weights: [m] f32.  The trained tree is packed once
    (QBLOCK-aligned layout), block-quantised in one grid dispatch
    (``quantize_packed`` — the simulated uplink carries int8 + scales),
    and ``safa_aggregate_packed_q8`` dequantises it in-register while
    applying Eq. 6-8 with the cache buffer aliased.  Crashed clients'
    rows are replaced by their base model inside the kernel (no upload
    arrived).  Returns (new_global, new_local, new_cache) pytrees —
    the same triple ``protocol.safa_round`` hands back.

    Bit-identical to the per-leaf reference (each client quantising each
    leaf with ``quantize``/``dequantize`` before a packed aggregation):
    the QBLOCK-aligned layout keeps every quantisation block inside one
    leaf of one client row, so the scales — and therefore every
    dequantised value — agree exactly.
    """
    if spec is None:
        spec = wire_spec(global_prev)
    _require_f32(spec)
    q, scales = quantize_packed(pack_stacked(trained, spec))
    ng, nc, nl = safa_aggregate_packed_q8(
        q, scales, pack_stacked(base, spec), pack_stacked(cache, spec),
        pack_global(global_prev, spec), picked, undrafted, deprecated,
        completed, weights)
    return (unpack_global(ng, spec), unpack_stacked(nl, spec),
            unpack_stacked(nc, spec))


def wire_roundtrip_packed(tree, spec: PackSpec = None, *, like=None):
    """Simulate the int8 wire for a whole stacked pytree in 2 dispatches:
    pack -> ``quantize_packed`` -> ``dequantize_packed`` -> unpack.

    Used by protocols without a fused aggregation kernel (FedAvg/FedCS):
    the server sees exactly what a compressed transfer delivers, at
    packed-dispatch cost instead of 2 dispatches per leaf per client.
    ``like`` supplies the global tree for spec inference (defaults to the
    first client's row of ``tree``)."""
    if spec is None:
        if like is None:
            like = jax.tree.map(lambda a: a[0], tree)
        spec = wire_spec(like)
    _require_f32(spec)
    buf = pack_stacked(tree, spec)
    q, scales = quantize_packed(buf)
    return unpack_stacked(dequantize_packed(q, scales), spec)


def comm_bytes(tree, quantized: bool, *, layout: str = 'tree') -> int:
    """Bytes on the wire for one model transfer (benchmark accounting).

    ``layout='tree'`` counts the pytree leaves as shipped individually
    (per-leaf scale ceilings, no padding); ``layout='packed'`` counts the
    packed wire buffers as the fast path actually ships them — including
    the QBLOCK alignment / tile padding and the full scale rows of the
    quantized format, or the tile padding of a f32 pack."""
    if layout not in ('tree', 'packed'):
        raise ValueError(
            f"unknown layout {layout!r} (want 'tree' or 'packed')")
    leaves = jax.tree.leaves(tree)
    if layout == 'packed':
        spec = wire_spec(tree) if quantized else pack_spec(tree)
        if not quantized:
            return 4 * spec.n_padded
        return spec.n_padded + 4 * (spec.n_padded // QBLOCK)
    n = sum(l.size for l in leaves)
    if not quantized:
        return sum(l.size * l.dtype.itemsize for l in leaves)
    return n + 4 * sum(-(-l.size // QBLOCK) for l in leaves)
