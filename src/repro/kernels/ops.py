"""Jit'd public wrappers for the Pallas kernels, including pytree plumbing
so the protocol layer can call the fused aggregation on whole model trees.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.protocol import AggregationResult
from repro.kernels.comm_quant import dequantize, quantize
from repro.kernels.safa_aggregate import safa_aggregate
from repro.kernels.swa_attention import swa_attention

__all__ = ['safa_aggregate', 'safa_aggregate_tree', 'quantize', 'dequantize',
           'swa_attention', 'quantize_tree', 'dequantize_tree']


def safa_aggregate_tree(cache, trained, global_prev, *, picked, undrafted,
                        deprecated, weights) -> AggregationResult:
    """Apply the fused Eq. 6-8 kernel leaf-by-leaf over stacked pytrees.

    cache/trained: pytrees with leading clients dim m; global_prev: pytree.
    """
    def one(c, t, g):
        m = c.shape[0]
        ng, nc = safa_aggregate(
            c.reshape(m, -1), t.reshape(m, -1), g.reshape(-1).astype(c.dtype),
            picked, undrafted, deprecated, weights)
        return ng.reshape(g.shape).astype(g.dtype), nc.reshape(c.shape)

    flat_c, treedef = jax.tree_util.tree_flatten(cache)
    flat_t = jax.tree_util.tree_flatten(trained)[0]
    flat_g = jax.tree_util.tree_flatten(global_prev)[0]
    outs = [one(c, t, g) for c, t, g in zip(flat_c, flat_t, flat_g)]
    new_global = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_cache = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return AggregationResult(new_global, new_cache)


def quantize_tree(tree):
    """Quantise every leaf (for communication-compressed uploads)."""
    return jax.tree.map(lambda x: quantize(x.reshape(-1)), tree)


def dequantize_tree(qtree, like):
    flat_q, _ = jax.tree_util.tree_flatten(qtree, is_leaf=lambda x: isinstance(x, tuple))
    flat_l, treedef = jax.tree_util.tree_flatten(like)
    outs = [dequantize(q, s, n=l.size).reshape(l.shape).astype(l.dtype)
            for (q, s), l in zip(flat_q, flat_l)]
    return jax.tree_util.tree_unflatten(treedef, outs)


def comm_bytes(tree, quantized: bool) -> int:
    """Bytes on the wire for one model transfer (benchmark accounting)."""
    leaves = jax.tree.leaves(tree)
    n = sum(l.size for l in leaves)
    if not quantized:
        return sum(l.size * l.dtype.itemsize for l in leaves)
    from repro.kernels.comm_quant import QBLOCK
    return n + 4 * sum(-(-l.size // QBLOCK) for l in leaves)
