"""Public experiment API — the single entry point for running protocols.

Declarative specs (``SafaSpec``/``FedAvgSpec``/``FedCSSpec``/``LocalSpec``/
``FedAsyncSpec`` + ``ExecSpec``) feed the ``PROTOCOLS`` registry, and
``Experiment(...).compile()`` returns a ``CompiledRunner`` with
checkpoint/resume-capable ``run()`` / ``run_sweep(members)``.  See
``docs/ARCHITECTURE.md`` ("The API layer") for the full tour; the
implementation lives in ``repro.core.api``.
"""
from repro.core import api as _impl
from repro.core.api import *  # noqa: F401,F403

__all__ = list(_impl.__all__)
