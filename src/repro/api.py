"""Public experiment API — the single entry point for running protocols.

Declarative specs (``SafaSpec``/``FedAvgSpec``/``FedCSSpec``/``LocalSpec``/
``FedAsyncSpec``/``SeaflSpec``/``CsaflSpec`` + ``ExecSpec``) feed the
``PROTOCOLS`` registry, and ``Experiment(...).compile()`` returns a
``CompiledRunner`` with checkpoint/resume-capable ``run()`` /
``run_sweep(members)``.  See ``docs/ARCHITECTURE.md`` ("The API layer")
for the full tour; the implementation lives in ``repro.core.api``, with
the staleness-adaptive aggregation family (SEAFL/CSAFL/FedAsync
discounts) registered from ``repro.core.agg_schemes``.
"""
from repro.core import api as _impl
from repro.core.api import *  # noqa: F401,F403
# importing the module registers the SEAFL/CSAFL protocol defs
from repro.core.agg_schemes import (  # noqa: F401
    CsaflSpec, SeaflSpec, WEIGHTED_SCHEMES, precompute_weighted_schedule,
    staleness_discount)

__all__ = list(_impl.__all__) + [
    'CsaflSpec', 'SeaflSpec', 'WEIGHTED_SCHEMES',
    'precompute_weighted_schedule', 'staleness_discount',
]
