"""Client environment simulator (paper §IV-A).

Reproduces the paper's experimental environment model:
  * local data sizes  n_k ~ N(mu, 0.3 mu), mu = n/m      (data imbalance)
  * client performance s_k ~ Exp(lambda=1) batches/sec   (heterogeneity)
  * independent crash probability cr per client per round (unreliability)
  * timing model Eq. 17-19: T_train = |B_k| E / s_k; up/down-link at
    1.40 Mbps per client; server distribution at ``server_bw_mbps``.

SAFA-specific realism: a crashed client keeps its partial progress
(``pending``) and *resumes* next round — that is the paper's straggler;
synchronous protocols discard partial progress on re-selection.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class FLEnv:
    m: int                      # number of clients
    crash_prob: float           # cr
    dataset_size: int           # n
    batch_size: int             # B
    epochs: int                 # E
    t_lim: float                # round deadline (seconds)
    model_size_mb: float = 10.0
    client_bw_mbps: float = 1.40
    server_bw_mbps: float = 198.0   # ~0.404 s per model copy (paper tables)
    lambda_perf: float = 1.0
    seed: int = 0
    # Separate stream for the per-round crash draws.  ``None`` keeps the
    # seed's single-stream behaviour (round draws continue the partition/
    # perf stream); an int re-seeds only the round draws, so a multi-seed
    # fleet shares one population (same partitions, same task data) while
    # each member sees an independent crash/straggler history.
    draw_seed: Optional[int] = None

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        mu = self.dataset_size / self.m
        sizes = np.maximum(rng.normal(mu, 0.3 * mu, self.m), 1.0)
        self.partition_sizes = np.round(sizes).astype(int)
        self.n_batches = np.maximum(1, -(-self.partition_sizes // self.batch_size))
        # performance: batches per second, Exp(lambda); floor to avoid /0
        self.perf = np.maximum(rng.exponential(1.0 / self.lambda_perf, self.m), 1e-3)
        self._rng = rng if self.draw_seed is None \
            else np.random.default_rng(self.draw_seed)

    # -- per-client constants ------------------------------------------------
    @property
    def weights(self) -> np.ndarray:
        """Aggregation weights n_k / n (Eq. 7)."""
        return self.partition_sizes / self.partition_sizes.sum()

    @property
    def t_updown(self) -> float:
        """Model upload or download time per client (Eq. 17 terms)."""
        return self.model_size_mb * 8.0 / self.client_bw_mbps

    def t_dist(self, n_copies):
        """Server-side distribution overhead (Eq. 19).

        ``n_copies`` may be an int or an ndarray of per-round copy counts —
        the schedule precomputes call this with whole [rounds] (or
        [S, rounds]) count tensors at once."""
        return n_copies * self.model_size_mb * 8.0 / self.server_bw_mbps

    def full_train_time(self) -> np.ndarray:
        """T_train per client (Eq. 18)."""
        return self.n_batches * self.epochs / self.perf

    # -- per-round draws -------------------------------------------------------
    def draw_round(self):
        """Returns (crashed [m] bool, crash_frac [m] in (0,1)) — crash_frac
        is the fraction of this round's work done before the crash."""
        crashed = self._rng.random(self.m) < self.crash_prob
        crash_frac = self._rng.random(self.m)
        return crashed, crash_frac

    def draw_rounds(self, rounds: int):
        """Vectorised multi-round draw: (crashed [rounds, m] bool,
        crash_frac [rounds, m]).

        Consumes the generator stream in exactly the order ``rounds``
        sequential ``draw_round`` calls would (crash draw then frac draw per
        round), so schedule precompute reproduces the loop-driven event
        process bit for bit."""
        u = self._rng.random((rounds, 2, self.m))
        return u[:, 0, :] < self.crash_prob, u[:, 1, :]


def env_grid(base: dict, **axes: Sequence) -> list:
    """Cartesian grid of environments for fleet sweeps.

    ``base`` holds the shared ``FLEnv`` kwargs; each keyword argument names a
    constructor field and a sequence of values, e.g.::

        env_grid(dict(m=5, dataset_size=506, batch_size=5, epochs=3,
                      t_lim=830.0, seed=3),
                 crash_prob=(0.3, 0.7), draw_seed=range(4))

    yields 8 environments sweeping crash rate x rng stream.  Axes vary in
    row-major order (last axis fastest), so the member index of a config is
    predictable.  Keep ``seed``/``m``/``dataset_size`` in ``base`` when the
    fleet must share one client population (``federation.run_sweep``
    requires a shared Task, hence shared partitions).
    """
    keys = list(axes)
    envs = []
    for combo in itertools.product(*(axes[k] for k in keys)):
        kw = dict(base)
        kw.update(zip(keys, combo))
        envs.append(FLEnv(**kw))
    return envs
