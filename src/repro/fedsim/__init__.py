"""Client environment simulator (paper §IV-A) behind a declarative spec.

Reproduces the paper's experimental environment model:
  * local data sizes  n_k ~ N(mu, 0.3 mu), mu = n/m      (data imbalance)
  * client performance s_k ~ Exp(lambda=1) batches/sec   (heterogeneity)
  * independent crash probability cr per client per round (unreliability)
  * timing model Eq. 17-19: T_train = |B_k| E / s_k; up/down-link at
    1.40 Mbps per client; server distribution at ``server_bw_mbps``.

The declarative surface is :class:`EnvSpec` — a frozen dataclass
mirroring the protocol specs of ``repro.api`` — whose ``.build()``
realizes an :class:`Env` (partitions, perf draws, rng streams, trace
arrays).  Two fields go beyond the paper's static model:

* ``traces`` — a ``repro.fedsim.traces.TraceSpec`` giving per-round
  per-client availability / bandwidth / compute-speed multipliers
  (day/night cycles, Markov churn, device-class grids, replayed arrays).
  Constant all-ones traces are bit-identical to ``traces=None``.
* ``comm='wire'`` — derive the comm times from the *actual wire bytes*
  of the experiment's model under the active ``ExecSpec.wire``
  (``ops.comm_bytes``), instead of the static ``model_size_mb``.  The
  compressed int8 wire then genuinely shortens rounds and shifts
  CFCFM/FedCS selections — protocol outcomes, not just host throughput.

SAFA-specific realism: a crashed client keeps its partial progress
(``pending``) and *resumes* next round — that is the paper's straggler;
synchronous protocols discard partial progress on re-selection.

``FLEnv`` is the deprecated ad-hoc constructor, kept as a shim over
``EnvSpec(...).build()`` and golden-tested bit-identical to it.
"""
from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Optional, Sequence, Union

import numpy as np

from repro.fedsim.traces import (  # noqa: F401  (re-exported surface)
    ConstantTrace,
    DayNight,
    DeviceClass,
    DeviceClasses,
    MarkovChurn,
    Replay,
    TraceSpec,
    Traces,
)

__all__ = [
    'ConstantTrace', 'DayNight', 'DeviceClass', 'DeviceClasses', 'Env',
    'EnvSpec', 'FLEnv', 'MarkovChurn', 'Replay', 'RoundTiming', 'TraceSpec',
    'Traces', 'env_grid', 'validate_env_spec',
]

#: valid values of ``EnvSpec.comm``
COMM_MODES = ('static', 'wire')


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    """Declarative environment spec: crash / timing / trace fields only.

    ``build()`` realizes it into an :class:`Env`; every build draws fresh
    partition/perf/round-draw streams from ``seed`` (and ``draw_seed``),
    so a spec passed to several experiments (or sweep members) replays
    the same population and event stream in each — specs are values,
    environments are consumables."""
    m: int                      # number of clients
    crash_prob: float           # cr
    dataset_size: int           # n
    batch_size: int             # B
    epochs: int                 # E
    t_lim: float                # round deadline (seconds)
    model_size_mb: float = 10.0
    client_bw_mbps: float = 1.40
    server_bw_mbps: float = 198.0   # ~0.404 s per model copy (paper tables)
    lambda_perf: float = 1.0
    seed: int = 0
    # Separate stream for the per-round crash draws.  ``None`` keeps the
    # seed's single-stream behaviour (round draws continue the partition/
    # perf stream); an int re-seeds only the round draws, so a multi-seed
    # fleet shares one population (same partitions, same task data) while
    # each member sees an independent crash/straggler history.
    draw_seed: Optional[int] = None
    #: per-round heterogeneity traces (see ``repro.fedsim.traces``);
    #: ``None`` == the paper's static model.
    traces: Optional[TraceSpec] = None
    #: comm-time source: ``'static'`` uses ``model_size_mb``; ``'wire'``
    #: derives the up/downlink megabytes from the experiment model's
    #: actual wire bytes under the active ``ExecSpec.wire`` (the api
    #: layer injects them via ``Env.set_wire_mb`` before precompute).
    comm: str = 'static'

    def build(self) -> 'Env':
        """Realize the spec (validates fields, draws the population)."""
        return Env(self)

    def replace(self, **changes) -> 'EnvSpec':
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class RoundTiming:
    """Per-round per-client timing components, each ``[rounds, m]``:
    ``t_up``/``t_down`` model upload/download seconds, ``full_tt`` the
    full local training time.  Traceless environments return O(1)-memory
    broadcast views."""
    t_up: np.ndarray
    t_down: np.ndarray
    full_tt: np.ndarray


def validate_env_spec(spec: EnvSpec) -> None:
    """Field validation shared by ``EnvSpec.build`` and the api layer's
    ``check_compat`` (golden messages)."""
    if spec.m < 1:
        raise ValueError(f'm must be >= 1, got {spec.m}')
    if not 0.0 <= spec.crash_prob <= 1.0:
        raise ValueError(
            f'crash_prob must be in [0, 1], got {spec.crash_prob}')
    if spec.comm not in COMM_MODES:
        raise ValueError(
            f"unknown comm {spec.comm!r} (want 'static' or 'wire')")
    if spec.traces is not None and not isinstance(spec.traces, TraceSpec):
        raise TypeError(
            f'traces must be a fedsim TraceSpec (ConstantTrace/DayNight/'
            f'MarkovChurn/DeviceClasses/Replay), got '
            f'{type(spec.traces).__name__!r}')


class Env:
    """A realized environment: the spec's config fields as attributes,
    plus the drawn population (``partition_sizes``, ``perf``) and the
    round-draw rng.  Build from a spec (``EnvSpec(...).build()``).

    The rng stream is consumed by ``draw_rounds``/``draw_round`` exactly
    as the historical ``FLEnv`` consumed it — traces modulate the crash
    *threshold* the same uniforms are compared against, never the draws
    themselves, so constant traces reproduce the legacy schedules bit for
    bit."""

    def __init__(self, spec: EnvSpec):
        self._init_from_spec(spec)

    def _init_from_spec(self, spec: EnvSpec) -> None:
        validate_env_spec(spec)
        self.spec = spec
        for f in dataclasses.fields(spec):
            setattr(self, f.name, getattr(spec, f.name))
        rng = np.random.default_rng(self.seed)
        mu = self.dataset_size / self.m
        sizes = np.maximum(rng.normal(mu, 0.3 * mu, self.m), 1.0)
        self.partition_sizes = np.round(sizes).astype(int)
        self.n_batches = np.maximum(
            1, -(-self.partition_sizes // self.batch_size))
        # performance: batches per second, Exp(lambda); floor to avoid /0
        self.perf = np.maximum(
            rng.exponential(1.0 / self.lambda_perf, self.m), 1e-3)
        self._rng = rng if self.draw_seed is None \
            else np.random.default_rng(self.draw_seed)
        self._traces_cache = None       # (rounds, Traces)
        self._wire_mb = None            # (up_mb, down_mb) under comm='wire'
        self._draws_consumed = False    # set by draw_rounds (single-shot)

    # -- per-client constants -------------------------------------------------
    @property
    def weights(self) -> np.ndarray:
        """Aggregation weights n_k / n (Eq. 7)."""
        return self.partition_sizes / self.partition_sizes.sum()

    @property
    def has_traces(self) -> bool:
        return self.traces is not None

    @property
    def t_updown(self) -> float:
        """Static model upload-or-download time per client (Eq. 17 terms).
        Trace-aware precomputes use ``round_timing`` instead."""
        return self.model_size_mb * 8.0 / self.client_bw_mbps

    def t_dist(self, n_copies):
        """Server-side distribution overhead (Eq. 19).

        ``n_copies`` may be an int or an ndarray of per-round copy counts —
        the schedule precomputes call this with whole [rounds] (or
        [S, rounds]) count tensors at once."""
        return n_copies * self._dist_mb() * 8.0 / self.server_bw_mbps

    def full_train_time(self) -> np.ndarray:
        """T_train per client (Eq. 18), before any speed trace."""
        return self.n_batches * self.epochs / self.perf

    # -- wire-derived comm ------------------------------------------------------
    def set_wire_mb(self, up_mb: float, down_mb: float) -> None:
        """Install the wire-derived transfer sizes (``comm='wire'``): the
        api layer measures the experiment model's actual bytes under the
        active ``ExecSpec.wire`` (``ops.comm_bytes``) and injects them
        here before the schedule precompute runs."""
        self._wire_mb = (float(up_mb), float(down_mb))

    def _comm_mb(self):
        if self._wire_mb is not None:
            return self._wire_mb
        return self.model_size_mb, self.model_size_mb

    def _dist_mb(self) -> float:
        # server distribution ships the (uncompressed) global model
        return self._comm_mb()[1]

    # -- traces ---------------------------------------------------------------
    def round_traces(self, rounds: int) -> Optional[Traces]:
        """The realized ``[rounds, m]`` trace bundle (``None`` without
        traces).  Cached per ``rounds``; realization is deterministic in
        the trace spec's own seed and never touches the env rng."""
        if self.traces is None:
            return None
        if self._traces_cache is None or self._traces_cache[0] != rounds:
            self._traces_cache = (rounds,
                                  self.traces.realize(rounds, self.m))
        return self._traces_cache[1]

    def round_timing(self, rounds: int) -> RoundTiming:
        """Per-round timing components, trace- and wire-aware.

        Without traces the arrays are broadcast views of the static
        scalars, elementwise bit-equal to the legacy ``t_updown`` /
        ``full_train_time()`` expressions — which is what keeps the
        array-driven precomputes bit-identical to the historical scalar
        ones (regression-tested)."""
        up_mb, down_mb = self._comm_mb()
        base_tt = self.full_train_time()
        shape = (rounds, self.m)
        tr = self.round_traces(rounds)
        if tr is None:
            return RoundTiming(
                t_up=np.broadcast_to(
                    np.float64(up_mb * 8.0 / self.client_bw_mbps), shape),
                t_down=np.broadcast_to(
                    np.float64(down_mb * 8.0 / self.client_bw_mbps), shape),
                full_tt=np.broadcast_to(base_tt, shape))
        bw = self.client_bw_mbps * tr.bandwidth
        return RoundTiming(t_up=up_mb * 8.0 / bw,
                           t_down=down_mb * 8.0 / bw,
                           full_tt=base_tt / tr.speed)

    def _crash_threshold(self, rounds: int):
        """Per-round crash threshold the uniform draws are compared
        against.  ``availability == 1`` must keep the *exact*
        ``crash_prob`` float (``1 - (1 - cr)`` re-rounds), hence the
        where-guard; ``availability == 0`` gives threshold 1.0 — certain
        crash, since draws lie in [0, 1)."""
        tr = self.round_traces(rounds)
        if tr is None:
            return self.crash_prob
        a = tr.availability
        return np.where(a >= 1.0, self.crash_prob,
                        1.0 - a * (1.0 - self.crash_prob))

    # -- per-round draws -------------------------------------------------------
    def draw_round(self):
        """Returns (crashed [m] bool, crash_frac [m] in (0,1)) — crash_frac
        is the fraction of this round's work done before the crash.

        Legacy single-round form: it has no round index, so it uses the
        static ``crash_prob`` (traces apply through ``draw_rounds``)."""
        crashed = self._rng.random(self.m) < self.crash_prob
        crash_frac = self._rng.random(self.m)
        return crashed, crash_frac

    def draw_rounds(self, rounds: int):
        """Vectorised multi-round draw: (crashed [rounds, m] bool,
        crash_frac [rounds, m]).

        Consumes the generator stream in exactly the order ``rounds``
        sequential ``draw_round`` calls would (crash draw then frac draw per
        round), so schedule precompute reproduces the loop-driven event
        process bit for bit.  Availability traces raise the comparison
        threshold without touching the uniforms, so constant traces keep
        the legacy masks exactly.

        Single-shot per built env: a second call would silently continue
        the generator stream, so the "same" experiment replayed on a
        reused env gets different crash masks than a fresh one — a
        classic source of unreproducible sweeps.  Reuse raises; build a
        fresh env per experiment (or hand the declarative ``EnvSpec`` to
        the api layer, which builds one for you)."""
        if self._draws_consumed:
            raise RuntimeError(
                'env rng already consumed: draw_rounds() was called once '
                'before on this built Env, so a second schedule precompute '
                'would continue the generator stream and diverge from a '
                'fresh environment. Build a fresh env per experiment — '
                'EnvSpec(...).build() — or pass the EnvSpec itself to '
                'api.Experiment / api.SweepMember (the api layer builds '
                'each run its own env).')
        self._draws_consumed = True
        u = self._rng.random((rounds, 2, self.m))
        return u[:, 0, :] < self._crash_threshold(rounds), u[:, 1, :]


@dataclasses.dataclass
class FLEnv(Env):
    """Deprecated ad-hoc constructor — a shim over ``EnvSpec(...).build()``
    (bit-identical, regression-tested).  Spell new code as::

        env = EnvSpec(m=5, crash_prob=0.3, ...).build()

    or pass the ``EnvSpec`` itself to ``api.Experiment`` /
    ``api.SweepMember`` (the api layer builds it)."""
    m: int
    crash_prob: float
    dataset_size: int
    batch_size: int
    epochs: int
    t_lim: float
    model_size_mb: float = 10.0
    client_bw_mbps: float = 1.40
    server_bw_mbps: float = 198.0
    lambda_perf: float = 1.0
    seed: int = 0
    draw_seed: Optional[int] = None

    def __post_init__(self):
        warnings.warn(
            'fedsim.FLEnv is deprecated; spell it as '
            'fedsim.EnvSpec(...).build() (or pass the EnvSpec to '
            'api.Experiment / api.SweepMember — see docs/ARCHITECTURE.md, '
            '"Environment & traces")',
            DeprecationWarning, stacklevel=3)
        self._init_from_spec(EnvSpec(**{
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(FLEnv)}))


def env_grid(base: Union[dict, EnvSpec], **axes: Sequence) -> list:
    """Cartesian grid of environment specs for fleet sweeps.

    ``base`` is the shared ``EnvSpec`` (or a dict of its kwargs); each
    keyword argument names a spec field and a sequence of values, e.g.::

        env_grid(EnvSpec(m=5, crash_prob=0.3, dataset_size=506,
                         batch_size=5, epochs=3, t_lim=830.0, seed=3),
                 crash_prob=(0.3, 0.7), draw_seed=range(4))

    yields 8 environments sweeping crash rate x rng stream.  Axes vary in
    row-major order (last axis fastest), so the member index of a config
    is predictable.  Keep ``seed``/``m``/``dataset_size`` in ``base``
    when the fleet must share one client population (a shared Task needs
    shared partitions).

    An ``EnvSpec`` base returns ``EnvSpec``s (declarative — hand them to
    ``api.SweepMember``, which builds each member a fresh env); a dict
    base returns *built* ``Env``s, matching the historical FLEnv-list
    behaviour."""
    if isinstance(base, EnvSpec):
        specs = [base.replace(**dict(zip(axes, combo)))
                 for combo in itertools.product(*axes.values())]
        return specs
    keys = list(axes)
    envs = []
    for combo in itertools.product(*(axes[k] for k in keys)):
        kw = dict(base)
        kw.update(zip(keys, combo))
        envs.append(EnvSpec(**kw).build())
    return envs
