"""Trace-driven device heterogeneity: declarative trace specs realized as
``[rounds, m]`` availability / bandwidth / compute-speed arrays.

A :class:`TraceSpec` describes how a fleet's conditions vary over time;
``realize(rounds, m)`` expands it into a :class:`Traces` bundle of three
``[rounds, m]`` arrays the environment folds into its per-round crash
thresholds and timing draws (``Env.draw_rounds`` / ``Env.round_timing``):

* ``availability`` in [0, 1] — scales a client's survival probability.
  1.0 keeps the env's base ``crash_prob``; 0.0 means certainly crashed
  that round (the effective crash probability is
  ``1 - availability * (1 - crash_prob)``).
* ``bandwidth`` > 0 — multiplies ``client_bw_mbps`` (0.5 == half speed).
* ``speed`` > 0 — multiplies the client's training rate (``perf``).

All generators are deterministic functions of their own ``seed`` field:
realizing a trace never touches the env rng, so adding (or re-realizing)
traces cannot perturb the crash/straggler draw stream.  A constant trace
of all-ones is the identity — schedules under it are bit-identical to the
traceless environment (regression-tested).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

__all__ = [
    'ConstantTrace', 'DayNight', 'DeviceClass', 'DeviceClasses',
    'MarkovChurn', 'Replay', 'TraceSpec', 'Traces',
]


@dataclasses.dataclass(frozen=True)
class Traces:
    """A realized trace bundle: three ``[rounds, m]`` float arrays (the
    constant generators return broadcast views, so an all-constant bundle
    costs O(1) memory at any scale)."""
    availability: np.ndarray
    bandwidth: np.ndarray
    speed: np.ndarray


def _bundle(rounds: int, m: int, availability, bandwidth, speed) -> Traces:
    """Broadcast-to-shape + range validation shared by every generator."""
    shape = (rounds, m)
    out = []
    for name, arr in (('availability', availability),
                      ('bandwidth', bandwidth), ('speed', speed)):
        a = np.broadcast_to(np.asarray(arr, dtype=float), shape)
        if name == 'availability':
            if a.min() < 0.0 or a.max() > 1.0:
                raise ValueError(
                    f'availability trace must lie in [0, 1], got range '
                    f'[{a.min()}, {a.max()}]')
        elif a.min() <= 0.0:
            raise ValueError(
                f'{name} trace must be > 0 (it scales a rate), got min '
                f'{a.min()}')
        out.append(a)
    return Traces(*out)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Base class for declarative trace specs.  Frozen and hashable like
    the protocol specs; ``realize(rounds, m)`` is a pure function of the
    spec fields (generators seed their own rng)."""

    def realize(self, rounds: int, m: int) -> Traces:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ConstantTrace(TraceSpec):
    """Round-invariant conditions.  The all-defaults spelling is the
    identity trace: schedules under it are bit-identical to
    ``traces=None`` (the golden EnvSpec-vs-FLEnv contract)."""
    availability: float = 1.0
    bandwidth: float = 1.0
    speed: float = 1.0

    def realize(self, rounds: int, m: int) -> Traces:
        return _bundle(rounds, m, self.availability, self.bandwidth,
                       self.speed)


@dataclasses.dataclass(frozen=True)
class DayNight(TraceSpec):
    """Diurnal cycle: each client is 'day' for ``day_fraction`` of every
    ``period`` rounds and 'night' otherwise, with night-time availability
    / bandwidth / speed scaled down.  ``spread=True`` gives every client
    its own phase offset (timezones), drawn once from ``seed``."""
    period: int = 24
    day_fraction: float = 0.5
    night_availability: float = 0.25
    night_bandwidth: float = 1.0
    night_speed: float = 1.0
    spread: bool = True
    seed: int = 0

    def realize(self, rounds: int, m: int) -> Traces:
        if self.period < 1:
            raise ValueError(f'period must be >= 1, got {self.period}')
        if not 0.0 <= self.day_fraction <= 1.0:
            raise ValueError(
                f'day_fraction must be in [0, 1], got {self.day_fraction}')
        phase = np.random.default_rng(self.seed).integers(
            0, self.period, m) if self.spread else np.zeros(m, dtype=int)
        t = np.arange(rounds)[:, None]
        day = ((t + phase[None, :]) % self.period) \
            < self.day_fraction * self.period
        return _bundle(
            rounds, m,
            np.where(day, 1.0, self.night_availability),
            np.where(day, 1.0, self.night_bandwidth),
            np.where(day, 1.0, self.night_speed))


@dataclasses.dataclass(frozen=True)
class MarkovChurn(TraceSpec):
    """On/off churn: a two-state Markov chain per client.  An online
    client goes offline with probability ``p_off`` each round; an offline
    one returns with probability ``p_on``.  ``start_online`` is the
    fraction of clients online at round 0 (the first ``round(m * f)``
    ids, deterministically).  Offline rounds have availability 0 — the
    client certainly crashes (it is simply not there)."""
    p_off: float = 0.1
    p_on: float = 0.5
    start_online: float = 1.0
    seed: int = 0

    def realize(self, rounds: int, m: int) -> Traces:
        for name in ('p_off', 'p_on', 'start_online'):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f'{name} must be in [0, 1], got {v}')
        rng = np.random.default_rng(self.seed)
        u = rng.random((rounds, m))
        on = np.arange(m) < int(round(self.start_online * m))
        avail = np.zeros((rounds, m))
        for t in range(rounds):
            avail[t] = on
            on = np.where(on, u[t] >= self.p_off, u[t] < self.p_on)
        return _bundle(rounds, m, avail, 1.0, 1.0)


@dataclasses.dataclass(frozen=True)
class DeviceClass:
    """One device tier of a heterogeneous fleet: multipliers applied to a
    member client's bandwidth, training speed, and availability."""
    name: str
    speed: float = 1.0
    bandwidth: float = 1.0
    availability: float = 1.0


@dataclasses.dataclass(frozen=True)
class DeviceClasses(TraceSpec):
    """Device-class grid: every client belongs to one :class:`DeviceClass`
    and inherits its multipliers for the whole run.  ``mix`` gives the
    class proportions (uniform when ``None``); assignment is blocked —
    client ids are split into contiguous runs sized by largest-remainder
    rounding of ``mix * m`` — so the layout is deterministic and a member
    override changing only ``mix`` shifts class boundaries predictably."""
    classes: Tuple[DeviceClass, ...]
    mix: Optional[Tuple[float, ...]] = None

    def assignments(self, m: int) -> np.ndarray:
        """[m] int class index per client (blocked largest-remainder)."""
        k = len(self.classes)
        if k == 0:
            raise ValueError('DeviceClasses needs at least one class')
        mix = np.full(k, 1.0 / k) if self.mix is None \
            else np.asarray(self.mix, dtype=float)
        if mix.shape != (k,) or mix.min() < 0 or mix.sum() <= 0:
            raise ValueError(
                f'mix must be {k} non-negative fractions, got {self.mix}')
        mix = mix / mix.sum()
        exact = mix * m
        counts = np.floor(exact).astype(int)
        rem = m - counts.sum()
        if rem:  # largest fractional remainders get the leftover clients
            counts[np.argsort(-(exact - counts), kind='stable')[:rem]] += 1
        return np.repeat(np.arange(k), counts)

    def realize(self, rounds: int, m: int) -> Traces:
        lab = self.assignments(m)
        col = lambda f: np.array([f(c) for c in self.classes])[lab]  # noqa: E731
        return _bundle(rounds, m,
                       col(lambda c: c.availability)[None, :],
                       col(lambda c: c.bandwidth)[None, :],
                       col(lambda c: c.speed)[None, :])


@dataclasses.dataclass(frozen=True, eq=False)
class Replay(TraceSpec):
    """Replay user-supplied trace arrays (e.g. measured fleet telemetry).
    Each field is broadcastable to ``[rounds, m]`` — scalars, ``[m]``
    per-client rows, or full ``[rounds, m]`` arrays; ``None`` means the
    neutral constant.  Compared by identity (``eq=False``): array fields
    have no useful value equality."""
    availability: Optional[Any] = None
    bandwidth: Optional[Any] = None
    speed: Optional[Any] = None

    def realize(self, rounds: int, m: int) -> Traces:
        def pick(v):
            return 1.0 if v is None else v
        try:
            return _bundle(rounds, m, pick(self.availability),
                           pick(self.bandwidth), pick(self.speed))
        except ValueError as e:
            if 'broadcast' in str(e):
                raise ValueError(
                    f'Replay traces must broadcast to [rounds={rounds}, '
                    f'm={m}]; got shapes '
                    f'{[np.shape(pick(v)) for v in (self.availability, self.bandwidth, self.speed)]}') \
                    from e
            raise
