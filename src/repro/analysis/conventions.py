"""AST convention linter: repo-wide rules (``REP001``...) that neither
pytest nor the jaxpr pass can see.

Each rule is a pure source-level (or registry-introspection) check —
nothing here traces, compiles, or executes protocol code:

* **REP001** — golden rejection coverage: every registered spec type is
  constructed in at least one test module that pairs ``pytest.raises``
  with ``check_compat`` (the golden-message rejection idiom of
  ``tests/test_agg_schemes.py``), so adding a protocol without pinning
  its compat rejections fails statically.
* **REP002** — numerics hygiene: no ``np.random.*`` and no
  ``float64`` spellings inside ``core/protocol.py`` or ``kernels/`` —
  the compiled round math must stay deterministic-by-schedule and f32
  (the host event process owns all randomness).
* **REP003** — spec immutability: every registered protocol spec class,
  plus ``ExecSpec`` / ``SweepSpec`` / ``fedsim.EnvSpec``, is a frozen
  dataclass (specs are hashable cache keys and jit statics).
* **REP004** — deprecation contract: any function/class whose docstring
  opens with "deprecated" must actually emit ``DeprecationWarning``
  (directly or via a ``*deprecated*`` helper).
* **REP005** — alias inventory: every ``pallas_call`` site is keyed by
  its kernel body in the module's ``ALIAS_CONTRACTS`` dict, and the
  ``input_output_aliases`` literal at the call site is one of the
  admitted forms.  (The jaxpr pass re-proves this on lowered programs
  as JAX003; this rule catches sites in cells no registry spec lowers.)
* **REP006** — env rng reuse: a built environment (``....build()`` /
  ``FLEnv(...)``) feeding more than one ``run_sweep`` call — or more
  than one ``SweepMember`` — in a single scope.  ``Env.draw_rounds``
  raises on the second consume at runtime; this flags the hazard at
  review time, including paths tests never execute.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from .report import Report

__all__ = ['check_conventions']

#: repo root (…/src/repro/analysis/conventions.py -> three parents up)
_ROOT = pathlib.Path(__file__).resolve().parents[3]

_FLOAT64_NAMES = frozenset(
    ('jnp.float64', 'np.float64', 'numpy.float64', 'jax.numpy.float64'))


def _parse(path: pathlib.Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _dotted(node) -> str:
    """'a.b.c' for an Attribute/Name chain, '' if not a plain chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return ''
    parts.append(node.id)
    return '.'.join(reversed(parts))


def _call_tail(call: ast.Call) -> str:
    """Last component of the called dotted name ('api.SafaSpec' ->
    'SafaSpec')."""
    d = _dotted(call.func)
    return d.rsplit('.', 1)[-1] if d else ''


def _rel(root: pathlib.Path, path: pathlib.Path, lineno: int) -> str:
    return f'{path.relative_to(root)}:{lineno}'


# ---------------------------------------------------------------------------
# REP001 — golden check_compat rejection coverage
# ---------------------------------------------------------------------------

def _is_golden_module(tree: ast.Module) -> bool:
    """True if the module contains ``with pytest.raises(...):`` wrapping a
    ``check_compat`` call somewhere in the block."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.With):
            continue
        raises = any(
            isinstance(item.context_expr, ast.Call)
            and _call_tail(item.context_expr) == 'raises'
            for item in node.items)
        if not raises:
            continue
        for sub in node.body:
            for inner in ast.walk(sub):
                if isinstance(inner, ast.Call) \
                        and _call_tail(inner) == 'check_compat':
                    return True
    return False


def _rep001(rep: Report, root: pathlib.Path) -> None:
    from repro import api     # the package import registers every protocol
    spec_names = sorted(cls.__name__ for cls in api.PROTOCOLS)
    covered: dict = {}
    for path in sorted((root / 'tests').glob('test_*.py')):
        tree = _parse(path)
        if not _is_golden_module(tree):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and _call_tail(node) in spec_names:
                covered.setdefault(_call_tail(node),
                                   path.relative_to(root))
    for name in spec_names:
        where = covered.get(name)
        rep.add('REP001', name, where is not None,
                f'golden check_compat rejection test constructs it '
                f'({where})' if where is not None else
                'registered spec type is never constructed in a test '
                'module pairing pytest.raises with check_compat — add a '
                'golden rejection row (see tests/test_agg_schemes.py '
                'GOLDENS)')


# ---------------------------------------------------------------------------
# REP002 — numerics hygiene in round math and kernels
# ---------------------------------------------------------------------------

def _rep002(rep: Report, root: pathlib.Path) -> None:
    targets = [root / 'src/repro/core/protocol.py']
    targets += sorted((root / 'src/repro/kernels').glob('*.py'))
    for path in targets:
        tree = _parse(path)
        hits = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            d = _dotted(node)
            if d.startswith(('np.random.', 'numpy.random.')) \
                    or d in ('np.random', 'numpy.random'):
                hits.append((node.lineno, f'{d} (host rng belongs in the '
                             f'fedsim event process, not round math)'))
            elif d in _FLOAT64_NAMES:
                hits.append((node.lineno, f'{d} (compiled state is f32; '
                             f'f64 doubles resident bytes and breaks '
                             f'fingerprints)'))
        if hits:
            for lineno, why in hits:
                rep.add('REP002', _rel(root, path, lineno), False, why)
        else:
            rep.add('REP002', str(path.relative_to(root)), True,
                    'no np.random.* / float64 spellings')


# ---------------------------------------------------------------------------
# REP003 — specs are frozen dataclasses
# ---------------------------------------------------------------------------

def _rep003(rep: Report) -> None:
    from repro import api, fedsim
    classes = sorted(api.PROTOCOLS, key=lambda c: c.__name__)
    classes += [api.ExecSpec, api.SweepSpec, fedsim.EnvSpec]
    for cls in classes:
        frozen = dataclasses.is_dataclass(cls) \
            and cls.__dataclass_params__.frozen
        rep.add('REP003', cls.__name__, frozen,
                'frozen dataclass' if frozen else
                'not a frozen dataclass — specs are hashable cache keys '
                'and jit statics, so they must be immutable')


# ---------------------------------------------------------------------------
# REP004 — deprecated shims emit DeprecationWarning
# ---------------------------------------------------------------------------

def _warns_deprecation(node) -> bool:
    for inner in ast.walk(node):
        if not isinstance(inner, ast.Call):
            continue
        tail = _call_tail(inner)
        if 'deprecated' in tail.lower():
            return True
        if tail == 'warn' and any(
                _dotted(a).rsplit('.', 1)[-1] == 'DeprecationWarning'
                for a in list(inner.args) +
                [kw.value for kw in inner.keywords]):
            return True
    return False


def _rep004(rep: Report, root: pathlib.Path) -> None:
    shims = 0
    for path in sorted((root / 'src/repro').rglob('*.py')):
        tree = _parse(path)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                continue
            doc = ast.get_docstring(node)
            # marker = docstring OPENS with "deprecated"; mid-sentence
            # occurrences are SAFA's client lag state, not a deprecation
            if not doc or not doc.lstrip().lower().startswith('deprecated'):
                continue
            shims += 1
            ok = _warns_deprecation(node)
            rep.add('REP004', _rel(root, path, node.lineno), ok,
                    f'{node.name}: deprecated shim '
                    + ('warns' if ok else 'never emits DeprecationWarning '
                       '— silent deprecations rot in place'))
    if not shims:
        rep.add('REP004', 'src/repro', True, 'no deprecated shims declared')


# ---------------------------------------------------------------------------
# REP005 — every pallas_call site keys into ALIAS_CONTRACTS
# ---------------------------------------------------------------------------

def _module_contracts(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == 'ALIAS_CONTRACTS'
                for t in node.targets):
            return ast.literal_eval(node.value)
    return None


def _alias_forms(call: ast.Call):
    """The input_output_aliases forms a call site can take, as tuples of
    (in, out) pairs; no kwarg means the empty form.  Conditional sites
    (``{0: 1} if alias else {}``) contribute both branches."""
    kw = next((k for k in call.keywords
               if k.arg == 'input_output_aliases'), None)
    if kw is None:
        return [()]
    branches = [kw.value.body, kw.value.orelse] \
        if isinstance(kw.value, ast.IfExp) else [kw.value]
    forms = []
    for b in branches:
        d = ast.literal_eval(b)
        forms.append(tuple(sorted((int(k), int(v)) for k, v in d.items())))
    return forms


def _partial_bindings(tree: ast.Module) -> dict:
    """name -> wrapped fn name for ``x = functools.partial(_fn, ...)``
    assignments anywhere in the module (kernels bind their static params
    this way before the ``pallas_call``)."""
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and isinstance(node.value, ast.Call) \
                and _call_tail(node.value) == 'partial' \
                and node.value.args \
                and isinstance(node.value.args[0], ast.Name):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value.args[0].id
    return out


def _rep005(rep: Report, root: pathlib.Path) -> None:
    for path in sorted((root / 'src/repro').rglob('*.py')):
        tree = _parse(path)
        sites = [node for node in ast.walk(tree)
                 if isinstance(node, ast.Call)
                 and _call_tail(node) == 'pallas_call']
        if not sites:
            continue
        contracts = _module_contracts(tree)
        if contracts is None:
            rep.add('REP005', str(path.relative_to(root)), False,
                    f'{len(sites)} pallas_call site(s) but no module '
                    f'ALIAS_CONTRACTS inventory')
            continue
        partials = _partial_bindings(tree)
        bad = 0
        for call in sites:
            kernel = call.args[0].id if call.args \
                and isinstance(call.args[0], ast.Name) else '<dynamic>'
            kernel = partials.get(kernel, kernel)
            subject = _rel(root, path, call.lineno)
            if kernel not in contracts:
                bad += 1
                rep.add('REP005', subject, False,
                        f'kernel {kernel!r} missing from the module '
                        f'ALIAS_CONTRACTS inventory')
                continue
            for form in _alias_forms(call):
                if form not in contracts[kernel]:
                    bad += 1
                    rep.add('REP005', subject, False,
                            f'{kernel} aliases {form} not admitted by '
                            f'inventory {contracts[kernel]}')
        if not bad:
            rep.add('REP005', str(path.relative_to(root)), True,
                    f'{len(sites)} pallas_call site(s) all in inventory')


# ---------------------------------------------------------------------------
# REP006 — built env reused across run_sweep calls / members
# ---------------------------------------------------------------------------

def _scope_walk(scope):
    """Walk a scope's statements without descending into nested
    function/class scopes (their reuse is judged separately)."""
    stack = list(scope.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.ClassDef)):
                stack.append(child)


def _built_env_names(scope) -> dict:
    """var name -> lineno for ``x = <...>.build()`` / ``x = FLEnv(...)``
    assignments in this scope."""
    out = {}
    for node in _scope_walk(scope):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        # ``.build()`` on ANY receiver (EnvSpec(...).build() roots the
        # attribute chain in a Call, which _call_tail can't follow)
        fn = node.value.func
        built_call = (isinstance(fn, ast.Attribute) and fn.attr == 'build') \
            or _call_tail(node.value) == 'FLEnv'
        if not built_call:
            continue
        for t in node.targets:
            if isinstance(t, ast.Name):
                out[t.id] = node.lineno
    return out


def _names_in(node) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _rep006_scope(rep: Report, root: pathlib.Path,
                  path: pathlib.Path, scope) -> int:
    built = _built_env_names(scope)
    if not built:
        return 0
    uses: dict = {}
    for node in _scope_walk(scope):
        if not isinstance(node, ast.Call):
            continue
        tail = _call_tail(node)
        if tail not in ('run_sweep', 'SweepMember'):
            continue
        args = list(node.args) + [kw.value for kw in node.keywords]
        for name in set.union(set(), *(_names_in(a) for a in args)) \
                if args else set():
            if name in built:
                uses.setdefault((name, tail), []).append(node.lineno)
    fails = 0
    for (name, tail), lines in sorted(uses.items()):
        if len(lines) > 1:
            fails += 1
            rep.add('REP006', _rel(root, path, min(lines)), False,
                    f'built env {name!r} (line {built[name]}) feeds '
                    f'{len(lines)} {tail} calls (lines {sorted(lines)}); '
                    f'draw_rounds is single-shot per built env — build a '
                    f'fresh env per sweep or pass the EnvSpec')
    return fails


def _rep006(rep: Report, root: pathlib.Path) -> None:
    files = 0
    fails = 0
    for sub in ('src', 'tests', 'launch', 'scripts'):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob('*.py')):
            tree = _parse(path)
            files += 1
            scopes = [tree] + [n for n in ast.walk(tree)
                               if isinstance(n, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef))]
            for scope in scopes:
                fails += _rep006_scope(rep, root, path, scope)
    if not fails:
        rep.add('REP006', 'repo', True,
                f'{files} files scanned, no built env feeds multiple '
                f'run_sweep calls or members')


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------

def check_conventions(root=None) -> Report:
    """Run REP001-REP006 over the repo tree."""
    root = pathlib.Path(root) if root is not None else _ROOT
    rep = Report()
    _rep001(rep, root)
    _rep002(rep, root)
    _rep003(rep)
    _rep004(rep, root)
    _rep005(rep, root)
    _rep006(rep, root)
    return rep


if __name__ == '__main__':      # pragma: no cover - dev helper
    r = check_conventions()
    for f in r.findings:
        print(f)
    print(r.summary())
