"""Static schedule verifier: prove the invariants the kernels assume.

The aliased tier kernels, the sentinel-slot no-op contract, and the
weighted-merge residual all rest on *schedule* properties that the
engines never re-check at runtime.  ``verify_schedule`` proves them on
any schedule instance — including ones users build from their own
traces/EnvSpecs — by independent recomputation (the lifetime/liveness
replay here shares no code with ``build_tier_schedule``'s allocator).

Rules
-----

* **SCH001** — tier read/write slot disjointness: per round, every
  written buffer slot (``cache_dst`` != scratch, ``global_dst``) is
  distinct from every other write and from every read slot
  (``base_src``/``cache_src``).  This is exactly the property that lets
  ``safa_aggregate_packed_*_tier_rows`` alias the ``[capacity+1, N]``
  buffer in place.
* **SCH002** — capacity == peak live rows: replaying value lifetimes
  from the slot maps alone (a write opens an interval, the last read
  closes it) must reproduce ``capacity`` exactly — the first-fit
  allocator's promise that the buffer is minimal, with no dead rows and
  no slot written twice without an intervening read.
* **SCH003** — sentinel slots are inert: ``idx == m`` slots carry zero
  roles and scratch-only slot maps, active slots carry nonzero roles,
  and padding is a contiguous suffix (the kernels rely on sentinel rows
  writing only to scratch).
* **SCH004** — lag <= tau everywhere (Eq. 3): replaying the version
  counters of the dense masks, no client's model may lag the global
  version by more than ``lag_tolerance`` after distribution, and
  deprecated clients must be force-synced; picked/undrafted must be
  committed subsets.
* **SCH005** — weight rows: ``wrow >= 0``, zero off the committed set,
  and each row sums to at most ``alpha`` (+1 ulp slack) so the merge's
  residual global weight stays non-negative.  FedAsync alphas obey the
  same bounds per merge, and merge orders are permutations.
* **SCH006** — sparse active-set indices sorted strictly ascending
  (unique) per round, all within ``[0, m)``.

Fleet-major stacks are verified member-by-member through their
``member(s)`` accessors; the tier fleet additionally proves that the
shared fleet capacity is the max of the members' peak live counts.
"""
from __future__ import annotations

import numpy as np

from repro.core import protocol, schedules

from .report import Report

__all__ = ['verify_schedule']

_EPS = 1e-6


def verify_schedule(sched, *, lag_tolerance=None, alpha=None,
                    subject=None) -> Report:
    """Prove every applicable invariant of ``sched``; returns a
    :class:`~repro.analysis.report.Report` (``.raise_if_failed()`` for
    assert-style use).  ``lag_tolerance`` enables the SCH004 lag bound on
    dense SAFA schedules; ``alpha`` tightens the SCH005 row-sum bound
    (defaults to 1.0, the hard residual-non-negativity bound)."""
    rep = Report()
    name = subject if subject is not None else type(sched).__name__
    if isinstance(sched, schedules.SafaSchedule):
        _check_safa_masks(rep, name, sched, lag_tolerance)
    elif isinstance(sched, (schedules.SparseSchedule,
                            schedules.SparseSyncSchedule)):
        _check_sparse(rep, name, sched)
    elif isinstance(sched, schedules.TierSchedule):
        _check_sparse(rep, name, sched)
        _check_tier(rep, name, sched, exact_capacity=True)
    elif isinstance(sched, schedules.TierFleetSchedule):
        peaks = []
        for s in range(sched.size):
            mem = sched.member(s)
            mname = f'{name}[member={s}]'
            _check_sparse(rep, mname, mem)
            # fleet members share the fleet-max capacity; each member's
            # own peak may be smaller
            peaks.append(_check_tier(rep, mname, mem, exact_capacity=False))
        peak = max(peaks)
        rep.add('SCH002', name, peak == sched.capacity,
                f'fleet capacity {sched.capacity} vs max member peak '
                f'live rows {peak}')
    elif isinstance(sched, schedules.WeightedSchedule):
        _check_weighted(rep, name, sched, alpha)
    elif isinstance(sched, schedules.FedasyncSchedule):
        _check_async(rep, name, sched)
    elif isinstance(sched, (schedules.SyncSchedule, schedules.LocalSchedule)):
        _check_bool_masks(rep, name, sched)
    elif isinstance(sched, (schedules.FleetSchedule,
                            schedules.SyncFleetSchedule,
                            schedules.LocalFleetSchedule,
                            schedules.AsyncFleetSchedule,
                            schedules.WeightedFleetSchedule,
                            schedules.SparseFleetSchedule,
                            schedules.SparseSyncFleetSchedule)):
        for s in range(sched.size):
            rep.extend(verify_schedule(sched.member(s),
                                       lag_tolerance=lag_tolerance,
                                       alpha=alpha,
                                       subject=f'{name}[member={s}]'))
    else:
        raise TypeError(
            f'verify_schedule: unsupported schedule type '
            f'{type(sched).__name__}')
    return rep


# ---------------------------------------------------------------------------
# Dense SAFA masks (SCH004)
# ---------------------------------------------------------------------------

def _check_safa_masks(rep: Report, name: str, sched, lag_tolerance) -> None:
    sync, committed = sched.sync, sched.committed
    picked, undrafted = sched.picked, sched.undrafted
    deprecated = sched.deprecated
    rounds, m = sync.shape
    ok_sets = True
    detail = ''
    for t in range(rounds):
        if not (committed[t] | ~picked[t]).all() \
                or not (committed[t] | ~undrafted[t]).all():
            ok_sets, detail = False, f'picked/undrafted not ⊆ committed ' \
                f'at round {t + 1}'
            break
        if (picked[t] & undrafted[t]).any():
            ok_sets, detail = False, f'picked ∩ undrafted nonempty at ' \
                f'round {t + 1}'
            break
        if not (sync[t] | ~deprecated[t]).all():
            ok_sets, detail = False, f'deprecated client not synced at ' \
                f'round {t + 1} (Eq. 3 forces stale clients to sync)'
            break
    rep.add('SCH004', name, ok_sets,
            detail or f'role-subset structure holds over {rounds} rounds')
    if lag_tolerance is None:
        return
    tau = int(lag_tolerance)
    v = np.zeros(m, np.int64)
    worst = 0
    for t in range(rounds):
        v[sync[t]] = t
        worst = max(worst, int((t - v).max()))
        v[committed[t]] = t + 1
    rep.add('SCH004', f'{name}[lag]', worst <= tau,
            f'max post-distribution staleness {worst} vs tau={tau}')


def _check_bool_masks(rep: Report, name: str, sched) -> None:
    """Sync/local schedules carry plain bool masks; the only static
    contract is shape/dtype sanity (kept so the registry pass emits a
    row for every protocol rather than silently skipping)."""
    masks = [getattr(sched, f) for f in ('selected', 'completed')
             if hasattr(sched, f)]
    ok = all(a.dtype == np.bool_ and a.ndim == 2 for a in masks)
    rep.add('SCH004', name, ok,
            f'{len(masks)} boolean [rounds, m] mask(s)')


# ---------------------------------------------------------------------------
# Sparse active sets (SCH003 + SCH006)
# ---------------------------------------------------------------------------

def _check_sparse(rep: Report, name: str, sched) -> None:
    idx, roles, m = sched.idx, sched.roles, sched.m
    rounds = idx.shape[0]
    ok_sorted = ok_inert = True
    d_sorted = d_inert = ''
    for t in range(rounds):
        valid = idx[t] < m
        act = idx[t][valid]
        if (idx[t] > m).any() or (idx[t] < 0).any():
            ok_sorted, d_sorted = False, \
                f'index out of [0, m] at round {t + 1}'
            break
        if act.size and not (np.diff(act) > 0).all():
            ok_sorted, d_sorted = False, \
                f'active indices not strictly ascending at round {t + 1}'
            break
        if valid.any() and not valid[:valid.sum()].all():
            ok_inert, d_inert = False, \
                f'sentinel slot before an active slot at round {t + 1}'
            break
        if (roles[t][~valid] != 0).any():
            ok_inert, d_inert = False, \
                f'sentinel slot carries nonzero role at round {t + 1}'
            break
        if (roles[t][valid] == 0).any():
            ok_inert, d_inert = False, \
                f'active slot carries zero role at round {t + 1}'
            break
    rep.add('SCH006', name, ok_sorted,
            d_sorted or f'active sets sorted/unique over {rounds} rounds')
    rep.add('SCH003', name, ok_inert,
            d_inert or 'sentinel slots inert (zero roles, contiguous '
            'suffix)')


# ---------------------------------------------------------------------------
# Tier slot maps (SCH001 + SCH002 + SCH003 on the maps)
# ---------------------------------------------------------------------------

def _check_tier(rep: Report, name: str, sched, *,
                exact_capacity: bool) -> int:
    """Prove the tier slot maps safe for in-place aliasing and minimal in
    capacity.  Returns the independently recomputed peak live count."""
    idx, roles = sched.idx, sched.roles
    base_src, cache_src = sched.base_src, sched.cache_src
    cache_dst, global_dst = sched.cache_dst, sched.global_dst
    scratch, m = sched.scratch, sched.m
    rounds, width = idx.shape
    r_c, r_s = protocol.ROLE_COMMITTED, protocol.ROLE_SYNC

    ok_disjoint = ok_inert = True
    d_disjoint = d_inert = ''
    reads_by_round, writes_by_round = [], []
    for t in range(rounds):
        valid = idx[t] < m
        reads = set(base_src[t][valid]) | set(cache_src[t][valid])
        reads.discard(scratch)
        writes = [int(s) for s in cache_dst[t][valid] if s != scratch]
        if global_dst[t] != scratch:
            writes.append(int(global_dst[t]))
        if len(writes) != len(set(writes)) and ok_disjoint:
            ok_disjoint, d_disjoint = False, \
                f'two writes share a slot at round {t + 1}'
        clash = reads & set(writes)
        if clash and ok_disjoint:
            ok_disjoint, d_disjoint = False, \
                f'slot {sorted(clash)[0]} both read and written at ' \
                f'round {t + 1} (in-place aliasing would clobber it)'
        sentinel_maps = np.concatenate(
            [base_src[t][~valid], cache_src[t][~valid],
             cache_dst[t][~valid]])
        if (sentinel_maps != scratch).any() and ok_inert:
            ok_inert, d_inert = False, \
                f'sentinel slot maps to a live row at round {t + 1}'
        # a synced committed slot reads no base (its base IS the fresh
        # global); a pure-sync slot touches no buffer row at all
        commit_only = valid & ((roles[t] & r_c) != 0) \
            & ((roles[t] & r_s) == 0)
        if (base_src[t][valid & ~commit_only] != scratch).any() \
                and ok_inert:
            ok_inert, d_inert = False, \
                f'non-commit slot reads a base row at round {t + 1}'
        reads_by_round.append(reads)
        writes_by_round.append(set(writes))

    rep.add('SCH001', name, ok_disjoint,
            d_disjoint or f'read/write slot sets disjoint over {rounds} '
            f'rounds (capacity {sched.capacity})')
    rep.add('SCH003', f'{name}[maps]', ok_inert,
            d_inert or 'sentinel slots map to scratch only')

    peak, ok_cap, d_cap = _replay_lifetimes(
        sched.capacity, reads_by_round, writes_by_round)
    if exact_capacity:
        ok = ok_cap and peak == sched.capacity
        rep.add('SCH002', name, ok,
                d_cap or f'capacity {sched.capacity} == recomputed peak '
                f'live rows {peak}')
    elif not ok_cap:
        rep.add('SCH002', name, False, d_cap)
    return peak


def _replay_lifetimes(capacity: int, reads_by_round, writes_by_round):
    """Recompute peak concurrently-live rows from the slot maps alone.

    A write opens a value interval; the last read of that slot before its
    next write closes it.  Rows live before any write are init state
    (interval open from round 0).  A slot is occupied from its write
    round through its last read round inclusive — the allocator frees it
    only the round after — so the peak is the max closed-interval
    overlap.  Also flags dead writes (a written row never read back):
    the allocator never emits them, and their presence means capacity is
    not minimal."""
    rounds = len(reads_by_round)
    intervals = []      # (write_round, last_read_round)
    open_at: dict = {}  # slot -> write round of the live value
    last_read: dict = {}
    init_slots = set()
    for t in range(rounds):
        for s in reads_by_round[t]:
            if s not in open_at and s not in init_slots:
                init_slots.add(s)
                open_at[s] = 0
            last_read[s] = t
        for s in writes_by_round[t]:
            if s in open_at:
                lr = last_read.get(s)
                if lr is None or lr < open_at[s]:
                    return 0, False, \
                        f'slot {s} written at round {t + 1} but its ' \
                        f'previous value was never read (dead row)'
                intervals.append((open_at[s], lr))
            open_at[s] = t
            last_read.pop(s, None)
    for s, w in open_at.items():
        lr = last_read.get(s)
        if lr is None:
            if s in init_slots:
                continue    # init rows may go unread (empty schedules)
            return 0, False, \
                f'slot {s} written at round {w + 1} and never read'
        intervals.append((w, lr))
    if not intervals:
        return 0, True, ''
    peak = 0
    for t in range(rounds):
        live = sum(1 for (w, lr) in intervals if w <= t <= lr)
        peak = max(peak, live)
    if peak > capacity:
        return peak, False, \
            f'{peak} rows live at once but capacity is {capacity}'
    return peak, True, ''


# ---------------------------------------------------------------------------
# Weight rows (SCH005)
# ---------------------------------------------------------------------------

def _check_weighted(rep: Report, name: str, sched, alpha) -> None:
    bound = 1.0 if alpha is None else float(alpha)
    wrow, committed = np.asarray(sched.wrow), sched.committed
    ok, detail = True, ''
    if (wrow < 0).any():
        ok, detail = False, 'negative merge weight'
    elif (wrow[~committed] != 0).any():
        ok, detail = False, 'nonzero weight off the committed set'
    else:
        sums = wrow.sum(axis=1)
        worst = float(sums.max()) if sums.size else 0.0
        if worst > bound + _EPS:
            ok, detail = False, \
                f'row sum {worst:.6f} exceeds alpha={bound} (residual ' \
                f'global weight would go negative)'
        else:
            detail = f'rows >= 0, max row sum {worst:.6f} <= {bound}'
    rep.add('SCH005', name, ok, detail)


def _check_async(rep: Report, name: str, sched) -> None:
    alphas, committed = np.asarray(sched.alphas), sched.committed
    order = np.asarray(sched.order)
    m = alphas.shape[1]
    ok, detail = True, ''
    if (alphas < 0).any() or (alphas > 1 + _EPS).any():
        ok, detail = False, 'merge alpha outside [0, 1]'
    elif (alphas[~committed] != 0).any():
        ok, detail = False, 'nonzero alpha off the committed set'
    elif any(not np.array_equal(np.sort(order[t]), np.arange(m))
             for t in range(order.shape[0])):
        ok, detail = False, 'merge order is not a permutation'
    else:
        detail = f'alphas in [0, 1], orders are permutations of {m}'
    rep.add('SCH005', name, ok, detail)
