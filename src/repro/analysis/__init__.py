"""repro.analysis — static contract checker for engines, kernels, and
schedules.

Three registry-driven passes, none of which execute protocol code:

* **jaxpr pass** (``JAX001``-``JAX006``, ``jaxpr_checks``): lower every
  admitted ``engine x wire x schedule x use_kernel`` cell of every spec
  in ``api.PROTOCOLS`` at tiny shapes and prove the compiled-program
  invariants — pallas dispatch budgets (``ProtocolDef.dispatch_budget``),
  effective donations, ``input_output_aliases`` claims, no f64, no host
  callbacks in scan bodies, segment re-dispatch fingerprint stability.
* **schedule pass** (``SCH001``-``SCH006``, ``schedule_checks``): verify
  host-precomputed schedules — tier slot disjointness and exact
  capacity, sentinel inertness, lag <= tau, weight-row bounds, sorted
  sparse indices.  ``verify_schedule(sched)`` is the standalone entry.
* **conventions pass** (``REP001``-``REP006``, ``conventions``): AST /
  registry rules — golden ``check_compat`` rejection coverage, numerics
  hygiene, frozen specs, deprecation warnings, pallas alias inventories,
  built-env rng reuse.

``run_all()`` chains the three into one ``Report``;
``python -m repro.analysis --all --json ANALYSIS.json`` is the CI entry.
"""
from __future__ import annotations

from .conventions import check_conventions
from .jaxpr_checks import check_cells, iter_cells, lower_cell
from .report import AnalysisError, Finding, Report
from .schedule_checks import verify_schedule

__all__ = [
    'AnalysisError', 'Finding', 'Report', 'check_cells',
    'check_conventions', 'check_schedules', 'iter_cells', 'lower_cell',
    'run_all', 'verify_schedule',
]


def check_schedules(names=None) -> Report:
    """Verify the host-precomputed schedule of every distinct
    (protocol, engine, schedule-form) cell — the same precompute path the
    runners dispatch, deduplicated over wire/kernel (which don't change
    the schedule)."""
    from . import jaxpr_checks
    rep = Report()
    seen = set()
    for cell in jaxpr_checks.iter_cells(names):
        key = (cell.pdef.name, cell.ex.engine, cell.ex.schedule)
        if key in seen:
            continue
        seen.add(key)
        subject = f'{cell.pdef.name}[{cell.ex.engine}/{cell.ex.schedule}]'
        try:
            sched = jaxpr_checks.precompute_cell(cell)
        except Exception as e:      # precompute must not break the pass
            rep.add('SCH001', subject, False,
                    f'schedule precompute failed: {type(e).__name__}: {e}')
            continue
        rep.extend(verify_schedule(
            sched,
            lag_tolerance=getattr(cell.spec, 'lag_tolerance', None),
            alpha=getattr(cell.spec, 'alpha', None),
            subject=subject))
    return rep


def run_all(names=None) -> Report:
    """All three passes over the registry (or the named protocols), one
    combined Report."""
    rep = Report()
    rep.extend(check_conventions())
    rep.extend(check_schedules(names))
    rep.extend(check_cells(names))
    return rep
