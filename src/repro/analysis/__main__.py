"""CLI: ``python -m repro.analysis --all [--json ANALYSIS.json]``.

Runs the three static passes over every registered protocol (or a named
subset), prints the per-rule summary plus every failure, optionally
writes the machine-readable per-spec, per-rule report, and exits
non-zero on any violated contract — the CI contract-gate entry point.
"""
from __future__ import annotations

import argparse
import sys

from repro import api

from . import run_all


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog='python -m repro.analysis',
        description='Static contract checker: jaxpr, schedule, and '
                    'convention passes over the protocol registry.')
    parser.add_argument('--all', action='store_true',
                        help='check every registered protocol (default '
                             'when no --protocol is given)')
    parser.add_argument('--protocol', action='append', default=None,
                        metavar='NAME',
                        help='check only this protocol (repeatable)')
    parser.add_argument('--json', default=None, metavar='PATH',
                        help='write the machine-readable report here')
    parser.add_argument('-v', '--verbose', action='store_true',
                        help='print every finding, not just failures')
    args = parser.parse_args(argv)

    names = None if args.all or not args.protocol else set(args.protocol)
    if names is not None:
        known = {p.name for p in api.PROTOCOLS.values()}
        bad = names - known
        if bad:
            parser.error(f'unknown protocol(s) {sorted(bad)} '
                         f'(registered: {sorted(known)})')

    report = run_all(names)
    shown = report.findings if args.verbose else report.failures
    for f in shown:
        print(f)
    if args.json:
        report.to_json(args.json)
        print(f'wrote {args.json}')
    print(report.summary())
    return 0 if report.ok else 1


if __name__ == '__main__':
    sys.exit(main())
