"""Finding/Report types shared by every analysis pass.

A pass emits one ``Finding`` per (rule, subject) pair it evaluated —
passing findings included, so ``ANALYSIS.json`` is a complete per-spec,
per-rule matrix and a rule that silently stopped running shows up as a
missing row, not a green report.  Failures name the spec and rule in the
same style as the conformance harness ids.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule evaluation: ``rule`` (e.g. ``'SCH001'``), ``subject``
    (spec/cell/file the rule ran against), ``ok``, and a human detail
    line (the violation for failures, the checked quantity for passes)."""
    rule: str
    subject: str
    ok: bool
    detail: str = ''

    def __str__(self) -> str:
        mark = 'ok  ' if self.ok else 'FAIL'
        return f'{mark} {self.rule} {self.subject}: {self.detail}'


@dataclasses.dataclass
class Report:
    """An ordered collection of findings from one or more passes."""
    findings: list = dataclasses.field(default_factory=list)

    def add(self, rule: str, subject: str, ok: bool, detail: str = ''):
        self.findings.append(Finding(rule, subject, ok, detail))

    def extend(self, other: 'Report') -> 'Report':
        self.findings.extend(other.findings)
        return self

    @property
    def ok(self) -> bool:
        return all(f.ok for f in self.findings)

    @property
    def failures(self) -> list:
        return [f for f in self.findings if not f.ok]

    def rules(self) -> set:
        return {f.rule for f in self.findings}

    def by_rule(self, rule: str) -> list:
        return [f for f in self.findings if f.rule == rule]

    def raise_if_failed(self) -> 'Report':
        """For library users (``analysis.verify_schedule(...)``): turn a
        failing report into one exception naming every violated rule."""
        if not self.ok:
            lines = '\n'.join(str(f) for f in self.failures)
            raise AnalysisError(
                f'{len(self.failures)} analysis finding(s) failed:\n{lines}')
        return self

    def to_dict(self) -> dict:
        by_subject: dict = {}
        for f in self.findings:
            by_subject.setdefault(f.subject, []).append(
                {'rule': f.rule, 'ok': f.ok, 'detail': f.detail})
        return {
            'ok': self.ok,
            'checked': len(self.findings),
            'failed': len(self.failures),
            'rules': sorted(self.rules()),
            'subjects': by_subject,
        }

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=2, sort_keys=True)
        if path is not None:
            with open(path, 'w') as fh:
                fh.write(text + '\n')
        return text

    def summary(self) -> str:
        n_fail = len(self.failures)
        state = 'PASS' if not n_fail else f'FAIL ({n_fail} finding(s))'
        return (f'{state}: {len(self.findings)} checks over '
                f'{len({f.subject for f in self.findings})} subjects, '
                f'{len(self.rules())} rules')


class AnalysisError(AssertionError):
    """A static contract the analyzer proves was found violated."""
